"""Trainer: loss decreases, watchdog, preemption checkpoint."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticTokens
from repro.optim import make_optimizer
from repro.train.trainer import Trainer, TrainerConfig, WatchdogTimeout


def _trainer(tmp_path=None, steps=25, watchdog=0.0, **run_kw):
    cfg = registry.get_smoke_config("llama3-8b")
    run = RunConfig(learning_rate=3e-3, **run_kw)
    return Trainer(cfg, run, make_optimizer(run),
                   SyntheticTokens(cfg, batch=8, seq=16, seed=0),
                   TrainerConfig(total_steps=steps,
                                 ckpt_dir=str(tmp_path) if tmp_path else None,
                                 ckpt_every=10, log_every=5, prefetch=2,
                                 watchdog_s=watchdog))


def test_fit_reduces_loss():
    t = _trainer()
    hist = t.fit()
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert all(h["grads_finite"] == 1.0 for h in hist)


def test_watchdog_checkpoints_and_raises(tmp_path):
    t = _trainer(tmp_path, steps=5, watchdog=1e-9)   # every step "hangs"
    with pytest.raises(WatchdogTimeout):
        t.fit()
    assert t.ckpt.latest_step() is not None          # state was saved


def test_preemption_checkpoints(tmp_path):
    t = _trainer(tmp_path, steps=1000)
    t._preempted = True                              # simulate SIGTERM
    t.fit()
    assert t.ckpt.latest_step() == 1                 # stopped + saved


def test_grad_accum_must_divide_batch():
    """grad_accum that doesn't divide the batch fails with an actionable
    message naming both values, not an opaque reshape error."""
    cfg = registry.get_smoke_config("llama3-8b")
    from repro.train import state as S
    from repro.train.steps import make_train_step
    from repro.configs import shapes
    batch = shapes.make_batch(cfg, 8, 16)
    run = RunConfig(grad_accum=3)
    opt = make_optimizer(run)
    st = S.init_state(jax.random.key(0), cfg, run, opt)
    step = jax.jit(make_train_step(cfg, run, opt))
    with pytest.raises(ValueError, match=r"grad_accum=3.*batch size 8"):
        step(st, batch)


def test_grad_accum_equivalence():
    """accum=2 with the same global batch gives a loss within tolerance of
    accum=1 (mean-of-microbatch losses == full-batch loss for CE)."""
    cfg = registry.get_smoke_config("llama3-8b")
    from repro.train import state as S
    from repro.train.steps import make_train_step
    from repro.configs import shapes
    batch = shapes.make_batch(cfg, 8, 16)
    losses = {}
    for k in (1, 2):
        run = RunConfig(grad_accum=k)
        opt = make_optimizer(run)
        st = S.init_state(jax.random.key(0), cfg, run, opt)
        step = jax.jit(make_train_step(cfg, run, opt))
        _, m = step(st, batch)
        losses[k] = float(m["loss"])
    np.testing.assert_allclose(losses[1], losses[2], rtol=1e-3)
