"""MPX casting semantics (paper §3.1–3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import mpx


def test_cast_tree_floats_only():
    key = jax.random.key(0)
    tree = {"w": jnp.ones((4, 4), jnp.float32),
            "ids": jnp.arange(3, dtype=jnp.int32),
            "mask": jnp.array([True, False]),
            "key": key,
            "static": "name",
            "n": 7}
    out = mpx.cast_tree(tree, jnp.bfloat16)
    assert out["w"].dtype == jnp.bfloat16
    assert out["ids"].dtype == jnp.int32
    assert out["mask"].dtype == jnp.bool_
    assert out["key"] is key            # PRNG keys untouched (paper §3.1)
    assert out["static"] == "name" and out["n"] == 7


def test_cast_roundtrip_structure():
    tree = {"a": [jnp.ones(3), (jnp.zeros(2), None)], "b": jnp.arange(4)}
    out = mpx.cast_to_float16(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)


def test_convenience_casts():
    x = {"w": jnp.ones(3, jnp.float32)}
    assert mpx.cast_to_float16(x)["w"].dtype == jnp.float16
    assert mpx.cast_to_bfloat16(x)["w"].dtype == jnp.bfloat16
    assert mpx.cast_to_float32(mpx.cast_to_float16(x))["w"].dtype == jnp.float32


def test_half_dtype_global():
    mpx.set_half_dtype(jnp.float16)
    try:
        assert mpx.cast_to_half_precision(
            {"w": jnp.ones(2)})["w"].dtype == jnp.float16
    finally:
        mpx.set_half_dtype(jnp.bfloat16)
    with pytest.raises(ValueError):
        mpx.set_half_dtype(jnp.float32)


def test_cast_function_inputs_and_outputs():
    def f(x, y):
        assert x.dtype == jnp.bfloat16 and y.dtype == jnp.bfloat16
        return x @ y

    g = mpx.cast_function(f, jnp.bfloat16, return_dtype=jnp.float32)
    out = g(jnp.ones((2, 3)), jnp.ones((3, 2)))
    assert out.dtype == jnp.float32


def test_force_full_precision_softmax():
    # bf16 softmax of large values overflows exp without fp32 internals
    x = jnp.asarray([60000.0, 0.0, -60000.0], jnp.float16)
    safe = mpx.force_full_precision(jax.nn.softmax, x.dtype)(x)
    assert safe.dtype == jnp.float16
    assert np.all(np.isfinite(np.asarray(safe, np.float32)))
    np.testing.assert_allclose(np.asarray(safe, np.float32)[0], 1.0,
                               atol=1e-3)


def test_force_full_precision_inside_jit():
    @jax.jit
    def f(x):
        return mpx.force_full_precision(jnp.mean, x.dtype)(x)

    x = jnp.full((1000,), 3.0, jnp.bfloat16)
    np.testing.assert_allclose(float(f(x)), 3.0, rtol=1e-2)


def test_policy_parse():
    p = mpx.Policy.parse("params=float32,compute=bfloat16,output=float32")
    assert p == mpx.MIXED_BF16
    assert mpx.Policy.parse("p=f32,c=f16,o=f32") == mpx.MIXED_F16
    assert mpx.Policy.parse("f32") == mpx.FULL_F32
    assert mpx.MIXED_F16.needs_loss_scaling
    assert not mpx.MIXED_BF16.needs_loss_scaling
    assert mpx.MIXED_BF16.is_mixed and not mpx.FULL_F32.is_mixed
