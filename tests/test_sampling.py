"""Sampling transforms and distributions: top-p threshold filter vs the
scatter formulation, and statistical checks of temperature/top-k/top-p
(and rejection sampling) against a numpy reference over many draws."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve
from repro.serve.sampling import (NEG_INF, SamplingParams, _apply_top_p,
                                  transform_logits)

pytestmark = pytest.mark.serve


# --------------------------------------------------------------------------
# top-p: threshold filter pins the scatter formulation's token survival
# --------------------------------------------------------------------------

def _top_p_scatter_ref(logits, p):
    """The pre-refactor full-vocab-scatter formulation (oracle)."""
    vocab = logits.shape[-1]
    sorted_l, sorted_idx = jax.lax.top_k(logits, vocab)
    probs = jax.nn.softmax(sorted_l, axis=-1)
    cum_before = jnp.cumsum(probs, axis=-1) - probs
    sorted_l = jnp.where(cum_before < p, sorted_l, NEG_INF)
    out = jnp.full_like(logits, NEG_INF)
    batch = jnp.arange(logits.shape[0])[:, None]
    return out.at[batch, sorted_idx].set(sorted_l)


@pytest.mark.parametrize("p", [0.05, 0.3, 0.7, 0.95, 0.999])
def test_top_p_threshold_matches_scatter(p):
    """Identical token survival AND surviving values, no (B, V) scatter."""
    logits = 3.0 * jax.random.normal(jax.random.key(17), (8, 64), jnp.float32)
    got = _apply_top_p(logits, p)
    want = _top_p_scatter_ref(logits, p)
    got_keep = np.asarray(got) > NEG_INF / 2
    want_keep = np.asarray(want) > NEG_INF / 2
    np.testing.assert_array_equal(got_keep, want_keep)
    np.testing.assert_allclose(np.asarray(got)[got_keep],
                               np.asarray(want)[want_keep])
    # the top token always survives, even when its own mass exceeds p
    assert got_keep[np.arange(8), np.asarray(jnp.argmax(logits, -1))].all()


def test_top_p_threshold_ties_keep_whole_tie_class():
    """Logits tied with the boundary value ALL survive (deterministic,
    token-order-independent) — the documented divergence from the scatter
    formulation, which broke ties by sort position.  Ties are real on the
    serving path: bf16 head logits quantize tail tokens to equal values."""
    logits = jnp.asarray([[2.0, 1.0, 1.0, 1.0, 0.0]], jnp.float32)
    # softmax mass: top token ~0.46; p=0.5 -> threshold is the first 1.0,
    # and every 1.0 survives with it
    out = np.asarray(_apply_top_p(logits, 0.5))[0]
    assert (out[:4] > NEG_INF / 2).all() and out[4] < NEG_INF / 2


def test_top_p_one_keeps_everything_implicitly():
    """top_p=1.0 is a no-op at the SamplingParams level (never filtered)."""
    sp = SamplingParams(temperature=1.0, top_p=1.0)
    logits = jax.random.normal(jax.random.key(0), (2, 16))
    np.testing.assert_allclose(np.asarray(transform_logits(logits, sp)),
                               np.asarray(logits, np.float32), rtol=1e-6)


# --------------------------------------------------------------------------
# statistical: sampled frequencies vs a numpy reference distribution
# --------------------------------------------------------------------------

def _numpy_reference_dist(logits, sp: SamplingParams):
    """Expected sampling distribution computed independently in numpy."""
    l = np.asarray(logits, np.float64) / sp.temperature
    if sp.top_k > 0 and sp.top_k < l.shape[-1]:
        kth = np.sort(l)[..., -sp.top_k]
        l = np.where(l < kth, -np.inf, l)
    if sp.top_p < 1.0:
        order = np.argsort(-l)
        sl = l[order]
        pr = np.exp(sl - sl.max())
        pr = pr / pr.sum()
        cum_before = np.cumsum(pr) - pr
        drop = order[cum_before >= sp.top_p]
        l[drop] = -np.inf
    e = np.exp(l - l[np.isfinite(l)].max())
    e[~np.isfinite(l)] = 0.0
    return e / e.sum()


def _empirical(tokens, vocab):
    return np.bincount(np.asarray(tokens), minlength=vocab) / len(tokens)


def _draw(logits_row, sp, n, seed):
    """n independent draws in ONE device call (batch the row n times)."""
    tiled = jnp.tile(jnp.asarray(logits_row, jnp.float32)[None], (n, 1))
    return serve.sample_logits(tiled, jax.random.key(seed), sp)


@pytest.mark.slow
def test_sampling_distributions_match_numpy_reference():
    """Temperature / top-k / top-p empirical frequencies track the numpy
    reference within total-variation tolerance (hypothesis-seeded logits
    when hypothesis is installed, a fixed sweep otherwise)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    vocab, n = 24, 8000

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           case=st.sampled_from([
               SamplingParams(temperature=0.7),
               SamplingParams(temperature=1.3, top_k=5),
               SamplingParams(temperature=1.0, top_p=0.8),
               SamplingParams(temperature=2.0, top_k=8, top_p=0.9),
           ]))
    def prop(seed, case):
        rng = np.random.default_rng(seed)
        logits = rng.normal(0.0, 2.0, vocab).astype(np.float32)
        want = _numpy_reference_dist(logits, case)
        got = _empirical(_draw(logits, case, n, seed), vocab)
        tv = 0.5 * np.abs(got - want).sum()
        assert tv < 0.06, (case, tv)
        # truncation support is exact, not just close: no forbidden token
        assert got[want == 0].sum() == 0.0

    prop()


@pytest.mark.slow
def test_rejection_sampling_preserves_target_distribution():
    """Leviathan guarantee: the marginal of the first emitted token under
    accept/residual equals the target distribution row 0, whatever the
    (deterministic) draft token — measured over many independent slots."""
    vocab, n = 16, 8000
    rng = np.random.default_rng(3)
    row = rng.normal(0.0, 1.5, vocab).astype(np.float32)
    sp = SamplingParams(temperature=0.9)
    want = _numpy_reference_dist(row, sp)
    for d in (int(np.argmax(row)), int(np.argmin(row)), 5):
        logits = jnp.tile(jnp.asarray(row)[None, None], (n, 2, 1))
        draft = jnp.full((n, 1), d, jnp.int32)
        accept, token = serve.rejection_sample(
            logits, draft, jnp.ones((n,), jnp.int32), jax.random.key(d), sp)
        accept, token = np.asarray(accept), np.asarray(token)
        first = np.where(accept > 0, d, token)
        tv = 0.5 * np.abs(_empirical(first, vocab) - want).sum()
        assert tv < 0.06, (d, tv)
        # acceptance probability is the target mass of the draft token
        assert abs(accept.mean() - want[d]) < 0.03


def test_make_sampler_returns_ids_and_probs():
    """Samplers expose the post-transform distribution alongside ids —
    the verify step consumes the probs, plain serving the ids."""
    logits = jax.random.normal(jax.random.key(2), (4, 32), jnp.bfloat16)
    ids, probs = serve.make_sampler(SamplingParams())(logits, None)
    assert ids.shape == (4,) and probs.shape == (4, 32)
    assert probs.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(jnp.argmax(probs, -1)),
                                  np.asarray(ids))          # greedy one-hot
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-6)
    sp = SamplingParams(temperature=1.0, top_k=4)
    l32 = jax.random.normal(jax.random.key(3), (4, 32), jnp.float32)
    ids, probs = serve.make_sampler(sp)(l32, jax.random.key(0))
    assert np.all(np.asarray(probs > 0).sum(-1) == 4)       # truncated
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-6)
