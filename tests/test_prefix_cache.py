"""Prefix caching with refcounted, copy-on-write page sharing.

Pool-level: refcounts track table multiplicity, retire decrements
instead of freeing, unreferenced registered pages park on a cached LRU
list and are reclaimed lazily, the rolling per-page hash makes admission
probes and registration O(pages touched), and ``check_invariants``
proves the free/held/referenced/cached partition (no page simultaneously
free and referenced).

Engine-level: greedy output is token-identical with the prefix cache on
vs off on bf16 AND quantized (``kv=i8``) pools — the i8 case pins
COW-before-requantize, since ``quantized_paged_write`` is a
read-modify-write of whole pages — registered pages stay bitwise intact
across another tenant's COW writes and speculative truncations, hybrid
stacks degrade to sharing-off gracefully, and preemption under pool
pressure composes with shared pages.
"""
import jax
import numpy as np
import pytest

from repro import mpx, serve
from repro.configs.base import ModelConfig
from repro.models import transformer as T

pytestmark = pytest.mark.serve

CFG = ModelConfig(
    name="prefix-test", family="dense",
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=128, pattern=("attn",), mlp="swiglu",
    tie_embeddings=True, remat="none",
)

HYBRID = ModelConfig(
    name="prefix-hybrid", family="hybrid",
    n_layers=3, d_model=48, n_heads=4, n_kv_heads=1, head_dim=12,
    d_ff=96, vocab_size=128, pattern=("rglru", "local_attn"), window=8,
    mlp="geglu", norm="rmsnorm", d_rnn=48, conv_width=4,
    rope_theta=10000.0, tie_embeddings=True, remat="none",
)


@pytest.fixture(scope="module")
def params():
    return mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), CFG))


@pytest.fixture(scope="module")
def hybrid_params():
    return mpx.cast_to_bfloat16(T.init_params(jax.random.key(1), HYBRID))


def make_cache(**kw):
    kw.setdefault("page_size", 8)
    kw.setdefault("prefix_cache", True)
    return serve.PagedKVCache(CFG, kw.pop("n_slots", 2),
                              kw.pop("max_seq", 64), **kw)


def commit_feed(cache, slot, feed):
    """Drive a slot's watermarks as if prefill committed all of ``feed``
    (starting from the admission skip) and register its full pages."""
    cache.note_write(slot, len(feed))
    cache.truncate(slot, len(feed))
    cache.note_committed(slot, feed)


def page_bits(cache, phys):
    """Bitwise host snapshot of one physical page across every page-pool
    leaf (K/V values and, for quantized formats, the amax-scale
    sidecars)."""
    mask = T.slot_state_mask(cache.cfg, kv_format=cache.kv_format.name)
    out = []
    for key in sorted(cache.pages):
        stacked = key == "scan"
        for a, m in zip(jax.tree.leaves(cache.pages[key]),
                        jax.tree.leaves(mask[key])):
            if not m:
                out.append(np.asarray(a[:, phys] if stacked else a[phys]))
    return out


# --------------------------------------------------------------------------
# pool-level refcounting
# --------------------------------------------------------------------------

def test_share_retire_cache_refcount_lifecycle():
    cache = make_cache(num_pages=12)
    feed = list(range(100, 125))             # 25 tokens: 3 full pages + 1
    assert cache.admit(0, 29, feed=feed)     # 4 pages, nothing resident
    assert cache.slot_length(0) == 0         # no skip on a cold pool
    commit_feed(cache, 0, feed)
    assert len(cache._index) == 3            # the 3 full pages registered
    # second tenant, same feed: maps the 3 registered pages shared
    assert cache.admit(1, 29, feed=feed)
    assert cache.slot_length(1) == 24        # skip = 3 full pages
    assert cache.shared_pages == 3
    assert cache._owned[0][:3] == cache._owned[1][:3]
    for p in cache._owned[1][:3]:
        assert cache._refcount[p] == 2
    cache.check_invariants()
    # retire the first tenant: shared pages stay referenced, its private
    # page goes free — nothing another slot maps is ever freed
    cache.retire(0)
    cache.check_invariants()
    for p in cache._owned[1][:3]:
        assert cache._refcount[p] == 1
    assert cache.shared_pages == 0
    # retire the last tenant: registered pages park cached (LRU), not free
    cache.retire(1)
    cache.check_invariants()
    assert cache.cached_pages == 3
    assert cache.free_pages + cache.cached_pages == cache.num_pages
    # ...and a third tenant still hits them
    assert cache.admit(0, 29, feed=feed)
    assert cache.slot_length(0) == 24
    assert cache.cached_pages == 0           # re-referenced out of the LRU
    cache.check_invariants()


def test_admission_boundary_cow_when_every_feed_page_hits():
    cache = make_cache(num_pages=12)
    feed = list(range(16))                   # exactly 2 pages
    assert cache.admit(0, 20, feed=feed)
    commit_feed(cache, 0, feed)
    cache.retire(0)
    assert cache.admit(1, 20, feed=feed)     # full-page hit
    # skip is capped one short: the final feed token must still run to
    # produce logits, and its write lands in the last hit page -> COW
    assert cache.slot_length(1) == 15
    assert len(cache._cow_pending) == 1
    src, dst = cache._cow_pending[0]
    assert cache._tables[1, 1] == dst != src
    assert cache._page_digest[src]           # original stays registered
    assert cache._refcount[src] == 0 and src in cache._lru
    assert cache._refcount[dst] == 1
    cache.check_invariants()


def test_lru_eviction_reclaims_cached_pages_under_pressure():
    cache = make_cache(num_pages=6, max_seq=48)
    old = list(range(16))
    assert cache.admit(0, 17, feed=old)      # 3 pages
    commit_feed(cache, 0, old)
    cache.retire(0)                          # 2 cached + 1 free...
    assert cache.cached_pages == 2 and cache.free_pages == 4
    # a 6-page admission must evict the cached pages (free list is 4)
    assert cache.can_admit(48)
    fresh = list(range(50, 90))
    assert cache.admit(1, 48, feed=fresh)
    assert cache.cached_pages == 0           # LRU reclaimed
    assert len(cache._index) == 0            # ...and unregistered
    cache.check_invariants()
    cache.retire(1)
    cache.check_invariants()


def test_admit_failure_mutates_nothing_even_with_partial_hits():
    cache = make_cache(num_pages=4, max_seq=64)
    feed = list(range(16))
    assert cache.admit(0, 20, feed=feed)     # 3 pages
    commit_feed(cache, 0, feed)
    before_free = cache.free_pages
    before_rc = list(cache._refcount)
    # hits 2 registered pages but needs more fresh pages than exist
    assert not cache.admit(1, 64, feed=feed + list(range(20, 60)))
    assert cache.free_pages == before_free
    assert cache._refcount == before_rc
    assert cache._owned[1] == []
    cache.check_invariants()


def test_defensive_cow_in_note_write():
    cache = make_cache(num_pages=12)
    feed = list(range(24))                   # 3 full pages
    assert cache.admit(0, 28, feed=feed)
    commit_feed(cache, 0, feed)
    assert cache.admit(1, 28, feed=feed)     # 3 shared, skip=23 (capped)
    assert cache.slot_length(1) == 23
    shared_before = [int(p) for p in cache._tables[1, :3]]
    n_pending = len(cache._cow_pending)
    # planning a write into the span that covers the shared page 2 must
    # COW it (the admission already queued page 2's boundary copy, so
    # force the defensive path on page 1 by faking a rewind)
    cache._written[1] = 8
    cache._committed[1] = 8
    cache.note_write(1, 20)                  # span covers pages 1 and 2
    assert int(cache._tables[1, 1]) != shared_before[1]
    assert len(cache._cow_pending) > n_pending
    assert cache._refcount[shared_before[1]] == 1   # slot 0's alone
    cache.check_invariants()


def test_rolling_hash_is_incremental(monkeypatch):
    """Satellite: registration hashes each committed page exactly once —
    O(pages newly committed), never a rehash of the whole prefix."""
    cache = make_cache(num_pages=12, max_seq=64)
    feed = list(range(48))                   # 6 pages
    assert cache.admit(0, 52, feed=feed)
    calls = []
    real = cache._page_hash
    monkeypatch.setattr(cache, "_page_hash",
                        lambda prev, toks: (calls.append(len(toks)),
                                            real(prev, toks))[1])
    # commit in three chunks: each registration hashes only new pages
    for end in (16, 40, 48):
        cache.note_write(0, end)
        cache.truncate(0, end)
        cache.note_committed(0, feed)
    assert len(calls) == 6                   # one hash per page, total
    assert cache._hash_state[0][0] == 6
    # the admission probe for an identical feed hashes each page once too
    calls.clear()
    assert cache.admit(1, 52, feed=feed)
    assert len(calls) == 6
    cache.check_invariants()


def test_prefix_cache_off_keeps_refcounts_at_most_one():
    cache = make_cache(num_pages=12, prefix_cache=False)
    feed = list(range(16))
    assert cache.admit(0, 20, feed=feed)
    commit_feed(cache, 0, feed)
    assert cache._index == {} and cache.cached_pages == 0
    assert cache.admit(1, 20, feed=feed)
    assert cache.slot_length(1) == 0         # no skip without the cache
    assert cache.shared_pages == 0
    assert max(cache._refcount) <= 1
    cache.retire(0)
    cache.retire(1)
    assert cache.free_pages == cache.num_pages
    cache.check_invariants()


def test_hybrid_stack_keeps_sharing_inert():
    # recurrent state depends on the full token history — skipping
    # prefill over shared pages is unsound, so the flag degrades to off
    cache = serve.PagedKVCache(HYBRID, 2, 64, page_size=8,
                               prefix_cache=True)
    assert cache.prefix_cache is False
    feed = list(range(16))
    assert cache.admit(0, 20, feed=feed)
    commit_feed(cache, 0, feed)
    assert cache._index == {}
    cache.retire(0)
    assert cache.free_pages == cache.num_pages
    cache.check_invariants()


# --------------------------------------------------------------------------
# engine e2e: token identity and bitwise page stability
# --------------------------------------------------------------------------

def make_engine(params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 128)
    kw.setdefault("page_size", 16)
    kw.setdefault("chunk_size", 16)
    return serve.ServeEngine(CFG, params, **kw)


def shared_prompts(n_hot=3, seed=9, prefix_len=32, suffix_len=3):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, CFG.vocab_size, prefix_len).tolist()
    return [list(prefix)] + [
        prefix + rng.integers(1, CFG.vocab_size, suffix_len).tolist()
        for _ in range(n_hot)]


@pytest.mark.parametrize("kv", ["bf16", "i8"])
def test_greedy_identity_prefix_cache_on_vs_off(params, kv):
    """The acceptance-criteria pin: same tokens with sharing on or off,
    on the bf16 passthrough AND the quantized pool (where identity
    requires COW before the requantizing scatter)."""
    # warm with the bare prefix, hot suffixed variants, then the bare
    # prefix again — the repeat is a full-page hit, the boundary COW path
    prompts = shared_prompts() + [shared_prompts()[0]]
    outs = {}
    for pc in (False, True):
        eng = make_engine(params, kv_dtype=kv, prefix_cache=pc)
        for p in prompts:                    # sequential: warm then hot
            eng.submit(list(p), max_new=8)
            eng.drain()
            eng.cache.check_invariants()
        res = eng.drain()                    # all results, id-sorted
        outs[pc] = [r.tokens for r in res]
        snap = eng.metrics_snapshot()
        if pc:
            assert snap["serve_prefix_hits_total"] > 0
            assert snap["serve_cow_copies_total"] >= 1  # boundary COW
            # the hot requests skipped their cached prefix in prefill
            assert all(r.metrics.cached_prefix_tokens > 0
                       for r in res[1:])
        else:
            assert snap["serve_prefix_hits_total"] == 0
            assert all(r.metrics.cached_prefix_tokens == 0 for r in res)
    assert outs[True] == outs[False]


@pytest.mark.parametrize("kv", ["bf16", "i8"])
def test_registered_pages_bitwise_stable_across_cow_and_truncate(params,
                                                                 kv):
    """Satellite: a hot tenant's writes — including speculative windows
    whose rejection rollback lands inside its COW copy — must never
    disturb the original registered pages, bit for bit (values and, for
    i8, the amax-scale sidecars)."""
    prompts = shared_prompts(n_hot=1)
    eng = make_engine(params, kv_dtype=kv, prefix_cache=True,
                      spec_tokens=3, chunk_size=16)
    eng.submit(list(prompts[0]), max_new=8)  # warm: registers the prefix
    base = eng.drain()
    eng.cache.check_invariants()
    pinned = {phys: page_bits(eng.cache, phys)
              for phys in eng.cache._page_digest}
    assert pinned                            # prefix actually registered
    # hot request: full-page hits + boundary COW + speculative windows
    eng.submit(list(prompts[0]), max_new=8)
    res = eng.drain()
    eng.cache.check_invariants()
    assert res[-1].metrics.cached_prefix_tokens > 0
    assert res[-1].tokens == base[0].tokens  # greedy + identical prompt
    snap = eng.metrics_snapshot()
    assert snap["serve_cow_copies_total"] >= 1
    for phys, before in pinned.items():
        after = page_bits(eng.cache, phys)
        for a, b in zip(before, after):
            np.testing.assert_array_equal(a, b)


def test_hybrid_engine_accepts_flag_and_stays_identical(hybrid_params):
    prompts = shared_prompts(n_hot=1, seed=4)
    outs = {}
    for pc in (False, True):
        eng = serve.ServeEngine(HYBRID, hybrid_params, n_slots=2,
                                max_seq=128, page_size=16, chunk_size=16,
                                prefix_cache=pc)
        assert eng.cache.prefix_cache is False or not pc
        for p in prompts:
            eng.submit(list(p), max_new=6)
        outs[pc] = [r.tokens for r in eng.drain()]
        eng.cache.check_invariants()
    assert outs[True] == outs[False]


def test_preemption_composes_with_shared_pages(params):
    """Pool pressure with sharing active: preemption must count only the
    victim's exclusive pages as reclaimable, never free a page another
    slot references, and keep greedy output identical."""
    prompts = shared_prompts(n_hot=3, prefix_len=32, suffix_len=2)
    ample = make_engine(params, prefix_cache=True)
    base = []
    for p in prompts:
        ample.submit(list(p), max_new=8)
        base += ample.drain()
    base = {r.request_id: r.tokens for r in base}
    # tight pool: warm sequentially, then all hot requests at once so
    # admissions overlap decodes and pressure can preempt
    eng = make_engine(params, prefix_cache=True, num_pages=10)
    eng.submit(list(prompts[0]), max_new=8)
    eng.drain()
    for p in prompts[1:]:
        eng.submit(list(p), max_new=8)
    while eng.scheduler.has_work:
        eng.step()
        eng.cache.check_invariants()
    res = {r.request_id: r for r in eng.drain()}
    assert all(r.status == "ok" for r in res.values())
    for rid, toks in base.items():
        assert res[rid].tokens == toks, f"rid {rid} diverged"
    eng.cache.check_invariants()


def test_recompute_after_preemption_hits_its_own_prefix(params):
    """A preempted request re-admits with feed = prompt + committed
    output — its own registered pages are the cache hit, so recompute
    prefill skips most of the re-feed."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, CFG.vocab_size, 24).tolist()
               for _ in range(2)]
    ample = make_engine(params, prefix_cache=True)
    for p in prompts:
        ample.submit(list(p), max_new=8)
    base = {r.request_id: r.tokens for r in ample.drain()}
    eng = make_engine(params, prefix_cache=True, num_pages=5,
                      page_size=16)
    for p in prompts:
        eng.submit(list(p), max_new=8)
    res = {r.request_id: r for r in eng.drain()}
    eng.cache.check_invariants()
    assert all(r.status == "ok" for r in res.values())
    for rid, r in res.items():
        assert r.tokens == base[rid], f"rid {rid} diverged"
    snap = eng.metrics_snapshot()
    if snap.get("serve_preemptions_total", 0):
        # the victim's recompute found its own pages resident
        assert snap["serve_prefix_hits_total"] > 0
