"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles,
executed in interpret mode on CPU (the TPU-lowering path is identical
modulo the interpreter)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,d", [
    (2, 256, 4, 2, 64),
    (1, 128, 2, 1, 128),
    (1, 512, 8, 8, 32),
])
def test_flash_attention_shapes_dtypes(b, s, h, kv, d, dtype):
    q = jax.random.normal(jax.random.key(0), (b, s, h, d), dtype)
    k = jax.random.normal(jax.random.key(1), (b, s, kv, d), dtype)
    v = jax.random.normal(jax.random.key(2), (b, s, kv, d), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    ke, ve = (jnp.repeat(t, h // kv, axis=2) for t in (k, v))
    want = ref.flash_attention_ref(q, ke, ve, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    assert got.dtype == dtype


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 96])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_flash_attention_masks_and_caps(causal, window, softcap):
    b, s, h, d = 1, 256, 2, 64
    q = jax.random.normal(jax.random.key(0), (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (b, s, h, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (b, s, h, d), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=0.05, rtol=0.05)


def test_flash_attention_fp32_state_stability():
    """Large logits: bf16-softmax would overflow; fp32 state must not."""
    b, s, h, d = 1, 128, 1, 64
    q = 30.0 * jax.random.normal(jax.random.key(0), (b, s, h, d),
                                 jnp.bfloat16)
    k = 30.0 * jax.random.normal(jax.random.key(1), (b, s, h, d),
                                 jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (b, s, h, d), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    assert np.all(np.isfinite(np.asarray(got, np.float32)))


@pytest.mark.parametrize("shape", [(128, 512), (3, 17, 256), (1000, 64),
                                   (5, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(shape, dtype):
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    w = jax.random.normal(jax.random.key(1), shape[-1:], jnp.float32)
    got = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=0.08)
    assert got.dtype == dtype


@pytest.mark.parametrize("n", [64, 1000, 65536 + 17])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_unscale_finite_kernel(n, dtype):
    g = jax.random.normal(jax.random.key(0), (n,), dtype) * 100
    out, ok = ops.unscale_and_check(g, 1.0 / 512.0, block=4096)
    wout, wok = ref.unscale_finite_ref(g, 1.0 / 512.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(wout), rtol=1e-6)
    assert bool(ok) and out.dtype == jnp.float32


@pytest.mark.parametrize("bad", [jnp.inf, -jnp.inf, jnp.nan])
def test_unscale_finite_detects(bad):
    g = jnp.ones((10000,), jnp.float32).at[7777].set(bad)
    _, ok = ops.unscale_and_check(g, 0.5, block=1024)
    assert not bool(ok)


def test_unscale_finite_padding_cannot_mask_infs():
    # inf in the very last element, with padding after it
    g = jnp.ones((4097,), jnp.float32).at[4096].set(jnp.inf)
    _, ok = ops.unscale_and_check(g, 1.0, block=4096)
    assert not bool(ok)
