"""Flight recorder: journal record/replay identity + postmortem analyzer.

The chaos drive exercised here is the full failure matrix in one
recording — NaN-poisoned logits, a clock-jump deadline expiry, a
cancellation, preemption ping-pong on a deliberately tight page pool,
prefix-cache sharing, and an i8-quantized KV pool — and the pins are:

- the journal replays it **token-identically** (every per-tick digest
  and every request result equal) from the header alone, params rebuilt
  from ``param_seed``;
- a perturbed journal names the **first divergent tick** with both
  digests, and a tampered result raises a result mismatch;
- a truncated journal refuses to replay (``JournalTruncated``) but still
  feeds the postmortem analyzer;
- fingerprint drift (``JournalMismatch``), an unreplayable custom
  proposer, and a missing ``param_seed`` all fail with actionable
  errors, never a silent wrong replay;
- the postmortem report tells each request's causal story (phases,
  preemptions, prefix hits, deadline/cancel/nonfinite outcome) and joins
  the trace / Prometheus / precision artifacts when supplied.
"""
import json

import jax
import pytest

from repro import mpx, serve
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.obs import (JournalDivergence, JournalError, JournalRecorder,
                       JournalTruncated, Tracer, read_journal,
                       replay_journal)
from repro.obs.journal import JournalMismatch, _Replayer
from repro.obs.journal import main as journal_main
from repro.obs.postmortem import analyze, parse_prometheus, render
from repro.obs.postmortem import main as postmortem_main

CFG = ModelConfig(
    name="journal-test", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, pattern=("attn",), mlp="swiglu",
    tie_embeddings=True, remat="none",
)

PARAM_SEED = 7
PREFIX = list(range(1, 9))          # one full page, shared by most prompts


def _chaos_drive(journal_path, tracer=None):
    """One drive covering every failure path: poison (rid 3), deadline
    expiry via clock jump (rid 4), cancel (rid 5), preemption ping-pong
    between rid 1 and rid 2 on a 6-page pool, prefix-cache sharing of
    PREFIX, i8 KV.  Deterministic: FakeClock + greedy sampling."""
    params = mpx.cast_to_bfloat16(
        T.init_params(jax.random.key(PARAM_SEED), CFG))
    faults = (serve.FaultInjector(clock=serve.FakeClock())
              .poison_logits(3)
              .advance_clock(10, 100.0))
    journal = JournalRecorder(journal_path, param_seed=PARAM_SEED)
    engine = serve.ServeEngine(
        CFG, params, n_slots=2, max_seq=64, page_size=8, num_pages=6,
        chunk_size=16, kv_dtype="i8", prefix_cache=True,
        faults=faults, tracer=tracer, journal=journal)
    engine.submit(PREFIX + [40], max_new=3)                    # rid 0
    engine.submit(PREFIX + [50], max_new=12)                   # rid 1
    engine.submit([100 + i for i in range(17)], max_new=8)     # rid 2
    engine.submit(PREFIX + [60, 61], max_new=4)                # rid 3 poison
    engine.submit(PREFIX + [70, 71, 72], max_new=20,
                  deadline_ms=50)                              # rid 4
    rid_cx = engine.submit(PREFIX + [80, 81], max_new=8)       # rid 5
    engine.step()
    engine.step()
    engine.cancel(rid_cx)
    results = engine.drain()
    journal.close()
    return engine, results


@pytest.fixture(scope="module")
def chaos(tmp_path_factory):
    path = tmp_path_factory.mktemp("journal") / "chaos.jsonl"
    engine, results = _chaos_drive(str(path))
    return {"path": str(path), "engine": engine,
            "results": {r.request_id: r for r in results}}


# --------------------------------------------------------------------------
# record -> replay identity
# --------------------------------------------------------------------------

@pytest.mark.serve
def test_chaos_drive_covers_every_failure_path(chaos):
    """The fixture drive must actually exercise what it claims to —
    otherwise the replay pin below proves nothing."""
    status = {rid: r.status for rid, r in chaos["results"].items()}
    assert status == {0: "ok", 1: "ok", 2: "ok", 3: "failed",
                      4: "timeout", 5: "cancelled"}
    snap = chaos["engine"].metrics_snapshot()
    assert snap["serve_preemptions_total"] >= 2     # the ping-pong fired
    assert chaos["engine"].cache.prefix_hits >= 1   # sharing fired
    assert chaos["results"][2].metrics.preempted_seconds > 0.0


@pytest.mark.serve
def test_replay_is_token_and_digest_identical(chaos):
    report = replay_journal(chaos["path"])
    assert report.ok
    assert report.ticks >= 10
    assert report.results == len(chaos["results"])
    assert not report.result_mismatches
    assert "replay OK" in report.summary()


@pytest.mark.serve
def test_journal_cli_replays(chaos, capsys):
    assert journal_main([chaos["path"]]) == 0
    assert "replay OK" in capsys.readouterr().out


@pytest.mark.serve
def test_journal_records_full_schema(chaos):
    header, events = read_journal(chaos["path"])
    assert header["schema"] == 1
    assert header["param_seed"] == PARAM_SEED
    assert header["config"]["name"] == "journal-test"
    eng = header["engine"]
    assert eng["kv_dtype"] == "i8" and eng["prefix_cache"] is True
    assert header["faults"]["poison"] == {"3": None}
    assert header["faults"]["advances"] == {"10": 100.0}
    assert header["faults"]["has_clock"] is True
    kinds = {ev["ev"] for ev in events}
    assert {"clocks", "submit", "cancel", "tick", "result"} <= kinds
    # per-request phase numbers ride the result records (satellite:
    # postmortem reads them without recomputing)
    res = [ev for ev in events if ev["ev"] == "result"]
    assert len(res) == 6
    for ev in res:
        assert {"queue_wait", "prefill_s", "decode_s",
                "preempted_s", "preemptions"} <= set(ev["m"])
    m2 = next(ev["m"] for ev in res if ev["rid"] == 2)
    assert m2["preemptions"] >= 1 and m2["preempted_s"] > 0.0


# --------------------------------------------------------------------------
# divergence / tamper / truncation diagnostics
# --------------------------------------------------------------------------

def _rewrite(src_path, dst_path, mutate):
    """Copy a journal line by line, letting ``mutate(obj)`` edit records."""
    with open(src_path) as f, open(dst_path, "w") as out:
        for line in f:
            obj = json.loads(line)
            mutate(obj)
            out.write(json.dumps(obj) + "\n")


@pytest.mark.serve
def test_perturbed_journal_names_first_divergent_tick(chaos, tmp_path):
    bad = tmp_path / "perturbed.jsonl"
    target = 3

    def flip_tok(obj):
        if obj["ev"] == "tick" and obj["i"] == target:
            d = obj["d"]
            d["tok"] = ("0" * 32 if d["tok"][0] != "0"
                        else "f" + d["tok"][1:])

    _rewrite(chaos["path"], bad, flip_tok)
    with pytest.raises(JournalDivergence, match=f"diverged at tick {target}"):
        replay_journal(str(bad))
    try:
        replay_journal(str(bad))
    except JournalDivergence as err:
        assert err.tick == target
        assert err.recorded != err.replayed       # both digests carried
    # CLI maps divergence to exit code 1, not a traceback
    assert journal_main([str(bad)]) == 1


@pytest.mark.serve
def test_tampered_result_tokens_flagged(chaos, tmp_path):
    bad = tmp_path / "tampered.jsonl"

    def flip_token(obj):
        if obj["ev"] == "result" and obj["rid"] == 0:
            obj["tokens"][-1] = (obj["tokens"][-1] + 1) % 256

    _rewrite(chaos["path"], bad, flip_token)
    with pytest.raises(JournalError, match="result mismatch rid=0"):
        replay_journal(str(bad))
    report = replay_journal(str(bad), raise_on_divergence=False)
    assert not report.ok and report.result_mismatches


@pytest.mark.serve
def test_truncated_journal_refuses_replay_but_feeds_postmortem(tmp_path):
    path = tmp_path / "truncated.jsonl"
    params = mpx.cast_to_bfloat16(
        T.init_params(jax.random.key(PARAM_SEED), CFG))
    journal = JournalRecorder(str(path), param_seed=PARAM_SEED,
                              max_events=12)
    engine = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                               page_size=8, chunk_size=16, journal=journal)
    engine.submit(PREFIX + [40], max_new=8)
    engine.submit(PREFIX + [50], max_new=8)
    engine.drain()
    journal.close()
    assert journal.truncated
    with pytest.raises(JournalTruncated, match="max_events"):
        replay_journal(str(path))
    assert journal_main([str(path)]) == 2
    # the postmortem still reads the recorded prefix and says so
    text = render(analyze(str(path)))
    assert "journal truncated" in text


def test_fingerprint_mismatch_names_the_drifted_paths(chaos):
    header, _ = read_journal(chaos["path"])
    rep = _Replayer(header, [])
    live = {"config": header["config"], "engine": dict(header["engine"])}
    live["engine"]["n_slots"] = 4
    live["engine"]["kv_dtype"] = "bf16"
    with pytest.raises(JournalMismatch) as err:
        rep.on_attach(live, None)
    msg = str(err.value)
    assert "engine.n_slots" in msg and "engine.kv_dtype" in msg
    assert "recorded 2" in msg          # both sides of the drift shown


def test_custom_proposer_requires_explicit_instance(chaos, tmp_path):
    bad = tmp_path / "proposer.jsonl"

    def set_proposer(obj):
        if obj["ev"] == "header":
            obj["engine"]["proposer"] = "MyProposer"

    _rewrite(chaos["path"], bad, set_proposer)
    with pytest.raises(JournalError, match="custom proposer 'MyProposer'"):
        replay_journal(str(bad))


def test_missing_param_seed_is_actionable(chaos, tmp_path):
    bad = tmp_path / "noseed.jsonl"

    def drop_seed(obj):
        if obj["ev"] == "header":
            obj["param_seed"] = None

    _rewrite(chaos["path"], bad, drop_seed)
    with pytest.raises(JournalError, match="param_seed"):
        replay_journal(str(bad))


def test_corrupt_and_headerless_journals_rejected(tmp_path):
    p = tmp_path / "corrupt.jsonl"
    p.write_text('{"ev": "header", "schema": 1}\nnot json\n')
    with pytest.raises(JournalError, match="not valid JSON"):
        read_journal(str(p))
    p.write_text('{"ev": "tick", "i": 0, "d": {}}\n')
    with pytest.raises(JournalError, match="no header record"):
        read_journal(str(p))
    p.write_text('{"ev": "header", "schema": 99}\n')
    with pytest.raises(JournalError, match="schema"):
        read_journal(str(p))


# --------------------------------------------------------------------------
# postmortem analyzer
# --------------------------------------------------------------------------

@pytest.mark.serve
def test_postmortem_tells_each_requests_story(chaos):
    text = render(analyze(chaos["path"]))
    assert "# Serve postmortem" in text
    for rid in range(6):
        assert f"### request {rid}" in text
    # outcomes + the chaos schedule are named
    assert "**failed**" in text and "**timeout**" in text \
        and "**cancelled**" in text
    assert "fault schedule" in text and "poison" in text
    # the preempted requests carry attribution with evicted time
    assert "preempted" in text
    assert "prefix cache absorbed" in text
    # phase decomposition renders per request
    assert "queue wait" in text and "prefill" in text and "decode" in text
    assert "prefix cache lifetime" in text


@pytest.mark.serve
def test_postmortem_joins_trace_metrics_precision(tmp_path):
    tracer = Tracer(process_name="repro.serve.test")
    engine, _ = _chaos_drive(str(tmp_path / "j.jsonl"), tracer=tracer)
    trace_path = tmp_path / "trace.json"
    tracer.export(str(trace_path))
    metrics_path = tmp_path / "metrics.prom"
    metrics_path.write_text(engine.prometheus())
    precision_path = tmp_path / "precision.json"
    precision_path.write_text(json.dumps(
        {"loss_scale_trajectory": [1024.0, 512.0, 512.0, 1024.0],
         "overflow_steps": 1, "skipped_steps": 1}))
    report = analyze(str(tmp_path / "j.jsonl"), trace_path=str(trace_path),
                     metrics_path=str(metrics_path),
                     precision_path=str(precision_path))
    text = render(report)
    assert "## Engine phase time (trace)" in text
    assert "## Engine metrics (Prometheus snapshot)" in text
    assert "mean queue wait" in text            # satellite-1 histograms join
    assert "preemptions:" in text
    assert "## Precision telemetry" in text
    assert "loss scale trajectory: start 1024" in text
    # per-request trace join: decode spans attributed by rid
    assert "- trace:" in text


@pytest.mark.serve
def test_postmortem_cli_writes_report(chaos, tmp_path, capsys):
    out = tmp_path / "report.md"
    assert postmortem_main([chaos["path"], "--out", str(out)]) == 0
    assert "postmortem report ->" in capsys.readouterr().out
    assert "# Serve postmortem" in out.read_text()


def test_parse_prometheus_roundtrips_escaped_labels():
    from repro.obs import Registry
    r = Registry()
    hostile = 'a "quoted" \\ backslash\nnewline'
    r.counter("x_total", "h", labels=("msg",)).inc(3, msg=hostile)
    parsed = parse_prometheus(r.prometheus())
    assert parsed == {f'x_total{{msg="{hostile}"}}': 3.0}
