"""repro.obs: registry / tracer / precision telemetry, and the serving
engine's observability contracts.

Pins the load-bearing invariants of the telemetry layer:

- the metrics registry's Prometheus subset (label series, monotone
  counters, log2 bucket boundaries, text exposition);
- the Chrome-trace schema the CI artifact relies on (required fields,
  span nesting) — validated on a real engine drive, no bench run needed;
- ``EngineStats.summary()``'s exact pre-registry key set (the bench/CI
  artifact schema keys on it);
- ``_percentile`` nearest-rank edge cases;
- the full-tick timing contract of ``ServeEngine.step()`` (elapsed
  covers admit through commit ≈ drain wall time);
- the ``drain()`` no-progress guard;
- **zero added device syncs**: instrumentation on/off, one engine step
  transfers exactly the two ``(B,)`` arrays it always has;
- the §3.3 precision trajectory: overflow -> halve -> skip observable in
  a :class:`PrecisionStats` snapshot, per-layer grad summaries computed
  in-jit with fixed shapes.
"""
import inspect
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import mpx, serve
from repro.configs.base import ModelConfig, RunConfig
from repro.core.loss_scaling import DynamicLossScaling
from repro.models import transformer as T
from repro.obs import Registry, Tracer, validate_chrome_trace
from repro.obs.precision import (FP16_TINY, PrecisionStats,
                                 grad_layer_names, per_layer_grad_summary)
from repro.obs.registry import Counter, Gauge, Histogram
from repro.serve.metrics import _percentile
from repro.serve.scheduler import Request

CFG = ModelConfig(
    name="obs-test", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, pattern=("attn",), mlp="swiglu",
    tie_embeddings=True, remat="none",
)


@pytest.fixture(scope="module")
def params():
    return mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), CFG))


def ragged_prompts(n, seed=0, lo=2, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, CFG.vocab_size, int(k)).tolist()
            for k in rng.integers(lo, hi, n)]


# --------------------------------------------------------------------------
# registry: counters / gauges / histograms
# --------------------------------------------------------------------------

def test_counter_label_series():
    c = Counter("steps_total", "x", labels=("kind",))
    c.inc(kind="prefill")
    c.inc(2, kind="mixed")
    c.inc(kind="mixed")
    assert c.value(kind="prefill") == 1
    assert c.value(kind="mixed") == 3
    assert c.value(kind="decode") == 0          # untouched series reads 0
    assert c.total == 4
    with pytest.raises(ValueError):             # counters only go up
        c.inc(-1, kind="mixed")
    with pytest.raises(ValueError):             # undeclared label
        c.inc(flavor="x")


def test_gauge_set_and_ratchet():
    g = Gauge("pages_used_peak")
    g.set(5)
    g.set(3)
    assert g.value() == 3
    g.set_max(7)
    g.set_max(2)                                # ratchet: never goes down
    assert g.value() == 7


def test_histogram_bucket_boundaries():
    h = Histogram("lat", lo_exp=0, hi_exp=3)    # edges 1, 2, 4, 8, +Inf
    assert h.edges == (1.0, 2.0, 4.0, 8.0, float("inf"))
    assert h.bucket_index(0.5) == 0
    assert h.bucket_index(1.0) == 0             # le semantics: v <= edge
    assert h.bucket_index(2.0) == 1
    assert h.bucket_index(2.0000001) == 2
    assert h.bucket_index(8.0) == 3
    assert h.bucket_index(8.1) == 4             # +Inf bucket
    assert h.bucket_index(0.0) == 0             # non-positive clamps low
    assert h.bucket_index(-3.0) == 0


def test_histogram_exact_on_powers_of_two():
    h = Histogram("wide", lo_exp=-20, hi_exp=4)
    for i, e in enumerate(range(-20, 5)):
        v = 2.0 ** e
        assert h.bucket_index(v) == i, f"2**{e} landed off its edge"
        assert h.bucket_index(v * (1 + 1e-9)) == i + 1


def test_histogram_observe_count_sum_cumulative():
    h = Histogram("lat", lo_exp=0, hi_exp=2)    # edges 1, 2, 4, +Inf
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.count() == 4
    assert h.sum() == pytest.approx(105.0)
    assert h.buckets() == [(1.0, 1), (2.0, 2), (4.0, 3), (float("inf"), 4)]


def test_registry_get_or_create_and_kind_mismatch():
    r = Registry()
    a = r.counter("x_total", labels=("k",))
    b = r.counter("x_total", labels=("k",))
    assert a is b                               # shared by name
    with pytest.raises(ValueError):             # same name, different kind
        r.gauge("x_total")
    with pytest.raises(ValueError):             # same kind, other labels
        r.counter("x_total", labels=("other",))


def test_registry_snapshot_and_prometheus():
    r = Registry()
    r.counter("ticks_total", "ticks", labels=("kind",)).inc(3, kind="mixed")
    r.gauge("depth", "queue").set(2)
    h = r.histogram("gap_seconds", "itl", lo_exp=-2, hi_exp=0)
    h.observe(0.3)
    h.observe(0.9)
    snap = r.snapshot()
    assert snap['ticks_total{kind="mixed"}'] == 3
    assert snap["depth"] == 2
    assert snap["gap_seconds_count"] == 2
    assert snap["gap_seconds_sum"] == pytest.approx(1.2)
    assert snap['gap_seconds_bucket{le="+Inf"}'] == 2
    prom = r.prometheus()
    assert "# TYPE ticks_total counter" in prom
    assert 'ticks_total{kind="mixed"} 3' in prom
    assert "# TYPE gap_seconds histogram" in prom
    assert 'gap_seconds_bucket{le="0.5"} 1' in prom
    assert 'gap_seconds_bucket{le="+Inf"} 2' in prom
    assert "gap_seconds_count 2" in prom
    # json round-trips the snapshot
    assert json.loads(r.json_dump()) == snap


def test_prometheus_escapes_hostile_label_values():
    """Exposition escaping: a label value carrying backslashes, quotes,
    or newlines must not corrupt the scrape document."""
    r = Registry()
    c = r.counter("errors_total", "why", labels=("msg",))
    c.inc(msg='path "C:\\tmp"\nsecond line')
    prom = r.prometheus()
    # one sample line (the newline is escaped, not emitted raw)...
    samples = [ln for ln in prom.splitlines() if not ln.startswith("#")]
    assert len(samples) == 1
    # ...with the exposition-format escapes, backslash escaped first
    assert ('errors_total{msg="path \\"C:\\\\tmp\\"\\nsecond line"} 1'
            == samples[0])
    # HELP text escapes backslash + newline too
    r2 = Registry()
    r2.counter("x_total", "line one\nline \\two").inc()
    help_line = r2.prometheus().splitlines()[0]
    assert help_line == "# HELP x_total line one\\nline \\\\two"


def test_merged_prometheus_one_header_per_shared_family():
    """Registries sharing a metric family merge under a single
    HELP/TYPE header — Prometheus rejects duplicate family headers."""
    from repro.obs import merged_prometheus
    a, b = Registry(), Registry()
    a.counter("shared_total", "shared fam", labels=("src",)).inc(src="a")
    b.counter("shared_total", "shared fam", labels=("src",)).inc(2, src="b")
    a.gauge("only_a").set(1)
    b.gauge("only_b").set(2)
    prom = merged_prometheus(a, b)
    lines = prom.splitlines()
    assert lines.count("# TYPE shared_total counter") == 1
    assert lines.count("# HELP shared_total shared fam") == 1
    # both registries' series survive the merge
    assert 'shared_total{src="a"} 1' in lines
    assert 'shared_total{src="b"} 2' in lines
    assert "only_a 1" in lines and "only_b 2" in lines
    # a name that changes kind across registries is a schema bug
    c = Registry()
    c.gauge("shared_total")
    with pytest.raises(ValueError, match="one family name, one type"):
        merged_prometheus(a, c)


# --------------------------------------------------------------------------
# tracer + chrome-trace schema
# --------------------------------------------------------------------------

def _fake_clock(step_s=0.001):
    t = [0.0]

    def clock():
        t[0] += step_s
        return t[0]

    return clock


def test_tracer_spans_nest_and_validate():
    tr = Tracer(clock=_fake_clock())
    tr.thread_name(1, "slot 0")
    with tr.span("tick", tid=0):
        with tr.span("device step", tid=0):
            tr.instant("mark", tid=1, rid=7)
    events = validate_chrome_trace(tr.chrome_trace())
    names = [e["name"] for e in events]
    assert "process_name" in names and "thread_name" in names
    spans = [e for e in events if e["ph"] == "X"]
    # emitted on exit: child first, and strictly inside the parent
    assert [e["name"] for e in spans] == ["device step", "tick"]
    child, parent = spans
    assert parent["ts"] <= child["ts"]
    assert child["ts"] + child["dur"] <= parent["ts"] + parent["dur"]
    instant = next(e for e in events if e["ph"] == "i")
    assert instant["args"] == {"rid": 7}


def test_tracer_ring_buffer_bounded_keeps_meta():
    tr = Tracer(clock=_fake_clock(), max_events=4)
    tr.thread_name(1, "slot 0")
    for i in range(10):
        tr.instant(f"e{i}")
    trace = tr.chrome_trace()
    non_meta = [e for e in trace["traceEvents"] if e["ph"] != "M"]
    assert len(non_meta) == 4                       # oldest evicted
    assert [e["name"] for e in non_meta] == ["e6", "e7", "e8", "e9"]
    meta = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    assert len(meta) == 2                           # process + thread names


def test_validate_rejects_missing_fields_and_overlap():
    with pytest.raises(ValueError, match="missing required field"):
        validate_chrome_trace([{"ph": "i", "ts": 0, "pid": 0, "tid": 0}])
    with pytest.raises(ValueError, match="needs dur"):
        validate_chrome_trace(
            [{"ph": "X", "ts": 0, "pid": 0, "tid": 0, "name": "x"}])
    overlap = [
        {"ph": "X", "ts": 0, "dur": 10, "pid": 0, "tid": 0, "name": "a"},
        {"ph": "X", "ts": 5, "dur": 10, "pid": 0, "tid": 0, "name": "b"},
    ]
    with pytest.raises(ValueError, match="must nest"):
        validate_chrome_trace(overlap)
    # same intervals on different tracks are fine
    overlap[1]["tid"] = 1
    validate_chrome_trace(overlap)


def test_validate_rejects_malformed_event_shapes():
    """Non-object events and non-numeric timestamps get actionable
    errors, not KeyError/TypeError."""
    with pytest.raises(ValueError, match="not a trace-event object"):
        validate_chrome_trace([["ph", "i"]])
    with pytest.raises(ValueError, match="ts must be a number"):
        validate_chrome_trace(
            [{"ph": "i", "ts": "0", "pid": 0, "tid": 0, "name": "x"}])
    with pytest.raises(ValueError, match="needs dur"):
        validate_chrome_trace(
            [{"ph": "X", "ts": 0, "dur": "5", "pid": 0, "tid": 0,
              "name": "x"}])
    with pytest.raises(ValueError, match="needs dur"):
        validate_chrome_trace(
            [{"ph": "X", "ts": 0, "dur": -1, "pid": 0, "tid": 0,
              "name": "x"}])


def test_validate_counter_events():
    """C events need a non-empty dict of numeric series; a valid counter
    mixed with instants and spans passes."""
    base = {"ph": "C", "ts": 1, "pid": 0, "tid": 0, "name": "pool"}
    with pytest.raises(ValueError, match="non-empty args dict"):
        validate_chrome_trace([dict(base)])                 # args missing
    with pytest.raises(ValueError, match="non-empty args dict"):
        validate_chrome_trace([dict(base, args={})])        # args empty
    with pytest.raises(ValueError, match="must be numeric"):
        validate_chrome_trace([dict(base, args={"free": "3"})])
    with pytest.raises(ValueError, match="must be numeric"):
        validate_chrome_trace([dict(base, args={"free": True})])
    mixed = [
        {"ph": "X", "ts": 0, "dur": 10, "pid": 0, "tid": 0, "name": "tick"},
        {"ph": "i", "ts": 2, "pid": 0, "tid": 0, "name": "admit", "s": "t"},
        dict(base, args={"free": 3, "used": 2.5}),
        {"ph": "X", "ts": 4, "dur": 2, "pid": 0, "tid": 0, "name": "plan"},
    ]
    assert len(validate_chrome_trace(mixed)) == 4


def test_validate_ring_evicted_parent_still_nests():
    """A ring buffer evicts children before parents (spans are emitted
    on exit), so an orphaned tail of the stream must still validate."""
    tr = Tracer(clock=_fake_clock(), max_events=3)
    with tr.span("tick"):
        with tr.span("plan"):
            pass
        with tr.span("device step"):
            pass
        with tr.span("commit"):
            pass
    # 4 spans through a 3-slot ring: "plan" (oldest child) evicted, the
    # surviving suffix has "tick" without one of its children
    events = [e for e in tr.chrome_trace()["traceEvents"] if e["ph"] == "X"]
    assert len(events) == 3 and events[-1]["name"] == "tick"
    validate_chrome_trace(tr.chrome_trace())


# --------------------------------------------------------------------------
# _percentile nearest-rank edges + summary schema pin
# --------------------------------------------------------------------------

def test_percentile_nearest_rank_edges():
    assert _percentile([42.0], 0.0) == 42.0         # len-1: any q
    assert _percentile([42.0], 0.5) == 42.0
    assert _percentile([42.0], 1.0) == 42.0
    vals = [1.0, 2.0, 3.0, 4.0]
    assert _percentile(vals, 0.25) == 1.0           # exact boundary q
    assert _percentile(vals, 0.5) == 2.0
    assert _percentile(vals, 0.75) == 3.0
    assert _percentile(vals, 1.0) == 4.0
    assert _percentile(vals, 0.51) == 3.0           # just past a boundary
    assert _percentile([4.0, 1.0, 3.0, 2.0], 0.5) == 2.0   # unsorted input


def test_engine_stats_summary_schema_pinned():
    """summary() keys predate the registry refactor — pinned verbatim."""
    st = serve.EngineStats(2)
    st.record_step("prefill", 1, 0, 0.01,
                   prefill_tokens=[4, 0], decode_tokens=[0, 0])
    st.record_step("mixed", 2, 2, 0.01,
                   prefill_tokens=[0, 3], decode_tokens=[1, 0],
                   proposed=2, accepted=1)
    st.record_token_gap(0.005)
    rm = serve.RequestMetrics(request_id=0, prompt_len=4, submit_time=0.0,
                              first_token_time=0.01, last_token_time=0.02,
                              finish_time=0.02)
    rm.new_tokens = 2
    st.record_finish(rm)
    s = st.summary()
    assert set(s) == {
        "requests", "steps", "prefill_steps", "decode_steps", "mixed_steps",
        "new_tokens", "prompt_tokens", "prefill_tokens_fed",
        "decode_tokens_fed", "elapsed_s", "tok_per_s", "tokens_per_step",
        "mean_occupancy", "spec_proposed", "spec_accepted",
        "spec_accept_rate", "ttft_mean_s", "ttft_p95_s",
        "itl_p50_s", "itl_p95_s", "itl_mean_s"}
    # the legacy attribute API reads through the registry
    assert st.steps == 2 and st.prefill_steps == 1 and st.mixed_steps == 1
    assert st.slot_prefill_tokens == [4, 3]
    assert st.slot_decode_tokens == [1, 0]
    assert s["prefill_tokens_fed"] == 7.0 and s["decode_tokens_fed"] == 1.0
    assert s["spec_accept_rate"] == 0.5
    # prometheus export carries the same numbers
    prom = st.registry.prometheus()
    assert 'serve_steps_total{kind="mixed"} 1' in prom
    assert 'serve_slot_tokens_total{slot="0",kind="prefill"} 4' in prom
    assert "serve_itl_seconds_count 1" in prom
    # a fresh instance is fully reset (the bench's warmup discard)
    assert serve.EngineStats(2).steps == 0


# --------------------------------------------------------------------------
# engine: trace schema on a real drive, timing, no-progress, zero syncs
# --------------------------------------------------------------------------

@pytest.mark.serve
def test_engine_trace_schema_and_registry_exports(params):
    """Fast trace-schema check: a tiny drive, no bench run needed."""
    tracer = Tracer()
    engine = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                               page_size=16, chunk_size=16, tracer=tracer)
    for p in ragged_prompts(3):
        engine.submit(p, max_new=4)
    results = engine.drain()
    assert len(results) == 3
    events = validate_chrome_trace(tracer.chrome_trace())
    names = {e["name"] for e in events}
    for want in ("submit", "admit", "plan", "device step", "host sync",
                 "commit", "tick", "prefill", "decode", "retire"):
        assert want in names, f"lifecycle event {want!r} missing"
    # slot spans live on per-slot tracks, engine phases on tid 0
    assert {e["tid"] for e in events if e["name"] == "decode"} <= {1, 2}
    assert {e["tid"] for e in events if e["name"] == "tick"} == {0}
    # registry exports: queue drained, pages back in the pool, peak kept
    snap = engine.metrics_snapshot()
    assert snap["serve_queue_depth"] == 0
    assert snap["serve_busy_slots"] == 0
    assert snap["serve_pages_used"] == 0
    assert snap["serve_pages_used_peak"] > 0
    assert snap["serve_admissions_total"] == 3
    assert snap["serve_requests_finished_total"] == 3
    prom = engine.prometheus()
    assert "serve_queue_depth" in prom and "serve_steps_total" in prom


@pytest.mark.serve
def test_prefix_cache_metrics_schema_pinned(params):
    """Sharing-layer observability: the hit/miss/COW counters and the
    shared/cached page gauges exist from tick zero (zero-valued series,
    not absent) and export in both the snapshot and Prometheus text."""
    engine = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                               page_size=16, chunk_size=16,
                               prefix_cache=True)
    # pinned at construction, before any traffic: a dashboard must see
    # the series immediately, not after the first hit
    snap = engine.metrics_snapshot()
    for name in ("serve_prefix_hits_total", "serve_prefix_miss_total",
                 "serve_cow_copies_total"):
        assert snap[name] == 0
    assert snap["serve_pages_shared"] == 0
    assert snap["serve_pages_cached"] == 0
    prompt = list(range(1, 33))              # 2 full pages
    engine.submit(prompt, max_new=4)
    engine.drain()
    engine.submit(list(prompt), max_new=4)   # identical: full-page hit
    engine.drain()
    snap = engine.metrics_snapshot()
    assert snap["serve_prefix_hits_total"] == 2
    assert snap["serve_prefix_miss_total"] >= 1
    assert snap["serve_cow_copies_total"] == 1    # the boundary COW
    assert snap["serve_pages_cached"] > 0         # parked after retire
    prom = engine.prometheus()
    for name in ("serve_prefix_hits_total", "serve_prefix_miss_total",
                 "serve_cow_copies_total", "serve_pages_shared",
                 "serve_pages_cached"):
        assert name in prom, f"{name} missing from Prometheus export"
    # the per-request view: cached_prefix_tokens rides RequestMetrics
    res = engine.drain()
    assert res[1].metrics.cached_prefix_tokens == len(prompt) - 1


@pytest.mark.serve
def test_engine_step_transfers_exactly_two_arrays(monkeypatch, params,
                                                  tmp_path):
    """Zero added device syncs: with tracer and/or journal enabled, one
    engine step crosses device->host exactly twice (the (B,) accept and
    token arrays the verifier always produces)."""
    from repro.obs import JournalRecorder
    import repro.serve.engine as eng

    class CountingNp:
        def __init__(self, real):
            self._real = real
            self.asarray_calls = 0

        def __getattr__(self, name):
            return getattr(self._real, name)

        def asarray(self, *a, **k):
            self.asarray_calls += 1
            return self._real.asarray(*a, **k)

    proxy = CountingNp(np)
    monkeypatch.setattr(eng, "np", proxy)
    counts = {}
    variants = (
        ("off", None, None),
        ("tracer", Tracer(), None),
        ("journal", None, JournalRecorder(str(tmp_path / "pin.jsonl"))),
    )
    for label, tracer, journal in variants:
        engine = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                                   page_size=16, chunk_size=16,
                                   tracer=tracer, journal=journal)
        engine.submit([1, 2, 3], max_new=3)
        per_step = []
        while engine.scheduler.has_work:
            before = proxy.asarray_calls
            engine.step()
            per_step.append(proxy.asarray_calls - before)
        counts[label] = per_step
        if journal is not None:
            journal.close()
        assert all(n == 2 for n in per_step), (label, per_step)
    assert counts["tracer"] == counts["off"]
    assert counts["journal"] == counts["off"]


def test_no_blocking_sync_in_serve_hot_path_sources():
    """block_until_ready must not appear in the serving hot path — the
    only intentional transfer points are the two np.asarray calls in
    engine.step() (counted above)."""
    import repro.serve.cache
    import repro.serve.engine
    import repro.serve.metrics
    import repro.serve.scheduler
    for mod in (repro.serve.engine, repro.serve.scheduler,
                repro.serve.cache, repro.serve.metrics):
        assert "block_until_ready" not in inspect.getsource(mod), mod


@pytest.mark.serve
def test_step_elapsed_covers_full_tick(params):
    """Regression: EngineStats.elapsed must cover admit through commit.
    Slow both phases down; the recorded elapsed must absorb the delays
    and stay ~= the drain() wall time."""
    engine = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                               page_size=16, chunk_size=16)
    engine.submit([1, 2, 3], max_new=2)          # warm the compiled step
    engine.drain()
    engine.stats = serve.EngineStats(2)

    delay = 0.005
    real_admit, real_commit = engine.scheduler.admit, engine.scheduler.commit

    def slow_admit(*a, **k):
        time.sleep(delay)
        return real_admit(*a, **k)

    def slow_commit(*a, **k):
        time.sleep(delay)
        return real_commit(*a, **k)

    engine.scheduler.admit = slow_admit
    engine.scheduler.commit = slow_commit
    for p in ragged_prompts(3):
        engine.submit(p, max_new=4)
    t0 = time.perf_counter()
    engine.drain()
    wall = time.perf_counter() - t0
    st = engine.stats
    assert st.steps > 0
    # each recorded tick ran one slowed admit and one slowed commit; the
    # pre-fix timing (t0 after admit, stop before commit) missed both
    assert st.elapsed >= st.steps * 2 * delay * 0.95
    assert st.elapsed <= wall * 1.01
    assert st.elapsed >= 0.6 * wall


@pytest.mark.serve
def test_drain_no_progress_guard_names_stuck_requests(params):
    """A request too large for the pool that bypassed submit() validation
    must raise an actionable error, not spin drain() forever."""
    engine = serve.ServeEngine(CFG, params, n_slots=2, max_seq=32,
                               page_size=16)
    # bypass submit()'s pool-fit validation: enqueue directly
    engine.scheduler.waiting.append(Request(99, [1] * 8, max_new=1000))
    with pytest.raises(RuntimeError, match=r"no progress.*\[99\]"):
        engine.drain()


# --------------------------------------------------------------------------
# precision telemetry: §3.3 trajectory + in-jit per-layer summaries
# --------------------------------------------------------------------------

def test_precision_stats_trajectory_halve_and_double():
    scaling = DynamicLossScaling(2.0 ** 15, period=2)
    ps = PrecisionStats()
    ps.record_scaling(0, scaling)
    scaling = scaling.adjust(jnp.asarray(False))       # overflow -> halve
    ps.record_scaling(1, scaling, grads_finite=False)
    for step in (2, 3):                                # period=2 -> double
        scaling = scaling.adjust(jnp.asarray(True))
        ps.record_scaling(step, scaling)
    assert ps.steps == 4
    assert ps.overflow_steps == 1
    assert ps.scale_halvings == 1
    assert ps.scale_doublings == 1
    snap = ps.snapshot()
    assert snap['train_loss_scale_events_total{event="halved"}'] == 1
    assert snap['train_loss_scale_events_total{event="doubled"}'] == 1
    traj = snap["loss_scale_trajectory"]
    assert [s for s, _ in traj] == [0, 1, 2, 3]
    assert traj[1][1] == traj[0][1] / 2                # the halving
    assert traj[3][1] == traj[1][1] * 2                # the recovery
    assert snap["train_loss_scale"] == traj[-1][1]


def test_fp16_overflow_halving_observable_in_snapshot():
    """End to end at fp16: a deliberately oversized scale overflows the
    gradients, the controller halves, the skip is counted — the
    quickstart's observable §3.3 loop, in miniature."""
    mpx.set_half_dtype(jnp.float16)
    try:
        w = {"w": jnp.ones((8, 8), jnp.float32)}
        batch = {"x": jnp.full((4, 8), 3.0), "y": jnp.zeros((4, 8))}

        def loss_fn(m, b):
            pred = b["x"] @ m["w"]
            return mpx.force_full_precision(jnp.mean)((pred - b["y"]) ** 2)

        scaling = mpx.DynamicLossScaling(2.0 ** 24, period=100)
        ps = PrecisionStats()
        ps.record_scaling(0, scaling)
        for step in range(3):
            scaling, finite, _ = mpx.filter_grad(loss_fn, scaling)(w, batch)
            ps.record_scaling(step + 1, scaling, bool(finite))
        assert ps.overflow_steps >= 1
        assert ps.scale_halvings >= 1
        snap = ps.snapshot()
        assert snap['train_loss_scale_events_total{event="halved"}'] >= 1
        traj = snap["loss_scale_trajectory"]
        assert traj[-1][1] < traj[0][1]
    finally:
        mpx.set_half_dtype(jnp.bfloat16)


def test_per_layer_grad_summary_values_in_jit():
    grads = {"a": jnp.asarray([1.0, -4.0, 0.0, jnp.inf]),
             "b": jnp.asarray([2.0 ** -20, 1.0]),
             "c": jnp.asarray([1, 2], jnp.int32)}      # int leaf excluded
    names = grad_layer_names(grads)
    assert names == ["a", "b"]
    out = jax.jit(per_layer_grad_summary)(grads)
    amax = np.asarray(out["grad_amax_per_layer"])
    nonf = np.asarray(out["grad_nonfinite_frac_per_layer"])
    under = np.asarray(out["grad_underflow_frac_per_layer"])
    assert amax.shape == nonf.shape == under.shape == (2,)
    assert np.isinf(amax[0]) and amax[1] == 1.0
    assert nonf[0] == pytest.approx(0.25) and nonf[1] == 0.0
    # leaf b: two nonzero elements, one below fp16's smallest normal
    assert 2.0 ** -20 < FP16_TINY
    assert under[0] == 0.0 and under[1] == pytest.approx(0.5)


def test_per_layer_summary_handles_all_zero_leaf():
    out = per_layer_grad_summary({"z": jnp.zeros(4)})
    assert float(out["grad_underflow_frac_per_layer"][0]) == 0.0  # not NaN
    assert float(out["grad_amax_per_layer"][0]) == 0.0


def test_record_layer_summary_exports_labeled_gauges():
    ps = PrecisionStats()
    ps.record_layer_summary(
        ["l0", "l1"],
        {"grad_amax_per_layer": np.asarray([0.5, 2.0]),
         "grad_underflow_frac_per_layer": np.asarray([0.0, 0.25])})
    snap = ps.snapshot()
    assert snap["grad_layer_names"] == ["l0", "l1"]
    assert snap['grad_amax{layer="l1"}'] == 2.0
    assert snap['grad_underflow_frac{layer="l1"}'] == 0.25
    assert snap["grad_amax_per_layer"] == [0.5, 2.0]
    with pytest.raises(ValueError, match="layer names"):
        ps.record_layer_summary(["l0"], {"grad_amax_per_layer": [1.0, 2.0]})


def test_train_step_grad_stats_rides_metrics_dict():
    """grad_stats=True adds fixed-shape (L,) arrays to the jitted step's
    metrics — no host callback, same compiled program shape."""
    from repro.optim import adamw
    from repro.train.steps import make_train_step

    run = RunConfig(policy="p=f32,c=f32,o=f32", zero1=False,
                    master_weights="none")

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"]) ** 2), {}

    optimizer = adamw(learning_rate=1e-2)
    params_tree = {"w": jnp.ones((4, 4)) * 0.1}
    state = {"params": params_tree,
             "opt_state": optimizer.init(params_tree),
             "scaling": DynamicLossScaling(2.0 ** 10),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(make_train_step(CFG, run, optimizer, loss_fn=loss_fn,
                                      grad_stats=True))
    batch = {"x": jnp.ones((2, 4))}
    _, metrics = step_fn(state, batch)
    names = grad_layer_names(params_tree)
    for key in ("grad_amax_per_layer", "grad_nonfinite_frac_per_layer",
                "grad_underflow_frac_per_layer"):
        assert metrics[key].shape == (len(names),)
    assert float(metrics["grad_nonfinite_frac_per_layer"][0]) == 0.0
    assert float(metrics["grad_amax_per_layer"][0]) > 0.0


def test_serving_obs_overhead_row_registered():
    """The bench's tracing-overhead row is part of the pinned schema."""
    from benchmarks.serving_bench import expected_row_names
    assert "serving_obs_overhead_pct" in expected_row_names()


def test_serving_journal_overhead_row_registered():
    """The flight-recorder overhead row is part of the pinned schema."""
    from benchmarks.serving_bench import expected_row_names
    assert "serving_journal_overhead_pct" in expected_row_names()


@pytest.mark.serve
def test_request_phase_histograms_exported(params):
    """A drive populates the per-request phase histograms
    (queue wait / prefill / decode) in the engine's registry."""
    engine = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                               page_size=16, chunk_size=16)
    for p in ragged_prompts(3):
        engine.submit(p, max_new=4)
    results = engine.drain()
    assert len(results) == 3
    prom = engine.stats.registry.prometheus()
    for fam in ("serve_queue_wait_seconds", "serve_prefill_seconds",
                "serve_decode_seconds"):
        assert f"# TYPE {fam} histogram" in prom
        assert f"{fam}_count 3" in prom
    # phases decompose: queue_wait + prefill + decode <= total latency
    for r in results:
        m = r.metrics
        assert m.queue_wait >= 0.0
        assert m.prefill_seconds >= 0.0
        assert m.decode_seconds >= 0.0
        total = m.finish_time - m.submit_time
        assert (m.queue_wait + m.prefill_seconds + m.decode_seconds
                <= total + 1e-9)
