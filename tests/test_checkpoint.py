"""Checkpointer: atomicity, GC, bit-exact resume, elastic reshard."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro import mpx


def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "scaling": mpx.DynamicLossScaling(512.0, period=5),
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep_n=2)
    tree = _tree()
    ck.save(7, tree, extra={"data": {"step": 3}})
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tree)
    restored, extra = ck.restore(abstract)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert isinstance(restored["scaling"], mpx.DynamicLossScaling)
    assert float(restored["scaling"].loss_scaling) == 512.0
    assert restored["scaling"].period == 5      # static aux preserved
    assert extra["data"]["step"] == 3


def test_latest_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep_n=2)
    for s in (1, 2, 3):
        ck.save(s, _tree())
    assert ck.latest_step() == 3
    dirs = sorted(p.name for p in tmp_path.iterdir() if p.is_dir())
    assert dirs == ["step_000000002", "step_000000003"]


def test_async_save(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save_async(1, _tree())
    ck.wait()
    assert ck.latest_step() == 1


def test_leaf_count_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": jnp.ones(3)})
    with pytest.raises(ValueError, match="leaves"):
        ck.restore({"w": jax.ShapeDtypeStruct((3,), jnp.float32),
                    "extra": jax.ShapeDtypeStruct((2,), jnp.float32)})


def test_trainer_resume_bit_exact(tmp_path):
    """20 straight steps == 10 steps + checkpoint + resume + 10 steps."""
    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.data.pipeline import SyntheticTokens
    from repro.optim import make_optimizer
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = registry.get_smoke_config("llama3-8b")
    run = RunConfig(learning_rate=1e-3)

    def make_trainer(steps, ckdir):
        return Trainer(cfg, run, make_optimizer(run),
                       SyntheticTokens(cfg, batch=4, seq=16, seed=3),
                       TrainerConfig(total_steps=steps, ckpt_dir=ckdir,
                                     ckpt_every=10, log_every=0,
                                     prefetch=0))

    t_straight = make_trainer(20, str(tmp_path / "a"))
    t_straight.fit()
    w_straight = np.asarray(jax.tree.leaves(t_straight.state["params"])[0])

    t1 = make_trainer(10, str(tmp_path / "b"))
    t1.fit()
    t2 = make_trainer(20, str(tmp_path / "b"))     # resumes at 10
    assert int(t2.state["step"]) == 10
    t2.fit()
    w_resumed = np.asarray(jax.tree.leaves(t2.state["params"])[0])
    np.testing.assert_array_equal(w_straight, w_resumed)


_ELASTIC_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((%d, %d), ("data", "model"))
    ck = Checkpointer(sys.argv[1])
    tree_abs = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    if sys.argv[2] == "save":
        w = jnp.arange(128.0).reshape(8, 16)
        w = jax.device_put(w, sh["w"])
        ck.save(1, {"w": w})
    else:
        tree, _ = ck.restore(tree_abs, shardings=sh)
        assert tree["w"].sharding.num_devices == %d
        np.testing.assert_array_equal(np.asarray(tree["w"]),
                                      np.arange(128.0).reshape(8, 16))
        print("ELASTIC_OK")
""")


def test_elastic_reshard(tmp_path):
    """Save on an 8-device mesh, restore onto a 4-device mesh."""
    env = dict(os.environ, PYTHONPATH="src")
    r1 = subprocess.run(
        [sys.executable, "-c", _ELASTIC_SCRIPT % (8, 4, 2, 8),
         str(tmp_path), "save"],
        capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert r1.returncode == 0, r1.stderr
    r2 = subprocess.run(
        [sys.executable, "-c", _ELASTIC_SCRIPT % (4, 2, 2, 4),
         str(tmp_path), "load"],
        capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert r2.returncode == 0, r2.stderr
    assert "ELASTIC_OK" in r2.stdout
