"""repro.quant: formats, quantize/dequantize ops vs loop references,
the policy kv= component, and the serving-bench artifact schema."""
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import mpx, quant
from repro.quant import ops as qops
from repro.quant import reference as qref

QUANT_FORMATS = ("i8", "f8_e4m3", "f8_e3m4")

#: worst-case round-trip error of one value under amax scaling: int8 is a
#: uniform grid (half a step = scale/2); fp8 rounds to 2^-(mantissa+1)
#: RELATIVE error, so the bound scales with |x| (plus a scale-sized floor
#: for the subnormal range).
_MANTISSA = {"f8_e4m3": 3, "f8_e3m4": 4}


def _roundtrip_bound(x, scale, fmt):
    if fmt.kind == "int":
        return np.full_like(x, scale * 0.5 + 1e-7)
    return np.abs(x) * 2.0 ** -(_MANTISSA[fmt.name] + 1) + scale


# --------------------------------------------------------------------------
# formats
# --------------------------------------------------------------------------

def test_format_registry_and_aliases():
    assert quant.resolve("i8") is quant.I8
    assert quant.resolve("int8") is quant.I8
    assert quant.resolve("fp8") is quant.F8_E4M3
    assert quant.resolve("e3m4") is quant.F8_E3M4
    assert quant.resolve(None) is quant.BF16
    assert quant.resolve(quant.I8) is quant.I8
    assert not quant.BF16.quantized and quant.I8.quantized
    assert quant.I8.itemsize == 1 and quant.BF16.itemsize == 2
    with pytest.raises(ValueError, match="unknown KV format"):
        quant.resolve("i4")


def test_storage_dtype_fp8_emulates_in_bf16_off_tpu():
    """Off-TPU the fp8 pools store in bf16 — exactly, because every fp8
    value is representable in bf16 (the emulation contract)."""
    assert quant.I8.storage_dtype("cpu") == jnp.int8
    assert quant.F8_E4M3.storage_dtype("cpu") == jnp.bfloat16
    assert quant.F8_E4M3.storage_dtype("tpu") == jnp.float8_e4m3fn
    assert quant.F8_E3M4.storage_dtype("tpu") == jnp.float8_e3m4
    x = jax.random.normal(jax.random.key(0), (4096,), jnp.float32) * 40
    for fmt in (quant.F8_E4M3, quant.F8_E3M4):
        scale = float(qops.amax_scale(x, fmt, axes=0))
        native = (x / scale).astype(fmt.grid_dtype).astype(jnp.float32)
        emulated = qops.quantize(x, scale, fmt).astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(native),
                                      np.asarray(emulated))


def test_pool_spec_container_layout():
    spec = quant.pool_spec(12, 16, 4, 32, "bf16")
    assert set(spec) == {"k", "v"}
    assert spec["k"].shape == (12, 16, 4, 32)
    assert spec["k"].dtype == jnp.bfloat16
    spec = quant.pool_spec(12, 16, 4, 32, "i8")
    assert set(spec) == {"k", "v", "k_scale", "v_scale"}
    assert spec["k"].dtype == jnp.int8
    assert spec["k_scale"].shape == (12, 4)
    assert spec["k_scale"].dtype == jnp.float32


def test_max_write_pages():
    # a C-token contiguous range straddles at most (C-1)//ps + 2 pages
    assert qops.max_write_pages(1, 16, 8) == 2
    assert qops.max_write_pages(16, 16, 8) == 2
    assert qops.max_write_pages(17, 16, 8) == 3
    assert qops.max_write_pages(64, 16, 2) == 2     # clamped to pmax


# --------------------------------------------------------------------------
# quantize / dequantize
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fmt_name", QUANT_FORMATS)
def test_quantize_matches_numpy_reference(fmt_name):
    fmt = quant.resolve(fmt_name)
    x = jax.random.normal(jax.random.key(1), (512,), jnp.float32) * 7
    scale = float(qops.amax_scale(x, fmt, axes=0))
    got = np.asarray(qops.quantize(x, scale, fmt).astype(jnp.float32))
    want = np.asarray(qref.quantize_ref(np.asarray(x), scale, fmt),
                      np.float32)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fmt_name", QUANT_FORMATS)
def test_roundtrip_error_bound(fmt_name):
    fmt = quant.resolve(fmt_name)
    x = np.asarray(jax.random.normal(jax.random.key(2), (4096,),
                                     jnp.float32) * 3)
    scale = max(np.abs(x).max() / fmt.fmax, qops.SCALE_FLOOR)
    deq = np.asarray(qops.dequantize(qops.quantize(jnp.asarray(x), scale,
                                                   fmt), scale))
    err = np.abs(deq - x)
    assert (err <= _roundtrip_bound(x, scale, fmt)).all()
    # zeros survive exactly, whatever the scale floor does
    z = qops.dequantize(qops.quantize(jnp.zeros(8), 1.0, fmt), 1.0)
    assert (np.asarray(z) == 0).all()


def test_quantize_rejects_passthrough():
    with pytest.raises(ValueError, match="passthrough"):
        qops.quantize(jnp.ones(4), 1.0, "bf16")


# --------------------------------------------------------------------------
# quantized paged write (write-quantize contract)
# --------------------------------------------------------------------------

def _write_case(fmt, seed=0):
    """Mixed batch: a page-straddling prefill chunk into a partially
    pre-populated page, a single decode token, an idle slot."""
    rng = np.random.default_rng(seed)
    P, ps, K, D = 10, 8, 2, 4
    B, C, pmax = 3, 6, 4
    table = np.full((B, pmax), P, np.int32)
    table[0, :3] = [2, 5, 7]
    table[1, :2] = [1, 9]
    start = np.array([5, 9, 0], np.int32)
    valid = np.array([6, 1, 0], np.int32)
    positions = start[:, None] + np.arange(C)[None, :]
    vals = jnp.asarray(rng.normal(size=(B, C, K, D)), jnp.bfloat16)

    pages = jnp.zeros((P, ps, K, D), fmt.storage_dtype())
    scales = jnp.full((P, K), qops.SCALE_FLOOR, jnp.float32)
    # pre-populate slot 0's first written page with quantized content
    pre = jnp.asarray(rng.normal(size=(ps, K, D)), jnp.float32)
    s_pre = qops.amax_scale(pre, fmt, axes=(0, 2))
    pages = pages.at[2].set(qops.quantize(pre, s_pre[None, :, None], fmt))
    scales = scales.at[2].set(s_pre)
    return (pages, scales, vals, jnp.asarray(table), jnp.asarray(positions),
            jnp.asarray(valid), ps, table, positions, valid)


@pytest.mark.parametrize("fmt_name", QUANT_FORMATS)
def test_quantized_paged_write_matches_loop_reference(fmt_name):
    fmt = quant.resolve(fmt_name)
    (pages, scales, vals, table_j, pos_j, valid_j, ps,
     table, positions, valid) = _write_case(fmt)
    got_p, got_s = qops.quantized_paged_write(
        pages, scales, vals, table_j, pos_j, valid_j, page_size=ps, fmt=fmt)
    ref_p, ref_s = qref.quantized_paged_write_ref(
        pages, scales, np.asarray(vals.astype(jnp.float32)),
        table, positions, valid, page_size=ps, fmt=fmt)
    np.testing.assert_array_equal(
        np.asarray(got_p.astype(jnp.float32)), ref_p)
    np.testing.assert_array_equal(np.asarray(got_s), ref_s)


@pytest.mark.parametrize("fmt_name", QUANT_FORMATS)
def test_quantized_paged_write_untouched_pages_bitwise(fmt_name):
    """Only the pages the chunk touches may change — bits and scales of
    every other page are identical (requantization never leaks)."""
    fmt = quant.resolve(fmt_name)
    (pages, scales, vals, table_j, pos_j, valid_j, ps,
     table, positions, valid) = _write_case(fmt)
    got_p, got_s = qops.quantized_paged_write(
        pages, scales, vals, table_j, pos_j, valid_j, page_size=ps, fmt=fmt)
    touched = set()
    for s in range(len(valid)):
        for t in range(valid[s]):
            touched.add(int(table[s, positions[s, t] // ps]))
    for pg in range(pages.shape[0]):
        if pg in touched:
            continue
        np.testing.assert_array_equal(
            np.asarray(got_p[pg].astype(jnp.float32)),
            np.asarray(pages[pg].astype(jnp.float32)))
        np.testing.assert_array_equal(np.asarray(got_s[pg]),
                                      np.asarray(scales[pg]))
    # slot 0 writes positions 5..10 (phys 2 and 5), slot 1 position 9
    # (phys 9); phys 7 (slot 0's reserved-but-unwritten page) stays put
    assert touched == {2, 5, 9}


@pytest.mark.parametrize("fmt_name", QUANT_FORMATS)
def test_quantized_paged_write_incremental_decode_stability(fmt_name):
    """Token-by-token decode writes into one page (the serving access
    pattern): every previously written token stays within the round-trip
    bound of its original value after all the requantizations."""
    fmt = quant.resolve(fmt_name)
    rng = np.random.default_rng(3)
    P, ps, K, D = 4, 8, 2, 4
    table = jnp.asarray([[1, 3]], jnp.int32)
    pages = jnp.zeros((P, ps, K, D), fmt.storage_dtype())
    scales = jnp.full((P, K), qops.SCALE_FLOOR, jnp.float32)
    written = []
    for pos in range(2 * ps):
        val = rng.normal(size=(1, 1, K, D)).astype(np.float32)
        written.append(val[0, 0])
        pages, scales = qops.quantized_paged_write(
            pages, scales, jnp.asarray(val), table,
            jnp.asarray([[pos]], jnp.int32), jnp.asarray([1], jnp.int32),
            page_size=ps, fmt=fmt)
    deq = np.asarray(qops.dequantize(
        pages, np.asarray(scales)[:, None, :, None]))
    sc = np.asarray(scales)
    for pos, val in enumerate(written):
        phys = int(table[0, pos // ps])
        got = deq[phys, pos % ps]
        bound = _roundtrip_bound(val, sc[phys].max(), fmt)
        # a couple of requantizations may stack: allow 2x the one-shot
        # bound, still far below bf16 storage error for these magnitudes
        assert (np.abs(got - val) <= 2 * bound + 1e-6).all(), pos


@pytest.mark.parametrize("fmt_name", QUANT_FORMATS)
def test_quantized_paged_write_ignores_stale_prior_tenant(fmt_name):
    """retire() frees pages without clearing the device pool, so a
    reused page still holds the previous request's values at positions
    the new tenant hasn't written.  Those rows are unreachable (attention
    masks by position) — they must be zeroed out of the fresh amax, or a
    prior tenant's outliers would crush the new tenant's precision."""
    fmt = quant.resolve(fmt_name)
    P, ps, K, D = 4, 8, 2, 4
    # previous tenant left huge values (amax ~50) across page 1
    stale = jnp.full((ps, K, D), 50.0, jnp.float32)
    s_stale = qops.amax_scale(stale, fmt, axes=(0, 2))
    pages = jnp.zeros((P, ps, K, D), fmt.storage_dtype())
    pages = pages.at[1].set(qops.quantize(stale, s_stale[None, :, None],
                                          fmt))
    scales = jnp.full((P, K), qops.SCALE_FLOOR, jnp.float32)
    scales = scales.at[1].set(s_stale)
    # new tenant (amax ~0.5) writes its first token into the reused page
    table = jnp.asarray([[1]], jnp.int32)
    val = jnp.full((1, 1, K, D), 0.5, jnp.bfloat16)
    new_p, new_s = qops.quantized_paged_write(
        pages, scales, val, table, jnp.asarray([[0]], jnp.int32),
        jnp.asarray([1], jnp.int32), page_size=ps, fmt=fmt)
    # the fresh scale reflects ONLY the live row, not the stale 50s...
    assert float(np.asarray(new_s)[1].max()) <= 0.5 / fmt.fmax * 1.01
    deq = np.asarray(qops.dequantize(new_p,
                                     np.asarray(new_s)[:, None, :, None]))
    # ...so the live row round-trips accurately and the unreachable
    # rows are now exact zeros instead of the prior tenant's values
    assert np.abs(deq[1, 0] - 0.5).max() <= float(
        _roundtrip_bound(np.float32(0.5), float(np.asarray(new_s)[1].max()),
                         fmt)) + 1e-6
    assert (deq[1, 1:] == 0).all()


def test_quantized_paged_write_drops_sentinel_and_idle():
    fmt = quant.I8
    P, ps, K, D = 3, 4, 1, 2
    table = jnp.asarray([[P, P]], jnp.int32)        # nothing allocated
    pages = jnp.zeros((P, ps, K, D), jnp.int8)
    scales = jnp.zeros((P, K), jnp.float32)
    new_p, new_s = qops.quantized_paged_write(
        pages, scales, jnp.ones((1, 2, K, D), jnp.bfloat16), table,
        jnp.asarray([[0, 1]], jnp.int32), jnp.asarray([2], jnp.int32),
        page_size=ps, fmt=fmt)
    np.testing.assert_array_equal(np.asarray(new_p), np.asarray(pages))
    np.testing.assert_array_equal(np.asarray(new_s), np.asarray(scales))


# --------------------------------------------------------------------------
# policy kv= component
# --------------------------------------------------------------------------

def test_policy_parse_kv_component():
    p = mpx.Policy.parse("p=f32,c=bf16,o=bf16,kv=i8")
    assert p.kv_dtype == "i8"
    assert p.compute_dtype == jnp.bfloat16
    # canonicalized through the quant alias table
    assert mpx.Policy.parse("p=f32,c=bf16,o=f32,kv=int8").kv_dtype == "i8"
    assert mpx.Policy.parse("p=f32,c=bf16,o=f32,kv=fp8").kv_dtype \
        == "f8_e4m3"
    # default stays bf16 and pre-quant policy strings round-trip unchanged
    assert mpx.MIXED_BF16.kv_dtype == "bf16"
    assert "kv=" not in str(mpx.MIXED_BF16)
    assert str(p).endswith(",kv=i8")
    assert mpx.Policy.parse(str(p)) == p
    with pytest.raises(ValueError, match="unknown KV format"):
        mpx.Policy.parse("p=f32,c=bf16,o=f32,kv=i4")


# --------------------------------------------------------------------------
# serving-bench artifact schema (fast — imports the module, runs nothing)
# --------------------------------------------------------------------------

def _load_serving_bench():
    root = pathlib.Path(__file__).resolve().parents[1]
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    import importlib
    return importlib.import_module("benchmarks.serving_bench")


def test_serving_bench_artifact_schema_pinned():
    """The CI-uploaded trajectory keys on these row names: renames must
    update this pin deliberately, never silently."""
    sb = _load_serving_bench()
    names = sb.expected_row_names()
    assert len(names) == len(set(names))
    for required in [
        "serving_hbm_bytes_decode_kvbf16",
        "serving_hbm_bytes_decode_kvi8",
        "serving_hbm_bytes_decode_kvf8",
        "serving_tok_kvbf16", "serving_tok_kvi8", "serving_tok_kvf8",
        "serving_hbm_bytes_decode_gather", "serving_hbm_bytes_decode_paged",
        "serving_spec_accept_rate", "serving_spec_tokens_per_step",
    ]:
        assert required in names, required
    # check_rows accepts exactly the schema and rejects any drift
    rows = [(n, 1.0, "") for n in names]
    sb.check_rows(rows)
    with pytest.raises(RuntimeError, match="drifted"):
        sb.check_rows(rows[:-1])
    renamed = [("serving_tok_kv_i8" if n == "serving_tok_kvi8" else n,
                1.0, "") for n in names]
    with pytest.raises(RuntimeError, match="drifted"):
        sb.check_rows(renamed)


def test_serving_bench_kv_hbm_model_hits_acceptance_ratio():
    """ACCEPTANCE: serving_hbm_bytes_decode_kvi8 <= ~0.55x of the bf16 row
    at the bench shapes (int8 pools + fp32 scale sidecar)."""
    sb = _load_serving_bench()
    cfg = sb._bench_cfg()
    mean_len = 20.0
    bf16 = sb._hbm_bytes_per_decode_token_kv(cfg, mean_len, sb.CMP_PAGE,
                                             quant.BF16)
    i8 = sb._hbm_bytes_per_decode_token_kv(cfg, mean_len, sb.CMP_PAGE,
                                           quant.I8)
    f8 = sb._hbm_bytes_per_decode_token_kv(cfg, mean_len, sb.CMP_PAGE,
                                           quant.F8_E4M3)
    assert i8 / bf16 <= 0.55
    assert f8 / bf16 <= 0.55
    assert i8 / bf16 > 0.5          # the sidecar is accounted, not free
