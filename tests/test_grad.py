"""filter_grad / filter_value_and_grad / optimizer_update (paper §3.4–3.5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import mpx


def _loss(model, batch):
    pred = batch @ model["w"]
    return mpx.force_full_precision(jnp.mean)((pred - 1.0) ** 2)


def _setup():
    model = {"w": jnp.linspace(-1, 1, 8).reshape(4, 2), "name": "toy"}
    batch = jnp.arange(32, dtype=jnp.float32).reshape(8, 4) / 32.0
    return model, batch


def test_filter_grad_matches_fp32():
    model, batch = _setup()
    scaling = mpx.DynamicLossScaling(2.0 ** 12)
    new_s, finite, grads = mpx.filter_grad(_loss, scaling)(model, batch)
    assert bool(finite)
    assert grads["w"].dtype == jnp.float32
    gref = jax.grad(lambda m: _loss({**m, "name": "x"}, batch))(
        {"w": model["w"]})["w"]
    np.testing.assert_allclose(np.asarray(grads["w"]), np.asarray(gref),
                               rtol=0.05, atol=1e-4)


def test_value_and_grad_returns_unscaled_fp32_loss():
    model, batch = _setup()
    scaling = mpx.DynamicLossScaling(2.0 ** 12)
    _, _, value, grads = mpx.filter_value_and_grad(_loss, scaling)(model,
                                                                   batch)
    ref = float(_loss(model, batch))
    assert value.dtype == jnp.float32
    np.testing.assert_allclose(float(value), ref, rtol=0.05)


def test_has_aux():
    def loss_aux(model, batch):
        loss = _loss(model, batch)
        return loss, {"n": batch.shape[0]}

    model, batch = _setup()
    scaling = mpx.DynamicLossScaling(2.0 ** 12)
    s, finite, grads, aux = mpx.filter_grad(loss_aux, scaling,
                                            has_aux=True)(model, batch)
    assert aux["n"] == 8
    s2, finite2, (val, aux2), g2 = mpx.filter_value_and_grad(
        loss_aux, scaling, has_aux=True)(model, batch)
    assert aux2["n"] == 8 and val.dtype == jnp.float32


def test_overflow_shrinks_scaling_and_reports_nonfinite():
    model, batch = _setup()
    mpx.set_half_dtype(jnp.float16)
    try:
        # loss stays fp16 (no force_full_precision) so a 2^30 scale overflows
        def raw_loss(model, batch):
            pred = batch @ model["w"]
            return jnp.mean((pred - 1.0) ** 2)

        scaling = mpx.DynamicLossScaling(2.0 ** 30)
        new_s, finite, grads = mpx.filter_grad(raw_loss, scaling)(model,
                                                                  batch)
        assert not bool(finite)
        assert float(new_s.loss_scaling) == 2.0 ** 29
    finally:
        mpx.set_half_dtype(jnp.bfloat16)


def test_use_mixed_precision_false_is_fp32():
    model, batch = _setup()

    def check_dtype_loss(m, b):
        assert m["w"].dtype == jnp.float32
        return _loss(m, b)

    scaling = mpx.DynamicLossScaling(2.0)
    _, finite, grads = mpx.filter_grad(check_dtype_loss, scaling,
                                       use_mixed_precision=False)(model,
                                                                  batch)
    assert bool(finite)


class _SGD:
    def update(self, grads, state, params=None):
        return jax.tree.map(lambda g: -0.5 * g, grads), state


def test_optimizer_update_applies_when_finite():
    model, batch = _setup()
    grads = {"w": jnp.ones_like(model["w"]), "name": None}
    m2, _ = mpx.optimizer_update(model, _SGD(), {}, grads, jnp.asarray(True))
    np.testing.assert_allclose(np.asarray(m2["w"]),
                               np.asarray(model["w"]) - 0.5)
    assert m2["name"] == "toy"                 # static leaves carried


def test_optimizer_update_skips_when_infinite():
    model, batch = _setup()
    grads = {"w": jnp.full_like(model["w"], jnp.inf), "name": None}
    m2, _ = mpx.optimizer_update(model, _SGD(), {}, grads, jnp.asarray(False))
    np.testing.assert_array_equal(np.asarray(m2["w"]), np.asarray(model["w"]))


def test_filter_jit_with_static_leaves():
    model, batch = _setup()

    @mpx.filter_jit
    def f(model, batch):
        return _loss(model, batch)

    a = float(f(model, batch))
    b = float(f(model, batch))          # cached executable path
    np.testing.assert_allclose(a, b)


def test_paper_example2_pipeline():
    """The exact call sequence of the paper's Example 2(b)."""
    from repro.optim import sgd
    model, batch = _setup()
    optimizer = sgd(learning_rate=0.1, momentum=0.0)
    opt_state = optimizer.init(model)
    loss_scaling = mpx.DynamicLossScaling(2.0 ** 10)

    for _ in range(5):
        loss_scaling, grads_finite, grads = mpx.filter_grad(
            _loss, loss_scaling)(model, batch)
        model, opt_state = mpx.optimizer_update(
            model, optimizer, opt_state, grads, grads_finite)
    assert float(_loss(model, batch)) < float(_loss(_setup()[0], batch))
