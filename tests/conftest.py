"""Shared pytest configuration: custom marker registration."""


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (dry-run lowering, big sweeps)")
    config.addinivalue_line(
        "markers", "serve: repro.serve inference-engine tests")
