"""Dry-run machinery on a reduced (4×4 and 2×2×2) host-device mesh.

The full 512-device production matrix runs via
``python -m repro.launch.dryrun --all`` (results under results/dryrun/);
these tests prove the same code path end to end — lowering, compiling,
memory/cost analysis, collective parsing, multi-pod axis — inside pytest
using subprocesses with a forced host device count.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import hlo


# --------------------------------------------------------------------------
# HLO collective parser (pure text — no devices needed)
# --------------------------------------------------------------------------

SAMPLE_HLO = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%x), replica_groups=[4,4]<=[16]
  %ag = bf16[8,128]{1,0} all-gather(%y), dimensions={0}
  %rs.2 = f32[256]{0} reduce-scatter(%z), dimensions={0}
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%p, %q)
  %cp = u32[4]{0} collective-permute(%r), source_target_pairs={{0,1}}
  %notacoll = f32[9999]{0} add(%a, %b)
  %ar-start = f32[10]{0} all-reduce-start(%w)
"""


def test_collective_parser_kinds_and_bytes():
    stats = hlo.collective_stats(SAMPLE_HLO)
    assert stats["all-reduce"]["bytes"] == 1024 * 512 * 4 + 10 * 4
    assert stats["all-gather"]["bytes"] == 8 * 128 * 2
    assert stats["reduce-scatter"]["bytes"] == 256 * 4
    assert stats["all-to-all"]["bytes"] == 2 * 16 * 16 * 4
    assert stats["collective-permute"]["bytes"] == 4 * 4
    assert hlo.collective_bytes(SAMPLE_HLO) == sum(
        v["bytes"] for v in stats.values())


def test_roofline_terms():
    r = hlo.Roofline(flops_per_dev=197e12, bytes_per_dev=819e9,
                     coll_bytes_per_dev=0.0, chips=4, model_flops=4 * 197e12)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.dominant in ("compute", "memory")
    assert r.useful_ratio == pytest.approx(1.0)
    assert r.mfu == pytest.approx(1.0)


def test_model_flops():
    assert hlo.model_flops_per_step(1e9, 1e6, "train") == 6e15
    assert hlo.model_flops_per_step(1e9, 1e6, "serve") == 2e15
    assert hlo.model_flops_per_step(1e9, 1e6, "train",
                                    active_params=5e8) == 3e15


# --------------------------------------------------------------------------
# end-to-end dry-run on small meshes (subprocess: needs fresh XLA_FLAGS)
# --------------------------------------------------------------------------

_DRYRUN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(n)d"
    import json
    from repro.launch import dryrun as D
    from repro.launch.mesh import make_host_mesh
    from repro.configs import registry
    mesh = make_host_mesh(%(mesh)s)
    cfg = registry.get_smoke_config("%(arch)s")
    rec = D.dryrun_cell("%(arch)s", "%(shape)s", mesh=mesh, cfg=cfg,
                        verbose=False)
    assert rec["status"] == "ok", rec
    assert rec["roofline"]["flops_per_dev"] > 0
    assert rec["memory"]["temp_bytes"] >= 0
    print("DRYRUN_OK", json.dumps(rec["roofline"]["dominant"]))
""")


def _run(script: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=os.getcwd(), timeout=480)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_dryrun_single_pod_smoke():
    out = _run(_DRYRUN_SCRIPT % dict(n=16, mesh="4, 4", arch="llama3-8b",
                                     shape="train_4k"))
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_dryrun_multi_pod_axis():
    out = _run(_DRYRUN_SCRIPT % dict(n=8, mesh="2, 2, pod=2",
                                     arch="mixtral-8x7b", shape="train_4k"))
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_dryrun_decode_cell():
    out = _run(_DRYRUN_SCRIPT % dict(n=16, mesh="4, 4",
                                     arch="recurrentgemma-9b",
                                     shape="decode_32k"))
    assert "DRYRUN_OK" in out


def test_skip_rules():
    from repro.configs import registry, shapes
    cases = {
        ("llama3-8b", "long_500k"): False,
        ("mixtral-8x7b", "long_500k"): True,
        ("mamba2-130m", "long_500k"): True,
        ("recurrentgemma-9b", "long_500k"): True,
        ("gemma2-2b", "long_500k"): False,     # global layers unbounded
        ("hubert-xlarge", "decode_32k"): False,
        ("hubert-xlarge", "prefill_32k"): True,
        ("phi-3-vision-4.2b", "decode_32k"): True,
    }
    for (arch, shape), want in cases.items():
        ok, reason = shapes.cell_status(registry.get_config(arch), shape)
        assert ok == want, (arch, shape, reason)
