"""Logical-axis rule resolution, divisibility fallbacks, ZeRO-1 specs."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import rules as R


class FakeMesh:
    """Duck-typed mesh: resolve_spec only reads .shape (dict)."""
    def __init__(self, **shape):
        self.shape = shape


MESH = FakeMesh(pod=2, data=16, model=16)


def test_basic_resolution():
    spec = R.resolve_spec(("batch", "seq", "embed"), (256, 4096, 4096),
                          MESH, R.DEFAULT_RULES)
    assert spec == P(("pod", "data"), None, None)


def test_divisibility_fallback():
    # 8 kv heads don't divide 16-way model: fallback to replicated
    spec = R.resolve_spec(("embed", "kv_heads", "head_dim"), (4096, 8, 128),
                          MESH, R.DEFAULT_RULES)
    assert spec == P(None, None, None)
    # 32 heads divide: sharded
    spec = R.resolve_spec(("embed", "heads", "head_dim"), (4096, 32, 128),
                          MESH, R.DEFAULT_RULES)
    assert spec == P(None, "model", None)


def test_head_dim_override():
    rules = R.rules_with({"head_dim": "model"})
    spec = R.resolve_spec(("embed", "heads", "head_dim"), (5120, 40, 128),
                          MESH, rules)
    assert spec == P(None, None, "model")    # 40 heads fall back, 128 shards


def test_axis_used_once_per_tensor():
    # vocab and mlp both map to model; only the first gets it
    spec = R.resolve_spec(("vocab", "mlp"), (128256, 14336), MESH,
                          R.DEFAULT_RULES)
    assert spec == P("model", None)


def test_partial_batch_split():
    # batch 8 divides pod(2) but not pod*data(32): only pod is taken
    spec = R.resolve_spec(("batch", None), (8, 5), MESH, R.DEFAULT_RULES)
    assert spec == P("pod", None)


def test_rules_with_overrides_and_additions():
    rules = R.rules_with({"seq": "model", "new_axis": "data"})
    d = dict(rules)
    assert d["seq"] == "model" and d["new_axis"] == "data"
    assert d["batch"] == ("pod", "data")      # untouched


def test_shard_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert R.shard(x, ("batch", "embed")) is x


def test_zero1_spec():
    from repro.train.state import _zero1_spec
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    # dim0 free and divisible by data=1 -> data added
    spec = _zero1_spec(P(None, "model"), (256, 128), mesh)
    assert spec == P("data", "model")
    # already data-sharded: unchanged
    spec = _zero1_spec(P("data", None), (256, 128), mesh)
    assert spec == P("data", None)


def test_state_shardings_cover_every_leaf():
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.optim import make_optimizer
    from repro.train import state as S
    cfg = registry.get_smoke_config("mixtral-8x7b")
    run = RunConfig()
    opt = make_optimizer(run)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    sds = S.abstract_state(cfg, run, opt)
    sh = S.state_shardings(cfg, run, opt, mesh)
    # structural zip must succeed and give one sharding per leaf
    pairs = jax.tree.map(lambda a, b: (a, b), sds, sh)
    n = len(jax.tree.leaves(sds))
    assert n == len(jax.tree.leaves(sh)) // 2 or len(jax.tree.leaves(sh)) > 0
