"""Decode-attention Pallas kernel vs oracle: shape/dtype/length sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,d,s", [
    (2, 8, 2, 64, 1024),
    (1, 4, 4, 128, 512),
    (4, 16, 1, 32, 256),
])
def test_decode_attention_vs_ref(b, h, kv, d, s, dtype):
    q = jax.random.normal(jax.random.key(0), (b, h, d), dtype)
    k = jax.random.normal(jax.random.key(1), (b, s, kv, d), dtype)
    v = jax.random.normal(jax.random.key(2), (b, s, kv, d), dtype)
    for length in (1, s // 3, s):
        got = decode_attention(q, k, v, length, block_k=128, interpret=True)
        want = decode_attention_ref(q, k, v, length)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=tol, rtol=tol)


def test_decode_attention_non_multiple_length_keeps_block():
    """S = 3*512+1 must pad to the next block multiple, not collapse to
    size-1 K-blocks (the old gcd fallback ran 1537 grid steps per row)."""
    b, h, kv, d, s = 2, 4, 2, 32, 3 * 512 + 1
    q = jax.random.normal(jax.random.key(0), (b, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (b, s, kv, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (b, s, kv, d), jnp.bfloat16)
    lengths = jnp.array([s, 700], jnp.int32)
    got = decode_attention(q, k, v, lengths, block_k=512, interpret=True)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)
    # tiny caches shorter than the block still work (block shrinks to S)
    got1 = decode_attention(q, k[:, :5], v[:, :5], jnp.int32(5),
                            block_k=512, interpret=True)
    want1 = decode_attention_ref(q, k[:, :5], v[:, :5], 5)
    np.testing.assert_allclose(np.asarray(got1, np.float32),
                               np.asarray(want1, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_decode_attention_length_is_dynamic():
    """One compiled kernel serves every position (length in SMEM)."""
    b, h, kv, d, s = 1, 4, 2, 64, 512
    q = jax.random.normal(jax.random.key(0), (b, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (b, s, kv, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (b, s, kv, d), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v, n: decode_attention(q, k, v, n,
                                                     block_k=128,
                                                     interpret=True))
    outs = [fn(q, k, v, jnp.int32(n)) for n in (7, 130, 512)]
    refs = [decode_attention_ref(q, k, v, n) for n in (7, 130, 512)]
    for got, want in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=3e-2)
