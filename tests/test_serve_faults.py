"""Chaos suite for the repro.serve resilience layer.

Drives the :mod:`repro.serve.faults` injector against a small engine to
prove the tentpole guarantees: ``drain()`` terminates with correct
statuses under every scripted fault schedule (NaN-poisoned logits, pool
exhaustion, deadline expiry, mid-tick exceptions), pool invariants hold
throughout, every submitted id gets exactly one result, and unfaulted
greedy output stays token-identical to the no-fault run even while
batch neighbors are preempted or killed.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import mpx, serve
from repro.configs.base import ModelConfig
from repro.models import transformer as T

pytestmark = pytest.mark.serve

CFG = ModelConfig(
    name="faults-test", family="dense",
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=128, pattern=("attn",), mlp="swiglu",
    tie_embeddings=True, remat="none",
)


@pytest.fixture(scope="module")
def params():
    return mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), CFG))


def make_engine(params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("chunk_size", 16)
    return serve.ServeEngine(CFG, params, **kw)


def prompts_of(n, seed=0, length=8):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, CFG.vocab_size, length).tolist()
            for _ in range(n)]


def drive(engine, prompts, max_new=8, **submit_kw):
    for p in prompts:
        engine.submit(p, max_new=max_new, **submit_kw)
    return {r.request_id: r for r in engine.drain()}


def assert_pool_clean(engine):
    engine.cache.check_invariants()
    assert engine.cache.free_pages == engine.cache.num_pages
    assert engine.scheduler.busy_slots == 0


# --------------------------------------------------------------------------
# nonfinite-logit guard
# --------------------------------------------------------------------------

def test_nonfinite_guard_fails_only_the_poisoned_request(params):
    prompts = prompts_of(3, seed=1)
    base = drive(make_engine(params, n_slots=3), prompts)
    faults = serve.FaultInjector().poison_logits(1)
    eng = make_engine(params, n_slots=3, faults=faults)
    res = drive(eng, prompts)
    assert res[1].status == "failed"
    assert res[1].metrics.error == "nonfinite logits in decode window"
    # neighbors in the same batch: untouched, token-identical
    for rid in (0, 2):
        assert res[rid].status == "ok"
        assert res[rid].tokens == base[rid].tokens
    assert_pool_clean(eng)
    snap = eng.metrics_snapshot()
    assert snap["serve_nonfinite_total"] == 1
    assert snap["serve_failed_total"] == 1
    assert any(ev[1] == "poison" for ev in faults.log)


def test_nonfinite_guard_mid_decode_delivers_partial_output(params):
    # poison at a decode tick (after the first token) — partial output
    # must be delivered with the failure, never dropped
    faults = serve.FaultInjector().poison_logits(0, tick=3)
    eng = make_engine(params, faults=faults)
    res = drive(eng, prompts_of(1), max_new=32)
    assert res[0].status == "failed"
    assert 0 < len(res[0].tokens) < 32
    assert_pool_clean(eng)


def test_nonfinite_guard_adds_zero_device_syncs(params, monkeypatch):
    """The transfer-count pin holds with the guard compiled in AND a
    poison schedule active: still exactly two device->host arrays per
    step (accept / token) — the verdict rides them."""
    import repro.serve.engine as eng_mod

    class CountingNp:
        def __init__(self, real):
            self._real = real
            self.asarray_calls = 0

        def __getattr__(self, name):
            return getattr(self._real, name)

        def asarray(self, *a, **k):
            self.asarray_calls += 1
            return self._real.asarray(*a, **k)

    proxy = CountingNp(np)
    faults = serve.FaultInjector().poison_logits(0, tick=2)
    engine = make_engine(params, faults=faults)
    monkeypatch.setattr(eng_mod, "np", proxy)
    engine.submit([1, 2, 3], max_new=8)
    per_step = []
    while engine.scheduler.has_work:
        before = proxy.asarray_calls
        engine.step()
        per_step.append(proxy.asarray_calls - before)
    stepped = [n for n in per_step if n]    # post-kill ticks run no step
    assert stepped and all(n == 2 for n in stepped), per_step
    results = sorted(engine._results, key=lambda r: r.request_id)
    assert [r.status for r in results] == ["failed"]


# --------------------------------------------------------------------------
# deadlines and cancellation
# --------------------------------------------------------------------------

def test_deadline_expires_in_flight_with_partial_output(params):
    clock = serve.FakeClock()
    faults = serve.FaultInjector(clock=clock).advance_clock(3, 10.0)
    eng = make_engine(params, faults=faults)
    res = drive(eng, prompts_of(1), max_new=32, deadline_ms=500)
    assert res[0].status == "timeout"
    assert 0 < len(res[0].tokens) < 32
    assert_pool_clean(eng)
    assert eng.metrics_snapshot()["serve_timeouts_total"] == 1


def test_deadline_expires_while_waiting(params):
    # pool exhausted by the injector, so the request never admits; the
    # deadline sweep must retire it (empty output) instead of spinning
    clock = serve.FakeClock()
    faults = (serve.FaultInjector(clock=clock)
              .exhaust_pool(0, until_tick=40)
              .advance_clock(2, 1.0))
    eng = make_engine(params, faults=faults)
    res = drive(eng, prompts_of(1), max_new=4, deadline_ms=100)
    assert res[0].status == "timeout"
    assert res[0].tokens == []
    eng.cache.release_held()
    assert_pool_clean(eng)


def test_cancel_waiting_and_in_flight(params):
    eng = make_engine(params)
    p = prompts_of(3, seed=2)
    r0 = eng.submit(p[0], max_new=32)
    r1 = eng.submit(p[1], max_new=4)
    r2 = eng.submit(p[2], max_new=4)      # waits: both slots busy
    for _ in range(3):
        eng.step()
    assert eng.cancel(r0) is True          # in flight
    assert eng.cancel(r2) is True          # still waiting
    assert eng.cancel(999) is False        # unknown
    res = {r.request_id: r for r in eng.drain()}
    assert res[r0].status == "cancelled"
    assert 0 < len(res[r0].tokens) < 32    # partial output delivered
    assert res[r1].status == "ok" and len(res[r1].tokens) == 4
    assert res[r2].status == "cancelled" and res[r2].tokens == []
    assert eng.cancel(r1) is False         # finished: result stands
    assert_pool_clean(eng)
    assert eng.metrics_snapshot()["serve_cancelled_total"] == 2


# --------------------------------------------------------------------------
# bounded admission
# --------------------------------------------------------------------------

def test_engine_overloaded_backpressure(params):
    eng = make_engine(params, n_slots=1, max_queue=2)
    p = prompts_of(1)[0]
    eng.submit(p, max_new=2)
    eng.submit(p, max_new=2)
    with pytest.raises(serve.EngineOverloaded) as ei:
        eng.submit(p, max_new=2)
    assert ei.value.queue_depth == 2
    assert ei.value.max_queue == 2
    assert ei.value.est_wait_s is None     # no throughput history yet
    assert "back off" in str(ei.value)
    res = eng.drain()
    assert [r.status for r in res] == ["ok", "ok"]
    # with history, the estimate is populated
    eng.submit(p, max_new=2, request_id=10)
    eng.submit(p, max_new=2, request_id=11)
    with pytest.raises(serve.EngineOverloaded) as ei:
        eng.submit(p, max_new=2, request_id=12)
    assert ei.value.est_wait_s is not None and ei.value.est_wait_s > 0
    eng.drain()


# --------------------------------------------------------------------------
# preemption & recompute
# --------------------------------------------------------------------------

def test_preemption_recompute_is_token_identical(params):
    prompts = prompts_of(2, seed=3)
    ample = make_engine(params)            # default pool: never preempts
    base = drive(ample, prompts)
    # never-incremented counters export no series
    assert ample.metrics_snapshot().get("serve_preemptions_total", 0) == 0
    # 3 pages for two requests needing 2 pages each: the second can only
    # admit by evicting the first, which then recomputes
    eng = make_engine(params, num_pages=3)
    res = drive(eng, prompts)
    assert all(r.status == "ok" for r in res.values())
    for rid, r in res.items():
        assert r.tokens == base[rid].tokens, f"rid {rid} diverged"
    snap = eng.metrics_snapshot()
    assert snap["serve_preemptions_total"] >= 1
    assert (sum(r.metrics.preemptions for r in res.values())
            == snap["serve_preemptions_total"])
    # recompute has a visible step cost — only when preemption fires
    assert eng.stats.steps > ample.stats.steps
    assert_pool_clean(eng)


def test_scheduler_preempts_youngest_decoding_slot():
    cache = serve.PagedKVCache(CFG, n_slots=3, max_seq=64, page_size=8,
                               num_pages=6)
    sched = serve.Scheduler(cache, chunk_size=8)
    sched.submit(serve.Request(0, [1] * 8, max_new=8))    # 2 pages
    sched.submit(serve.Request(1, [1] * 8, max_new=8))    # 2 pages
    admitted, preempted = sched.admit()
    assert admitted == [0, 1] and preempted == []
    for slot in sched.slots[:2]:           # mark both as decoding
        slot.fed = len(slot.feed)
        slot.length = slot.fed
        slot.emit([5])
        slot.next_token = 5
    sched.submit(serve.Request(2, [1] * 8, max_new=16))   # 3 pages > 2 free
    admitted, preempted = sched.admit()
    assert admitted == [2]
    assert preempted == [1]                # youngest decoding slot evicted
    requeued = sched.waiting[0]
    assert requeued.request_id == 1 and requeued.resume_out == [5]
    # the resumed slot recomputes prompt KV, then re-feeds its last token
    slot = serve.scheduler._Slot(requeued)
    assert slot.resumed and slot.out == [5]
    assert slot.feed == requeued.prompt    # out[:-1] is empty here
    cache.check_invariants()


def test_no_preemption_of_prefilling_slots():
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=64, page_size=8,
                               num_pages=3)
    sched = serve.Scheduler(cache, chunk_size=8)
    sched.submit(serve.Request(0, [1] * 8, max_new=8))
    sched.submit(serve.Request(1, [1] * 8, max_new=8))
    admitted, preempted = sched.admit()
    assert admitted == [0] and preempted == []
    # slot 0 is still prefilling: not a preemption victim, so request 1
    # waits (evicting a prefill would make no progress at all)
    admitted, preempted = sched.admit()
    assert admitted == [] and preempted == []
    assert sched.slots[0].req.request_id == 0


# --------------------------------------------------------------------------
# device-step and commit failures
# --------------------------------------------------------------------------

def test_injected_device_step_failure_fails_the_plan(params):
    faults = serve.FaultInjector().fail_device_step(2)
    eng = make_engine(params, faults=faults)
    res = drive(eng, prompts_of(2, seed=4), max_new=6)
    assert all(r.status == "failed" for r in res.values())
    assert all("InjectedFault" in r.metrics.error for r in res.values())
    assert all(len(r.tokens) > 0 for r in res.values())   # partial output
    assert_pool_clean(eng)
    # the engine keeps serving after the scripted fault
    rid = eng.submit(prompts_of(1)[0], max_new=3)
    after = {r.request_id: r for r in eng.drain()}
    assert after[rid].status == "ok" and len(after[rid].tokens) == 3


def test_commit_failure_cannot_leak_pages_or_slots(params):
    eng = make_engine(params)
    rid = eng.submit(prompts_of(1, seed=5)[0], max_new=6)
    eng.step()                             # prefill + first token

    def bad_commit(plan, sampled, accept=None):
        raise RuntimeError("synthetic commit failure")

    orig, eng.scheduler.commit = eng.scheduler.commit, bad_commit
    with pytest.raises(RuntimeError, match="synthetic commit failure"):
        eng.step()
    eng.scheduler.commit = orig
    # the regression the try/except exists for: no leaked pages, no
    # busy slot, invariants intact, partial output delivered as "failed"
    assert_pool_clean(eng)
    res = {r.request_id: r for r in eng.drain()}
    assert res[rid].status == "failed"
    assert "synthetic commit failure" in res[rid].metrics.error
    assert len(res[rid].tokens) > 0
    # and the engine still serves
    rid2 = eng.submit(prompts_of(1)[0], max_new=2)
    res = {r.request_id: r for r in eng.drain()}
    assert res[rid2].status == "ok"


def test_exception_after_partial_commit_still_cleans_up(params):
    # the nastier shape: commit() completes its mutations (even retiring
    # a finished slot) and THEN the tick raises — the snapshot path must
    # still deliver every planned request exactly once
    eng = make_engine(params)
    r0 = eng.submit(prompts_of(1, seed=6)[0], max_new=1)   # finishes tick 0
    r1 = eng.submit(prompts_of(1, seed=7)[0], max_new=8)
    orig = eng.scheduler.commit

    def commit_then_raise(plan, sampled, accept=None):
        orig(plan, sampled, accept)
        raise RuntimeError("post-commit failure")

    eng.scheduler.commit = commit_then_raise
    with pytest.raises(RuntimeError, match="post-commit failure"):
        eng.step()
    eng.scheduler.commit = orig
    assert_pool_clean(eng)
    res = {r.request_id: r for r in eng.drain()}
    assert set(res) == {r0, r1}
    assert res[r0].status == "failed" and len(res[r0].tokens) == 1
    assert res[r1].status == "failed"


# --------------------------------------------------------------------------
# pool exhaustion windows + drain termination
# --------------------------------------------------------------------------

def test_pool_exhaustion_window_recovers(params):
    faults = serve.FaultInjector().exhaust_pool(0, until_tick=3)
    eng = make_engine(params, faults=faults)
    res = drive(eng, prompts_of(1), max_new=4)
    assert res[0].status == "ok" and len(res[0].tokens) == 4
    kinds = [ev[1] for ev in faults.log]
    assert "exhaust" in kinds and "release" in kinds
    assert_pool_clean(eng)


def test_drain_no_progress_guard_still_fires_without_deadline(params):
    # satellite pin: the actionable no-progress error is preserved for a
    # genuinely unadmittable request (no deadline to sweep it out)
    eng = make_engine(params)
    eng.scheduler.waiting.append(serve.Request(99, [1] * 8, max_new=1000))
    with pytest.raises(RuntimeError, match=r"no progress.*\[99\]"):
        eng.drain()


def test_drain_terminates_when_only_expired_requests_wait(params):
    # ...while the same unadmittable shape WITH a deadline terminates
    # gracefully: the sweep converts the would-be spin into a timeout
    clock = serve.FakeClock()
    faults = (serve.FaultInjector(clock=clock)
              .exhaust_pool(0, until_tick=30)
              .advance_clock(1, 5.0))
    eng = make_engine(params, faults=faults)
    eng.submit(prompts_of(1)[0], max_new=4, deadline_ms=50)
    res = eng.drain()
    assert [r.status for r in res] == ["timeout"]


# --------------------------------------------------------------------------
# property test: random interleavings (satellite)
# --------------------------------------------------------------------------

def test_random_interleavings_one_result_per_id_invariants_hold(params):
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.data())
    def run(data):
        clock = serve.FakeClock()
        faults = serve.FaultInjector(clock=clock)
        eng = make_engine(params, num_pages=3, faults=faults)
        submitted = []
        n_ops = data.draw(st.integers(4, 14), label="n_ops")
        for i in range(n_ops):
            op = data.draw(st.sampled_from(
                ["submit", "submit", "step", "step", "cancel", "poison",
                 "advance"]), label=f"op{i}")
            if op == "submit":
                deadline = data.draw(
                    st.one_of(st.none(), st.just(50.0)),
                    label=f"deadline{i}")
                rid = eng.submit(
                    [1 + i % 31] * data.draw(st.integers(2, 8),
                                             label=f"plen{i}"),
                    max_new=data.draw(st.integers(1, 6),
                                      label=f"new{i}"),
                    deadline_ms=deadline)
                submitted.append(rid)
            elif op == "cancel" and submitted:
                eng.cancel(data.draw(st.sampled_from(submitted),
                                     label=f"cancel{i}"))
            elif op == "poison" and submitted:
                faults.poison_logits(
                    data.draw(st.sampled_from(submitted),
                              label=f"poison{i}"))
            elif op == "advance":
                clock.advance(data.draw(
                    st.floats(0.0, 0.04, allow_nan=False),
                    label=f"dt{i}"))
            elif op == "step":
                eng.step()
                eng.cache.check_invariants()
        results = eng.drain()
        assert_pool_clean(eng)
        assert sorted(r.request_id for r in results) == sorted(submitted)
        valid = {"ok", "cancelled", "timeout", "failed"}
        assert all(r.status in valid for r in results)

    run()


# --------------------------------------------------------------------------
# proposer memo hygiene on abnormal exits (satellite)
# --------------------------------------------------------------------------

def assert_pool_clean_shared(engine):
    """Sharing-aware pool check: cached prefix pages legitimately stay
    resident after drain, but nothing may remain referenced or held."""
    engine.cache.check_invariants()
    assert engine.scheduler.busy_slots == 0
    assert max(engine.cache._refcount, default=0) == 0
    assert (engine.cache.free_pages + engine.cache.cached_pages
            == engine.cache.num_pages)


def test_proposer_forgets_cancelled_timeout_and_failed_requests(params):
    """Every terminal path — not just normal completion — must drop the
    request's NGramProposer suffix-index entry, or a long-running engine
    leaks host memory under churn."""
    clock = serve.FakeClock()
    prop = serve.NGramProposer(max_ngram=2)
    faults = (serve.FaultInjector(clock=clock)
              .poison_logits(2, tick=6)
              .advance_clock(8, 10.0))
    eng = make_engine(params, n_slots=4, faults=faults, spec_tokens=2,
                      chunk_size=16, proposer=prop)
    p = prompts_of(4, seed=8)
    eng.submit(p[0], max_new=32)                       # cancelled below
    eng.submit(p[1], max_new=32, deadline_ms=500)      # times out
    eng.submit(p[2], max_new=32)                       # poisoned -> failed
    eng.submit(p[3], max_new=4)                        # completes
    for _ in range(4):
        eng.step()
    assert prop._index                   # decoding slots built memo state
    eng.cancel(0)
    res = {r.request_id: r for r in eng.drain()}
    assert res[0].status == "cancelled"
    assert res[1].status == "timeout"
    assert res[2].status == "failed"
    assert res[3].status == "ok"
    assert prop._index == {}             # no terminal path leaks a memo
    assert_pool_clean(eng)


def test_proposer_forgets_device_step_failure(params):
    prop = serve.NGramProposer(max_ngram=2)
    faults = serve.FaultInjector().fail_device_step(3)
    eng = make_engine(params, faults=faults, spec_tokens=2,
                      chunk_size=16, proposer=prop)
    res = drive(eng, prompts_of(2, seed=9), max_new=16)
    assert all(r.status == "failed" for r in res.values())
    assert prop._index == {}
    assert_pool_clean(eng)


# --------------------------------------------------------------------------
# chaos with the prefix cache enabled (satellite)
# --------------------------------------------------------------------------

def test_pool_exhaustion_window_with_prefix_cache(params):
    """A scripted hold window with sharing active: refcount/free/held/
    cached invariants must hold every tick, and the engine recovers."""
    rng = np.random.default_rng(12)
    prefix = rng.integers(1, CFG.vocab_size, 16).tolist()
    faults = serve.FaultInjector().exhaust_pool(1, until_tick=4)
    eng = make_engine(params, faults=faults, prefix_cache=True)
    eng.submit(prefix + [5, 6], max_new=4)
    eng.submit(prefix + [7, 8], max_new=4)
    while eng.scheduler.has_work:
        eng.step()
        eng.cache.check_invariants()
    res = {r.request_id: r for r in eng.drain()}
    assert all(r.status == "ok" for r in res.values())
    kinds = [ev[1] for ev in faults.log]
    assert "exhaust" in kinds and "release" in kinds
    assert_pool_clean_shared(eng)


def test_preemption_under_sharing_keeps_invariants_every_tick(params):
    """Pool pressure + shared prefix pages: eviction decrements, never
    frees a page another slot references — checked at every tick."""
    rng = np.random.default_rng(13)
    prefix = rng.integers(1, CFG.vocab_size, 16).tolist()
    prompts = [prefix + [i + 1] for i in range(3)]
    ample = make_engine(params, n_slots=3, prefix_cache=True)
    base = drive(ample, prompts, max_new=8)
    eng = make_engine(params, n_slots=3, num_pages=8, prefix_cache=True)
    eng.submit(prompts[0], max_new=8)
    eng.drain()                                        # warm the prefix
    for p in prompts[1:]:
        eng.submit(p, max_new=8)
    while eng.scheduler.has_work:
        eng.step()
        eng.cache.check_invariants()
    res = {r.request_id: r for r in eng.drain()}
    assert all(r.status == "ok" for r in res.values())
    for rid, r in base.items():
        assert res[rid].tokens == r.tokens, f"rid {rid} diverged"
    assert_pool_clean_shared(eng)


def test_random_interleavings_with_prefix_cache(params):
    """The property test of the resilience tentpole, rerun with sharing
    active: one result per id, refcount invariants at every step."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    prefix = [3, 1, 4, 1, 5, 9, 2, 6]        # one page at page_size=8

    @settings(max_examples=4, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.data())
    def run(data):
        clock = serve.FakeClock()
        faults = serve.FaultInjector(clock=clock)
        eng = make_engine(params, num_pages=6, prefix_cache=True,
                          faults=faults)
        submitted = []
        n_ops = data.draw(st.integers(4, 12), label="n_ops")
        for i in range(n_ops):
            op = data.draw(st.sampled_from(
                ["submit", "submit", "step", "step", "cancel",
                 "advance"]), label=f"op{i}")
            if op == "submit":
                tail = data.draw(st.integers(0, 4), label=f"tail{i}")
                rid = eng.submit(prefix + [10 + i] * tail,
                                 max_new=data.draw(st.integers(1, 4),
                                                   label=f"new{i}"),
                                 deadline_ms=data.draw(
                                     st.one_of(st.none(), st.just(50.0)),
                                     label=f"deadline{i}"))
                submitted.append(rid)
            elif op == "cancel" and submitted:
                eng.cancel(data.draw(st.sampled_from(submitted),
                                     label=f"cancel{i}"))
            elif op == "advance":
                clock.advance(data.draw(
                    st.floats(0.0, 0.04, allow_nan=False),
                    label=f"dt{i}"))
            elif op == "step":
                eng.step()
                eng.cache.check_invariants()
        results = eng.drain()
        assert_pool_clean_shared(eng)
        assert sorted(r.request_id for r in results) == sorted(submitted)

    run()


# --------------------------------------------------------------------------
# bench schema
# --------------------------------------------------------------------------

def test_bench_schema_has_resilience_rows():
    import pathlib
    import sys
    root = pathlib.Path(__file__).resolve().parents[1]
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    import importlib
    sb = importlib.import_module("benchmarks.serving_bench")
    names = sb.expected_row_names()
    assert "serving_preempt_recompute_overhead_pct" in names
    assert "serving_resilience_statuses" in names
