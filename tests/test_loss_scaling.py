"""DynamicLossScaling behavior (paper §2.1, §3.3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import mpx


def test_scale_unscale_roundtrip():
    ls = mpx.DynamicLossScaling(2.0 ** 11)
    g = {"a": jnp.full((5,), 3.0), "ids": jnp.arange(2)}
    out = ls.unscale(ls.scale(g))
    np.testing.assert_allclose(np.asarray(out["a"]), 3.0, rtol=1e-6)
    assert out["a"].dtype == jnp.float32       # unscale casts to fp32
    assert out["ids"].dtype == jnp.int32


def test_unscale_casts_half_to_fp32():
    ls = mpx.DynamicLossScaling(1024.0)
    g = {"a": jnp.full((3,), 8.0, jnp.float16)}
    out = ls.unscale(g)
    assert out["a"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out["a"]), 8.0 / 1024.0)


def test_adjust_grows_after_period():
    ls = mpx.DynamicLossScaling(1024.0, period=3, factor=2.0)
    t = jnp.asarray(True)
    for _ in range(2):
        ls = ls.adjust(t)
    assert float(ls.loss_scaling) == 1024.0      # not yet
    ls = ls.adjust(t)
    assert float(ls.loss_scaling) == 2048.0      # third consecutive
    assert int(ls.counter) == 0                  # counter reset


def test_adjust_shrinks_on_overflow_and_resets_counter():
    ls = mpx.DynamicLossScaling(1024.0, period=3, factor=2.0)
    ls = ls.adjust(jnp.asarray(True))
    ls = ls.adjust(jnp.asarray(False))
    assert float(ls.loss_scaling) == 512.0
    assert int(ls.counter) == 0


def test_adjust_clamps():
    ls = mpx.DynamicLossScaling(1.0, period=1, factor=2.0,
                                min_loss_scaling=1.0, max_loss_scaling=4.0)
    ls = ls.adjust(jnp.asarray(False))
    assert float(ls.loss_scaling) == 1.0          # min clamp
    for _ in range(5):
        ls = ls.adjust(jnp.asarray(True))
    assert float(ls.loss_scaling) == 4.0          # max clamp


def test_scaling_is_pytree_and_jittable():
    ls = mpx.DynamicLossScaling(256.0, period=2)

    @jax.jit
    def step(ls, ok):
        return ls.adjust(ok)

    out = step(ls, jnp.asarray(False))
    assert isinstance(out, mpx.DynamicLossScaling)
    assert float(out.loss_scaling) == 128.0
    # static fields preserved through flatten/unflatten
    leaves, treedef = jax.tree.flatten(ls)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert rebuilt.period == 2


def test_noop_scaling_is_lazy():
    """``NoOpLossScaling.loss_scaling`` must not be a device array baked at
    import time (that would allocate on the default device before user code
    can pick one) — it is a property computed on access."""
    assert isinstance(vars(mpx.NoOpLossScaling)["loss_scaling"], property)
    ls = mpx.NoOpLossScaling()
    assert isinstance(ls.loss_scaling, jax.Array)
    assert float(ls.loss_scaling) == 1.0


def test_noop_scaling_import_allocates_nothing():
    """Importing the loss-scaling module creates zero live device arrays."""
    import os
    import subprocess
    import sys
    code = ("import jax\n"
            "import repro.core.loss_scaling\n"
            "leaked = jax.live_arrays()\n"
            "assert not leaked, leaked\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = ("src" + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr


def test_noop_scaling():
    ls = mpx.NoOpLossScaling()
    g = {"a": jnp.full((3,), 5.0, jnp.bfloat16)}
    assert ls.scale(g)["a"].dtype == jnp.bfloat16
    out = ls.unscale(g)
    assert out["a"].dtype == jnp.float32
    assert ls.adjust(jnp.asarray(False)) is not None


def test_all_finite():
    assert bool(mpx.all_finite({"a": jnp.ones(3)}))
    assert not bool(mpx.all_finite({"a": jnp.array([1.0, jnp.inf])}))
    assert not bool(mpx.all_finite({"a": jnp.array([jnp.nan])}))
    assert bool(mpx.all_finite({"ids": jnp.arange(3)}))   # ints ignored
    assert bool(mpx.all_finite({}))
