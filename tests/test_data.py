"""Data pipeline: determinism, shard slicing, checkpointable iteration."""
import numpy as np

from repro.configs import registry
from repro.data.pipeline import (MemmapTokens, Prefetcher, SyntheticTokens,
                                 make_token_file)


def _cfg():
    return registry.get_smoke_config("llama3-8b")


def test_synthetic_deterministic():
    a = SyntheticTokens(_cfg(), batch=4, seq=8, seed=1)
    b = SyntheticTokens(_cfg(), batch=4, seq=8, seed=1)
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba["inputs"], bb["inputs"])


def test_synthetic_state_resume():
    a = SyntheticTokens(_cfg(), batch=4, seq=8, seed=1)
    a.next_batch(); a.next_batch()
    st = a.state()
    want = a.next_batch()
    b = SyntheticTokens(_cfg(), batch=4, seq=8, seed=99)
    b.load_state(st)
    got = b.next_batch()
    np.testing.assert_array_equal(want["inputs"], got["inputs"])


def test_shards_disjoint_and_partition():
    full = SyntheticTokens(_cfg(), batch=8, seq=8, seed=2)
    s0 = SyntheticTokens(_cfg(), batch=8, seq=8, seed=2, shard_id=0,
                         num_shards=2)
    s1 = SyntheticTokens(_cfg(), batch=8, seq=8, seed=2, shard_id=1,
                         num_shards=2)
    b0, b1 = s0.next_batch(), s1.next_batch()
    assert b0["inputs"].shape[0] == 4 and b1["inputs"].shape[0] == 4
    assert not np.array_equal(b0["inputs"], b1["inputs"])


def test_frontends_have_right_keys():
    hub = registry.get_smoke_config("hubert-xlarge")
    b = SyntheticTokens(hub, batch=2, seq=8).next_batch()
    assert set(b) == {"features", "targets"}
    vlm = registry.get_smoke_config("phi-3-vision-4.2b")
    b = SyntheticTokens(vlm, batch=2, seq=8).next_batch()
    assert set(b) == {"inputs", "targets", "patches"}


def test_memmap_tokens(tmp_path):
    path = str(tmp_path / "tokens.bin")
    make_token_file(path, 10000, vocab=128, seed=0)
    it = MemmapTokens(path, batch=4, seq=16, seed=1)
    b = it.next_batch()
    assert b["inputs"].shape == (4, 16) and b["targets"].shape == (4, 16)
    # next-token alignment
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])
    # determinism via state
    st = it.state()
    want = it.next_batch()
    it2 = MemmapTokens(path, batch=4, seq=16, seed=1)
    it2.load_state(st)
    np.testing.assert_array_equal(want["inputs"], it2.next_batch()["inputs"])


def test_prefetcher_preserves_order_and_state():
    src = SyntheticTokens(_cfg(), batch=4, seq=8, seed=5)
    ref = SyntheticTokens(_cfg(), batch=4, seq=8, seed=5)
    pf = Prefetcher(src, depth=2)
    try:
        for _ in range(5):
            np.testing.assert_array_equal(pf.next_batch()["inputs"],
                                          ref.next_batch()["inputs"])
        # state counts consumed batches, not produced ones
        assert pf.state()["step"] == 5
    finally:
        pf.close()
