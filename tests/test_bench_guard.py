"""Unit tests for the bench-regression guard
(``benchmarks/check_regression.py``) and for the committed baseline.

The guard is pure stdlib, so most tests here run on synthetic row lists
and never touch jax.  The last test cross-checks the committed
``benchmarks/baseline.json`` against ``expected_row_names()`` so a bench
schema change that forgets to regenerate the baseline fails in tier-1,
not just in the CI bench step.
"""
import json
import pathlib

import pytest

from benchmarks.check_regression import (DEFAULT_TOLERANCE, compare, main)

REPO = pathlib.Path(__file__).resolve().parents[1]
BASELINE = REPO / "benchmarks" / "baseline.json"


def rows(**kv):
    return [{"name": k, "value": v, "derived": ""} for k, v in kv.items()]


def test_identical_rows_pass():
    r = rows(serving_tok_2slots=3000.0,
             serving_hbm_bytes_decode_paged=123456.0,
             serving_prefix_ttft_hot_ratio=0.1)
    assert compare(r, r) == []


def test_missing_row_is_schema_drift():
    base = rows(serving_tok_2slots=3000.0, serving_prefix_pages_resident=7.0)
    cur = rows(serving_tok_2slots=3000.0)
    (err,) = compare(cur, base)
    assert "schema drift" in err and "serving_prefix_pages_resident" in err


def test_extra_row_is_schema_drift():
    base = rows(serving_tok_2slots=3000.0)
    cur = rows(serving_tok_2slots=3000.0, serving_new_thing=1.0)
    (err,) = compare(cur, base)
    assert "schema drift" in err and "serving_new_thing" in err
    assert "regenerate" in err


def test_bytes_rows_compared_exactly():
    base = rows(serving_hbm_bytes_decode_paged=1000.0)
    cur = rows(serving_hbm_bytes_decode_paged=1001.0)
    (err,) = compare(cur, base)
    assert "exact match required" in err
    # even a 0.1% drift in an analytic row is a cost-model change
    assert compare(base, base) == []


def test_wallclock_rows_use_relative_tolerance():
    base = rows(serving_ttft_2slots=100_000.0)
    # 10x slower: within the 25x guard band
    assert compare(rows(serving_ttft_2slots=1_000_000.0), base) == []
    # 30x slower: catastrophic, fails
    (err,) = compare(rows(serving_ttft_2slots=3_000_000.0), base)
    assert "wall-clock" in err
    # 30x *faster* also fails — that means the row stopped measuring work
    (err,) = compare(rows(serving_ttft_2slots=3_000.0), base)
    assert "wall-clock" in err


def test_other_rows_are_presence_only():
    base = rows(serving_prefix_ttft_hot_ratio=0.1, serving_occupancy=0.99)
    cur = rows(serving_prefix_ttft_hot_ratio=0.9, serving_occupancy=0.01)
    assert compare(cur, base) == []


def test_duplicate_names_rejected():
    dup = [{"name": "serving_tok_2slots", "value": 1.0},
           {"name": "serving_tok_2slots", "value": 2.0}]
    with pytest.raises(ValueError, match="duplicate"):
        compare(dup, rows(serving_tok_2slots=1.0))


def test_tolerance_must_be_a_ratio():
    r = rows(serving_tok_2slots=1.0)
    with pytest.raises(ValueError, match="tolerance"):
        compare(r, r, tolerance=0.5)


def test_cli_exit_codes(tmp_path, capsys):
    base = rows(serving_tok_2slots=3000.0,
                serving_hbm_bytes_decode_paged=1000.0)
    good = tmp_path / "good.json"
    bad = tmp_path / "bad.json"
    basef = tmp_path / "baseline.json"
    basef.write_text(json.dumps(base))
    good.write_text(json.dumps(base))
    bad.write_text(json.dumps(
        rows(serving_tok_2slots=3000.0,
             serving_hbm_bytes_decode_paged=999.0)))
    assert main([str(good), str(basef)]) == 0
    assert "passed" in capsys.readouterr().out
    assert main([str(bad), str(basef)]) == 1
    assert "FAILED" in capsys.readouterr().out


def test_committed_baseline_matches_bench_schema():
    serving_bench = pytest.importorskip("benchmarks.serving_bench")
    baseline = json.loads(BASELINE.read_text())
    names = [r["name"] for r in baseline]
    assert names == serving_bench.expected_row_names(), (
        "benchmarks/baseline.json is stale — regenerate it with "
        "`python -m benchmarks.serving_bench --json benchmarks/baseline.json`")
    # and the default tolerance stays a guard band, not a precision claim
    assert DEFAULT_TOLERANCE >= 10.0
