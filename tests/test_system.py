"""End-to-end system tests: the paper's pipeline on the framework stack.

1. Mixed-precision training of a small LM memorizes synthetic data (loss
   drops measurably in 40 steps) with dynamic loss scaling active.
2. fp16 + dynamic scaling survives an injected overflow: the scale halves,
   the step is skipped (params unchanged), training continues.
3. Serving: greedy decode from the trained params is deterministic.
4. fp32 vs bf16-mixed training converge to similar losses (the paper's
   "no accuracy compromise" claim at smoke scale).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import mpx
from repro.configs import registry, shapes
from repro.configs.base import RunConfig
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import state as S
from repro.train.steps import make_serve_step, make_train_step


def test_train_then_serve_end_to_end():
    cfg = registry.get_smoke_config("llama3-8b")
    run = RunConfig(learning_rate=3e-3)
    opt = make_optimizer(run)
    st = S.init_state(jax.random.key(0), cfg, run, opt)
    step = jax.jit(make_train_step(cfg, run, opt))
    batch = shapes.make_batch(cfg, 8, 16)

    losses = []
    for _ in range(40):
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])
    assert float(m["loss_scale"]) >= 2.0 ** 15     # scaling stayed healthy

    # --- serve from the trained params ---
    params_bf16 = mpx.cast_to_bfloat16(st["params"])
    serve = jax.jit(make_serve_step(cfg))

    def generate():
        cache = T.init_cache(cfg, 8, 16, jnp.bfloat16)
        toks = batch["inputs"][:, :1]
        outs = [toks]
        for t in range(8):
            toks, cache = serve(params_bf16, cache, toks, jnp.int32(t))
            outs.append(toks)
        return np.asarray(jnp.concatenate(outs, axis=1))

    gen1, gen2 = generate(), generate()
    assert gen1.shape == (8, 9)
    np.testing.assert_array_equal(gen1, gen2)      # deterministic serving


def test_overflow_step_is_skipped_and_training_recovers():
    cfg = registry.get_smoke_config("gemma2-2b")
    # init_scale 2^8: the default 2^15 overflows fp16 cotangents on this
    # tiny model immediately (which dynamic scaling would walk down over
    # a few steps — here we want a healthy step 1 to compare against).
    run = RunConfig(learning_rate=1e-3, init_scale=2.0 ** 8,
                    policy="params=float32,compute=float16,output=float32")
    opt = make_optimizer(run)
    st = S.init_state(jax.random.key(1), cfg, run, opt)
    step = jax.jit(make_train_step(cfg, run, opt))
    batch = shapes.make_batch(cfg, 4, 16)

    st, m0 = step(st, batch)
    assert bool(m0["grads_finite"])
    scale_before = float(m0["loss_scale"])

    # poison the params so the fp16 forward overflows -> skipped step
    poisoned = dict(st)
    poisoned["params"] = jax.tree.map(
        lambda p: p * 1e30 if p.ndim >= 2 else p, st["params"])
    st_bad, m_bad = step(poisoned, batch)
    assert not bool(m_bad["grads_finite"])
    assert float(m_bad["loss_scale"]) == scale_before / 2   # halved
    np.testing.assert_array_equal(                          # step skipped
        np.asarray(jax.tree.leaves(st_bad["params"])[0]),
        np.asarray(jax.tree.leaves(poisoned["params"])[0]))

    st, m1 = step(st, batch)                                # recovers
    assert bool(m1["grads_finite"])


def test_fp32_and_mixed_converge_similarly():
    cfg = registry.get_smoke_config("starcoder2-3b")
    batch = shapes.make_batch(cfg, 8, 16)
    finals = {}
    for name, policy in [("fp32", "f32"),
                         ("mixed", "params=f32,compute=bf16,output=f32")]:
        run = RunConfig(learning_rate=1e-3, policy=policy)
        opt = make_optimizer(run)
        st = S.init_state(jax.random.key(2), cfg, run, opt)
        step = jax.jit(make_train_step(cfg, run, opt))
        for _ in range(30):
            st, m = step(st, batch)
        finals[name] = float(m["loss"])
    assert abs(finals["fp32"] - finals["mixed"]) / finals["fp32"] < 0.05, \
        finals
