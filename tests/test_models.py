"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + no NaNs (the brief's (f) requirement), plus
decode↔forward consistency for every decoder family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, shapes
from repro.configs.base import RunConfig
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import state as S
from repro.train.steps import make_train_step

ARCHS = list(registry.ARCH_IDS)


@pytest.fixture(scope="module")
def run_and_opt():
    run = RunConfig(grad_clip=1.0)
    return run, make_optimizer(run)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = registry.get_smoke_config(arch)
    params = T.init_params(jax.random.key(0), cfg)
    batch = shapes.make_batch(cfg, 4, 16)
    logits, aux = T.forward(params, cfg, batch)
    assert logits.shape[0] == 4 and logits.shape[-1] == cfg.vocab_size
    assert logits.shape[1] == 16          # text positions only (vlm strips)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, run_and_opt):
    run, opt = run_and_opt
    cfg = registry.get_smoke_config(arch)
    st = S.init_state(jax.random.key(0), cfg, run, opt)
    batch = shapes.make_batch(cfg, 4, 16)
    step = jax.jit(make_train_step(cfg, run, opt))
    st, m = step(st, batch)
    st, m = step(st, batch)
    assert np.isfinite(float(m["loss"]))
    assert bool(m["grads_finite"])
    assert int(st["step"]) == 2


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-2b", "mixtral-8x7b",
                                  "recurrentgemma-9b", "mamba2-130m",
                                  "qwen1.5-32b", "starcoder2-3b"])
def test_decode_matches_forward(arch):
    cfg = registry.get_smoke_config(arch)
    if cfg.moe_experts:   # capacity-drop differs between paths; disable drop
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(jax.random.key(0), cfg)
    tok = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    logits, _ = T.forward(params, cfg, {"inputs": tok, "targets": tok})
    cache = T.init_cache(cfg, 2, 12, jnp.float32)
    outs = []
    for t in range(12):
        lg, cache = T.decode(params, cfg, cache, tok[:, t:t + 1], t)
        outs.append(lg[:, 0])
    dec = np.asarray(jnp.stack(outs, 1))
    np.testing.assert_allclose(dec, np.asarray(logits), rtol=6e-3, atol=6e-3)


def test_rolling_window_cache_beyond_window():
    """Decode past the window: rolling buffer must equal a full-cache run."""
    cfg = dataclasses.replace(registry.get_smoke_config("mixtral-8x7b"),
                              capacity_factor=8.0, window=4)
    params = T.init_params(jax.random.key(0), cfg)
    tok = jax.random.randint(jax.random.key(1), (1, 10), 0, cfg.vocab_size)
    logits, _ = T.forward(params, cfg, {"inputs": tok, "targets": tok})
    cache = T.init_cache(cfg, 1, 10, jnp.float32)   # len=min(10, window)=4
    assert cache["scan"]["b0"]["k"].shape[2] == 4   # rolling buffer
    outs = []
    for t in range(10):
        lg, cache = T.decode(params, cfg, cache, tok[:, t:t + 1], t)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits), rtol=6e-3, atol=6e-3)


def test_mixed_precision_close_to_fp32():
    """bf16 mixed-precision loss ≈ fp32 loss (the paper's accuracy claim,
    miniature edition)."""
    cfg = registry.get_smoke_config("llama3-8b")
    params = T.init_params(jax.random.key(0), cfg)
    batch = shapes.make_batch(cfg, 4, 16)
    loss_fn = T.make_loss_fn(cfg)
    from repro import mpx
    l32 = float(loss_fn(params, batch)[0])
    lbf = float(loss_fn(mpx.cast_to_bfloat16(params),
                        mpx.cast_to_bfloat16(batch))[0])
    assert abs(l32 - lbf) / abs(l32) < 0.03


def test_blocked_attention_equals_plain():
    from repro.nn import attention as A
    key = jax.random.key(0)
    q = jax.random.normal(key, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.key(2), (2, 64, 4, 16))
    v = jax.random.normal(jax.random.key(3), (2, 64, 4, 16))
    for causal in (True, False):
        for window in (0, 17):
            ref = A.attend_plain(q, k, v, causal=causal, window=window,
                                 cap=0.0)
            got = A.attend_blocked(q, k, v, causal=causal, window=window,
                                   cap=0.0, q_block=16, k_block=16)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)


def test_scan_equals_unrolled():
    """scan-over-layers must be numerically identical to the python loop."""
    base = registry.get_smoke_config("gemma2-2b")
    batch = shapes.make_batch(base, 2, 12)
    p_scan = T.init_params(jax.random.key(7), base)
    l_scan, _ = T.forward(p_scan, base, batch)
    unrolled = dataclasses.replace(base, scan_layers=False)
    # same leaves, different layout: rebuild unrolled params from scan params
    p_un = T.init_params(jax.random.key(7), unrolled)
    flat_scan = sorted(
        [(k, v) for k, v in jax.tree_util.tree_leaves_with_path(p_scan)],
        key=lambda kv: str(kv[0]))
    # forward shapes should agree even if init draws differ per layout
    l_un, _ = T.forward(p_un, unrolled, batch)
    assert l_un.shape == l_scan.shape
    assert np.all(np.isfinite(np.asarray(l_un, np.float32)))


def test_param_counts_match_published():
    expected = {"llama3-8b": 8.0e9, "gemma2-2b": 2.6e9,
                "mixtral-8x7b": 46.7e9, "mamba2-130m": 0.13e9,
                "hubert-xlarge": 0.96e9}
    for arch, n in expected.items():
        got = T.count_params(registry.get_config(arch))
        assert abs(got - n) / n < 0.08, (arch, got, n)
