"""Hypothesis property tests on system invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro import mpx

hypothesis.settings.register_profile(
    "fast", max_examples=25, deadline=None)
hypothesis.settings.load_profile("fast")

# -- pytree strategies -------------------------------------------------------

_float_dtypes = st.sampled_from([jnp.float32, jnp.float16, jnp.bfloat16])
_scalars = st.one_of(st.integers(-5, 5), st.text(max_size=3), st.none())


@st.composite
def arrays(draw):
    shape = tuple(draw(st.lists(st.integers(1, 4), min_size=0, max_size=3)))
    if draw(st.booleans()):
        dt = draw(_float_dtypes)
        vals = draw(st.floats(-1e3, 1e3, allow_nan=False))
        return jnp.full(shape, vals, dt)
    return jnp.ones(shape, jnp.int32)


@st.composite
def pytrees(draw, depth=2):
    if depth == 0:
        return draw(st.one_of(arrays(), _scalars))
    return draw(st.one_of(
        arrays(), _scalars,
        st.lists(pytrees(depth=depth - 1), max_size=3),
        st.dictionaries(st.text(max_size=4), pytrees(depth=depth - 1),
                        max_size=3),
    ))


# -- properties --------------------------------------------------------------

@given(pytrees())
def test_cast_preserves_structure_and_nonfloats(tree):
    out = mpx.cast_to_bfloat16(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        if mpx.is_float_array(a):
            assert b.dtype == jnp.bfloat16
            assert a.shape == b.shape
        elif mpx.is_array(a):
            assert a.dtype == b.dtype


@given(pytrees())
def test_cast_idempotent(tree):
    once = mpx.cast_to_bfloat16(tree)
    twice = mpx.cast_to_bfloat16(once)
    for a, b in zip(jax.tree.leaves(once), jax.tree.leaves(twice)):
        if mpx.is_array(a):
            np.testing.assert_array_equal(np.asarray(a, np.float32)
                                          if mpx.is_float_array(a)
                                          else np.asarray(a),
                                          np.asarray(b, np.float32)
                                          if mpx.is_float_array(b)
                                          else np.asarray(b))


@given(pytrees())
def test_partition_combine_roundtrip(tree):
    dyn, static = mpx.partition(tree, mpx.is_inexact_array)
    merged = mpx.combine(dyn, static)
    assert jax.tree.structure(merged) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(merged)):
        if mpx.is_array(a):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            assert a == b


@given(st.floats(1.0, 2.0 ** 20), st.floats(-100.0, 100.0))
def test_scale_unscale_identity(scale, value):
    ls = mpx.DynamicLossScaling(scale)
    g = {"a": jnp.full((3,), value, jnp.float32)}
    out = ls.unscale(ls.scale(g))
    np.testing.assert_allclose(np.asarray(out["a"]), value,
                               rtol=1e-5, atol=1e-5)


@given(st.lists(st.booleans(), min_size=1, max_size=40),
       st.integers(1, 8))
def test_scaling_bounds_invariant(finite_seq, period):
    """Scaling never leaves [min, max] under any finite/overflow sequence."""
    ls = mpx.DynamicLossScaling(1024.0, period=period, factor=2.0,
                                min_loss_scaling=1.0,
                                max_loss_scaling=2.0 ** 16)
    for ok in finite_seq:
        ls = ls.adjust(jnp.asarray(ok))
        s = float(ls.loss_scaling)
        assert 1.0 <= s <= 2.0 ** 16
        assert 0 <= int(ls.counter) < period


@given(st.integers(1, 64), st.integers(1, 8))
def test_adamw_closed_form_first_step(n, seed):
    """After one AdamW step from zero state, update = -lr·g/(|g|+eps)·bias
    corrections cancel -> step direction is -sign(g) ·lr (no wd)."""
    from repro.optim import adamw
    key = jax.random.key(seed)
    g = jax.random.normal(key, (n,)) + 0.01
    params = {"w": jnp.zeros((n,))}
    opt = adamw(learning_rate=0.1, weight_decay=0.0)
    state = opt.init(params)
    updates, _ = opt.update({"w": g}, state, params=params)
    expected = -0.1 * np.sign(np.asarray(g))
    np.testing.assert_allclose(np.asarray(updates["w"]), expected,
                               atol=1e-3)


@given(st.floats(0.1, 10.0))
def test_select_tree(p):
    a = {"x": jnp.full((2,), p)}
    b = {"x": jnp.zeros((2,))}
    out_t = mpx.select_tree(jnp.asarray(True), a, b)
    out_f = mpx.select_tree(jnp.asarray(False), a, b)
    np.testing.assert_allclose(np.asarray(out_t["x"]), p)
    np.testing.assert_allclose(np.asarray(out_f["x"]), 0.0)
