"""The per-layer-kind paged state pool: one ServeEngine for attention,
SSM, RG-LRU and hybrid stacks.

Pins the tentpole end state — greedy engine output token-identical to the
dense per-token ``decode()`` oracle for one config per layer-kind family —
plus the hygiene and policy invariants around it: slot reuse re-initializes
recurrent state (and ``check_invariants`` catches a leak), SSD/RG-LRU slot
states stay fp32 through the live engine under the default bf16 serving
policy, unsupported layer kinds and speculative windows on recurrent
stacks fail with actionable errors, the serving_bench arch rows are
schema-pinned without running the bench, and the per-layer-kind
state-bytes gauge lands in the Prometheus snapshot.
"""
import functools
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import mpx, serve
from repro.configs.base import ModelConfig
from repro.models import transformer as T

pytestmark = pytest.mark.serve

# one config per layer-kind family the state pool serves: dense attention,
# mamba2-130m-shaped pure SSD, pure RG-LRU, recurrentgemma-shaped hybrid
CFGS = {
    "attn": ModelConfig(
        name="state-attn", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, pattern=("attn",), mlp="swiglu",
        tie_embeddings=True, remat="none"),
    "ssm": ModelConfig(
        name="state-ssm", family="ssm",
        n_layers=3, d_model=48, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=128, pattern=("ssd",), mlp="none",
        norm="rmsnorm", ssm_state=16, ssm_headdim=24, ssm_expand=2,
        ssm_chunk=8, conv_width=4, rope_theta=0.0, tie_embeddings=True,
        remat="none"),
    "rglru": ModelConfig(
        name="state-rglru", family="hybrid",
        n_layers=3, d_model=48, n_heads=0, n_kv_heads=0,
        d_ff=96, vocab_size=128, pattern=("rglru",), mlp="geglu",
        norm="rmsnorm", d_rnn=48, conv_width=4, rope_theta=0.0,
        tie_embeddings=True, remat="none"),
    "hybrid": ModelConfig(
        name="state-hybrid", family="hybrid",
        n_layers=5, d_model=48, n_heads=4, n_kv_heads=1, head_dim=12,
        d_ff=96, vocab_size=128,
        pattern=("rglru", "rglru", "local_attn"), window=8,
        mlp="geglu", norm="rmsnorm", d_rnn=48, conv_width=4,
        rope_theta=10000.0, tie_embeddings=True, emb_scale=True,
        remat="none"),
}

PROMPT_LENS = (3, 11, 6, 9)


@functools.lru_cache(maxsize=None)
def _params(fam):
    return mpx.cast_to_bfloat16(T.init_params(jax.random.key(7), CFGS[fam]))


def _prompts(fam, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, CFGS[fam].vocab_size, k).tolist()
            for k in PROMPT_LENS[:n]]


def _oracle(cfg, params, prompts, max_new, max_seq):
    """Greedy per-token dense decode: prefill token-by-token through
    ``T.decode`` (batch 1), then generate with fp32 argmax — the serving
    token-identity reference for every architecture family."""
    step = jax.jit(lambda p, c, t, pos: T.decode(p, cfg, c, t, pos))
    outs = []
    for prompt in prompts:
        cache = T.init_cache(cfg, 1, max_seq, jnp.bfloat16)
        logits = None
        for i, tok in enumerate(prompt):
            logits, cache = step(params, cache,
                                 jnp.asarray([[tok]], jnp.int32),
                                 jnp.int32(i))
        out = []
        for pos in range(len(prompt), len(prompt) + max_new):
            tok = int(jnp.argmax(logits[0, -1].astype(jnp.float32)))
            out.append(tok)
            logits, cache = step(params, cache,
                                 jnp.asarray([[tok]], jnp.int32),
                                 jnp.int32(pos))
        outs.append(out)
    return outs


# --------------------------------------------------------------------------
# tentpole: token identity vs the dense decode() oracle, per family
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fam", list(CFGS))
def test_engine_token_identical_to_decode_oracle(fam):
    """Ragged mixed workload (2 slots, 4 requests, chunked prefill +
    continuous batching) drains the exact greedy tokens the per-token
    dense oracle produces — for every layer-kind family."""
    cfg, params = CFGS[fam], _params(fam)
    prompts = _prompts(fam)
    max_new, max_seq = 6, 32
    want = _oracle(cfg, params, prompts, max_new, max_seq)

    eng = serve.ServeEngine(cfg, params, n_slots=2, max_seq=max_seq,
                            page_size=16, chunk_size=8)
    for p in prompts:
        eng.submit(p, max_new=max_new)
    got = [r.tokens for r in eng.drain()]
    assert got == want
    eng.cache.check_invariants()
    if fam in ("ssm", "rglru"):
        # page-free stack: recurrent state is O(1) per slot, no KV pools
        assert eng.cache.num_pages == 0
        assert eng.cache.used_pages == 0


# --------------------------------------------------------------------------
# satellite: slot-reuse hygiene
# --------------------------------------------------------------------------

def test_slot_reuse_resets_recurrent_state():
    """Retire + re-admit into the same slot must zero the slot's recurrent
    state rows (and only that slot's); check_invariants catches the leak
    when a reset is skipped."""
    cfg = CFGS["ssm"]
    pool = serve.PagedStatePool(cfg, n_slots=2, max_seq=32, page_size=16)
    assert pool.num_pages == 0
    # poison every state leaf, as if both slots had been decoding
    pool.pages = jax.tree.map(jnp.ones_like, pool.pages)
    assert pool.admit(0, 8)
    for name in ("ssm", "conv_x", "conv_B", "conv_C"):
        leaf = np.asarray(pool.pages["scan"]["b0"][name])
        assert (leaf[:, 0] == 0).all(), f"{name}: slot 0 not reset"
        assert (leaf[:, 1] == 1).all(), f"{name}: slot 1 clobbered"
    pool.check_invariants()
    pool.retire(0)
    assert pool._dirty[0]           # retired state is stale until reset
    assert pool.admit(0, 8)         # re-admission resets again
    assert not pool._dirty[0]
    pool.check_invariants()
    # an admit that skipped the reset must be caught, not decoded from
    pool._dirty[0] = True
    with pytest.raises(RuntimeError, match="stale recurrent state"):
        pool.check_invariants()


# --------------------------------------------------------------------------
# satellite: precision pin — recurrent slot state is fp32 in the live pool
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fam", ["ssm", "rglru", "hybrid"])
def test_recurrent_state_stays_fp32_through_engine(fam):
    """Under the default bf16 serving policy, the live pool's SSD state
    accumulators ('ssm') and RG-LRU hidden states ('h') are fp32 before
    AND after a full drain — the MPX fragile-spot policy holds end to end
    through the engine, not just in the spec."""
    cfg, params = CFGS[fam], _params(fam)
    eng = serve.ServeEngine(cfg, params, n_slots=2, max_seq=32,
                            page_size=16, chunk_size=8)

    def fp32_state_leaves():
        found = 0
        leaves, _ = jax.tree_util.tree_flatten_with_path(eng.cache.pages)
        for path, leaf in leaves:
            keys = [getattr(k, "key", "") for k in path]
            if any(k in ("ssm", "h") for k in keys):
                assert leaf.dtype == jnp.float32, (keys, leaf.dtype)
                found += 1
        return found

    assert fp32_state_leaves() > 0
    for p in _prompts(fam, n=3):
        eng.submit(p, max_new=4)
    eng.drain()
    assert fp32_state_leaves() > 0


# --------------------------------------------------------------------------
# satellite: actionable errors name the kind and the supported families
# --------------------------------------------------------------------------

def test_unsupported_kind_names_kind_and_families():
    cfg = ModelConfig(
        name="state-weird", family="dense",
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64, pattern=("conv",), mlp="swiglu",
        tie_embeddings=True, remat="none")
    with pytest.raises(ValueError) as ei:
        T._require_paged_support(cfg)
    msg = str(ei.value)
    assert "'conv'" in msg                      # the offending kind, named
    assert "attn" in msg and "rglru" in msg     # the supported families
    # the engine fails the same way, before any state is allocated
    with pytest.raises(ValueError, match="conv"):
        serve.ServeEngine(cfg, {}, n_slots=1, max_seq=32, page_size=16)


def test_spec_tokens_on_recurrent_names_kind_and_fix():
    """Speculative windows need paged rollback; recurrent state only moves
    forward.  The v1 cap is an engine-construction error naming the layer
    kind and the fix (spec_tokens=0)."""
    with pytest.raises(ValueError, match="rglru"):
        serve.ServeEngine(CFGS["hybrid"], _params("hybrid"), n_slots=2,
                          max_seq=32, page_size=16, spec_tokens=2)
    with pytest.raises(ValueError, match="spec_tokens=0"):
        serve.ServeEngine(CFGS["ssm"], _params("ssm"), n_slots=2,
                          max_seq=32, page_size=16, spec_tokens=1)
    # spec_tokens=0 (the named fix) constructs fine
    eng = serve.ServeEngine(CFGS["ssm"], _params("ssm"), n_slots=2,
                            max_seq=32, page_size=16, spec_tokens=0)
    assert eng.spec_tokens == 0


# --------------------------------------------------------------------------
# satellite: serving_bench arch rows, schema-pinned without running it
# --------------------------------------------------------------------------

def _load_serving_bench():
    root = pathlib.Path(__file__).resolve().parents[1]
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    import importlib
    return importlib.import_module("benchmarks.serving_bench")


def test_serving_bench_arch_rows_schema_pinned():
    sb = _load_serving_bench()
    names = sb.expected_row_names()
    for fam in ("attn", "ssm", "rglru", "hybrid"):
        assert f"serving_tok_arch_{fam}" in names
    rows = [(n, 1.0, "") for n in names]
    sb.check_rows(rows)                         # full set passes
    with pytest.raises(RuntimeError, match="drifted"):
        sb.check_rows([r for r in rows if r[0] != "serving_tok_arch_ssm"])


# --------------------------------------------------------------------------
# satellite: per-layer-kind state-bytes gauge in the Prometheus snapshot
# --------------------------------------------------------------------------

def test_state_bytes_gauge_per_layer_kind():
    """The engine registry reports where decode memory lives: KV pages
    for attention layers vs O(1) recurrent state for rglru layers, one
    labeled gauge series per kind."""
    eng = serve.ServeEngine(CFGS["hybrid"], _params("hybrid"), n_slots=2,
                            max_seq=32, page_size=16)
    snap = eng.metrics_snapshot()
    rec = snap['serve_state_bytes{kind="rglru"}']
    kv = snap['serve_state_bytes{kind="local_attn"}']
    assert rec > 0 and kv > 0
    assert 'serve_state_bytes{kind="rglru"}' in eng.prometheus()
    # pure-recurrent engines report only recurrent kinds (no pages exist)
    eng2 = serve.ServeEngine(CFGS["ssm"], _params("ssm"), n_slots=2,
                             max_seq=32, page_size=16)
    snap2 = eng2.metrics_snapshot()
    assert snap2['serve_state_bytes{kind="ssd"}'] > 0
    assert not any("attn" in k for k in snap2 if "serve_state_bytes" in k)
