"""Pipeline parallelism: GPipe over a 'pipe' axis == sequential stack.

Runs in a subprocess with 4 forced host devices (the pipe axis), checking
exact equivalence of the pipelined MLP stack against the plain loop.
"""
import os
import subprocess
import sys
import textwrap

from repro.train.pipeline import bubble_fraction

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.train.pipeline import gpipe

    S, M, B, D = 4, 8, 16, 32
    mesh = make_mesh((S,), ("pipe",))
    key = jax.random.key(0)
    # stacked stage params: (S, D, D) weight + (S, D) bias
    w = jax.random.normal(key, (S, D, D)) / D ** 0.5
    b = jax.random.normal(jax.random.key(1), (S, D)) * 0.1
    x = jax.random.normal(jax.random.key(2), (B, D))

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    piped = gpipe(stage_fn, mesh, n_microbatches=M)
    y_pipe = jax.jit(piped)({"w": w, "b": b}, x)

    y_ref = x
    for s in range(S):
        y_ref = stage_fn({"w": w[s], "b": b[s]}, y_ref)

    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    print("PIPELINE_OK")
""")


def test_gpipe_matches_sequential():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.getcwd(), timeout=480)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPELINE_OK" in r.stdout


def test_bubble_fraction():
    assert bubble_fraction(4, 8) == 3 / 11
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(8, 1) == 7 / 8
