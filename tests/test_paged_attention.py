"""Paged-attention Pallas kernel (interpret mode) vs the ragged paged oracle.

Covers the contract the serving engine relies on: identity with
``kernels.ref.paged_attention_ref`` across ragged mixed prefill+decode
batches (idle ``valid=0`` slots, sentinel page-table entries, multiple
page sizes, GQA, ``C>1`` chunks), never reading pages the scheduler never
allocated (NaN-poisoned free pages), permutation-invariance over physical
page placement (hypothesis), and — the tentpole acceptance — that
``serve_forward(use_kernel=True)`` traces with NO gathered dense
``(B, Pmax*page_size, K, D)`` intermediate.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.kernels import ref as kref
from repro.kernels.paged_attention import paged_attention
from repro.quant import ops as qops


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 2e-5


def _random_paged_case(key, b, c, h, kv, d, n_pages, page_size, pmax,
                       start, valid, dtype, permute_seed=0):
    """Build (q, pools, table) with each slot's prefix scattered into
    randomly chosen physical pages; returns NaN in every free page."""
    ks = jax.random.split(jax.random.key(key), 3)
    q = jax.random.normal(ks[0], (b, c, h, d), dtype)
    rng = np.random.default_rng(permute_seed)
    perm = rng.permutation(n_pages)
    table = np.full((b, pmax), n_pages, np.int32)        # sentinel
    used = 0
    for s in range(b):
        need = -(-(int(start[s]) + int(valid[s])) // page_size)
        table[s, :need] = perm[used:used + need]
        used += need
    # dense logical content, scattered through the table page by page
    k_dense = jax.random.normal(ks[1], (b, pmax * page_size, kv, d), dtype)
    v_dense = jax.random.normal(ks[2], (b, pmax * page_size, kv, d), dtype)
    pools_k = jnp.full((n_pages, page_size, kv, d), jnp.nan, dtype)
    pools_v = jnp.full((n_pages, page_size, kv, d), jnp.nan, dtype)
    for s in range(b):
        length = int(start[s]) + int(valid[s])
        for pg in range(-(-length // page_size)):
            lo = pg * page_size
            n = min(page_size, length - lo)
            phys = int(table[s, pg])
            pools_k = pools_k.at[phys, :n].set(k_dense[s, lo:lo + n])
            pools_v = pools_v.at[phys, :n].set(v_dense[s, lo:lo + n])
            # allocated-page tails must be benign, not NaN: probs there
            # are exactly 0 but 0 * NaN would still poison the row sum
            if n < page_size:
                pools_k = pools_k.at[phys, n:].set(0)
                pools_v = pools_v.at[phys, n:].set(0)
    return q, pools_k, pools_v, jnp.asarray(table)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("c", [1, 8])
def test_paged_kernel_vs_ref_ragged(dtype, page_size, c):
    """Mixed batch: prefill chunk, mid-stream decode, fresh decode, idle."""
    b, h, kv, d = 4, 8, 2, 32
    pmax = 6
    n_pages = 4 * pmax
    start = np.array([11, 2 * page_size + 3, 0, 0], np.int32)
    valid = np.array([c, 1, 1, 0], np.int32)
    q, pk, pv, table = _random_paged_case(
        0, b, c, h, kv, d, n_pages, page_size, pmax, start, valid, dtype)
    got = paged_attention(q, pk, pv, table, jnp.asarray(start),
                          jnp.asarray(valid), interpret=True)
    want = kref.paged_attention_ref(q, pk, pv, table, jnp.asarray(start),
                                    jnp.asarray(valid))
    got = np.asarray(got, np.float32)
    # free pages are NaN: any read of an unallocated page poisons the out
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
    # idle slot and padding chunk positions are exact zeros
    assert (got[3] == 0).all()
    if c > 1:
        assert (got[1, 1:] == 0).all() and (got[2, 1:] == 0).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ppb", [1, 2, 4])
def test_paged_kernel_pages_per_block_parity(dtype, ppb):
    """Multi-page K-blocks (pages_per_block logical pages concatenated per
    grid step — the MXU-lane-filling follow-on) are numerically identical
    to the one-page-per-step kernel and to the ragged oracle, including
    ragged tails where a block straddles a slot's length and blocks whose
    later sub-pages fall entirely past it."""
    b, c, h, kv, d = 4, 8, 8, 2, 32
    page_size, pmax = 8, 6
    n_pages = 4 * pmax
    # lengths chosen to land mid-page, mid-block and at block boundaries
    start = np.array([11, 2 * page_size + 3, 0, 0], np.int32)
    valid = np.array([c, 1, 1, 0], np.int32)
    q, pk, pv, table = _random_paged_case(
        0, b, c, h, kv, d, n_pages, page_size, pmax, start, valid, dtype)
    got = paged_attention(q, pk, pv, table, jnp.asarray(start),
                          jnp.asarray(valid), pages_per_block=ppb,
                          interpret=True)
    got = np.asarray(got, np.float32)
    assert np.isfinite(got).all()       # NaN-poisoned free pages never read
    want = kref.paged_attention_ref(q, pk, pv, table, jnp.asarray(start),
                                    jnp.asarray(valid))
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
    base = paged_attention(q, pk, pv, table, jnp.asarray(start),
                           jnp.asarray(valid), pages_per_block=1,
                           interpret=True)
    if ppb > 1 and dtype == jnp.float32:
        # widening the block changes the summation grouping, not the math
        np.testing.assert_allclose(got, np.asarray(base, np.float32),
                                   atol=1e-6, rtol=1e-6)
    assert (got[3] == 0).all()          # idle slot stays exact zeros


def test_paged_kernel_pages_per_block_clamps_to_pmax():
    """pages_per_block beyond the table width degrades to one grid step
    spanning every logical page."""
    b, c, h, kv, d, page_size, pmax = 2, 4, 4, 2, 16, 8, 4
    start = np.array([5, 9], np.int32)
    valid = np.array([c, 1], np.int32)
    q, pk, pv, table = _random_paged_case(
        1, b, c, h, kv, d, 3 * pmax, page_size, pmax, start, valid,
        jnp.float32)
    got = paged_attention(q, pk, pv, table, jnp.asarray(start),
                          jnp.asarray(valid), pages_per_block=64,
                          interpret=True)
    want = kref.paged_attention_ref(q, pk, pv, table, jnp.asarray(start),
                                    jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-5, rtol=2e-5)
    with pytest.raises(ValueError, match="pages_per_block"):
        paged_attention(q, pk, pv, table, start, valid, pages_per_block=0,
                        interpret=True)


def test_paged_kernel_gqa_and_mha():
    """K == H (no grouping) and K < H (group resident) both match."""
    b, c, d, page_size, pmax = 2, 4, 16, 8, 4
    start = np.array([5, 9], np.int32)
    valid = np.array([c, 1], np.int32)
    for h, kv in ((4, 4), (8, 2), (6, 1)):
        q, pk, pv, table = _random_paged_case(
            h, b, c, h, kv, d, 3 * pmax, page_size, pmax, start, valid,
            jnp.float32)
        got = paged_attention(q, pk, pv, table, jnp.asarray(start),
                              jnp.asarray(valid), interpret=True)
        want = kref.paged_attention_ref(q, pk, pv, table,
                                        jnp.asarray(start),
                                        jnp.asarray(valid))
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=2e-5, rtol=2e-5)


def test_paged_kernel_matches_contiguous_decode():
    """C=1 decode vs the dense ragged decode oracle — same numbers the old
    gather+decode_attention path produced."""
    b, h, kv, d, page_size, pmax = 3, 4, 2, 32, 8, 4
    lengths = np.array([1, 13, 30], np.int32)
    start, valid = lengths - 1, np.ones(b, np.int32)
    q, pk, pv, table = _random_paged_case(
        7, b, 1, h, kv, d, 2 * pmax, page_size, pmax, start, valid,
        jnp.float32)
    got = paged_attention(q, pk, pv, table, jnp.asarray(start),
                          jnp.asarray(valid), interpret=True)
    tbl = jnp.clip(table, 0, 2 * pmax - 1)
    k = jnp.nan_to_num(pk[tbl].reshape(b, pmax * page_size, kv, d))
    v = jnp.nan_to_num(pv[tbl].reshape(b, pmax * page_size, kv, d))
    want = kref.decode_attention_ref(q[:, 0], k, v, jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got[:, 0], np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-5, rtol=2e-5)


def test_paged_kernel_page_table_permutation_property():
    """Physical page placement is invisible: any permutation of the pool
    yields identical outputs (hypothesis over permutations + lengths)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    b, c, h, kv, d, page_size, pmax = 2, 4, 4, 2, 16, 8, 4
    n_pages = 3 * pmax

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           start0=st.integers(0, 3 * 8 - 4),
           valid1=st.integers(0, 4))
    def prop(seed, start0, valid1):
        start = np.array([start0, 7], np.int32)
        valid = np.array([c, valid1], np.int32)
        q, pk, pv, table = _random_paged_case(
            3, b, c, h, kv, d, n_pages, page_size, pmax, start, valid,
            jnp.float32, permute_seed=seed)
        got = paged_attention(q, pk, pv, table, jnp.asarray(start),
                              jnp.asarray(valid), interpret=True)
        want = kref.paged_attention_ref(q, pk, pv, table,
                                        jnp.asarray(start),
                                        jnp.asarray(valid))
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=2e-5, rtol=2e-5)

    prop()


# --------------------------------------------------------------------------
# quantized pools: in-kernel dequant vs the quantized ragged oracle
# --------------------------------------------------------------------------

def _quantize_case(q, pk, pv, table, fmt):
    """Quantize a `_random_paged_case`'s pools page by page.

    Allocated pages get per-(page, head) amax scales; free pages keep
    NaN values AND get NaN scales, so any kernel read of an unallocated
    page (values or sidecar) poisons the output and fails the isfinite
    assert."""
    n_pages, ps, kv, d = pk.shape
    alloc = np.unique(np.asarray(table)[np.asarray(table) < n_pages])
    qk = jnp.zeros((n_pages, ps, kv, d), fmt.storage_dtype())
    qv = jnp.zeros_like(qk)
    if fmt.kind == "float":             # NaN representable: poison pools
        qk = jnp.full_like(qk, jnp.nan)
        qv = jnp.full_like(qv, jnp.nan)
    sk = jnp.full((n_pages, kv), jnp.nan, jnp.float32)
    sv = jnp.full_like(sk, jnp.nan)
    for pg in alloc:
        pg = int(pg)
        kb = jnp.nan_to_num(pk[pg]).astype(jnp.float32)
        vb = jnp.nan_to_num(pv[pg]).astype(jnp.float32)
        ksc = qops.amax_scale(kb, fmt, axes=(0, 2))
        vsc = qops.amax_scale(vb, fmt, axes=(0, 2))
        qk = qk.at[pg].set(qops.quantize(kb, ksc[None, :, None], fmt))
        qv = qv.at[pg].set(qops.quantize(vb, vsc[None, :, None], fmt))
        sk = sk.at[pg].set(ksc)
        sv = sv.at[pg].set(vsc)
    return qk, qv, sk, sv


@pytest.mark.parametrize("fmt_name", ["i8", "f8_e4m3", "f8_e3m4"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("c", [1, 8])
def test_quantized_paged_kernel_vs_quantized_ref(fmt_name, dtype, c):
    """TENTPOLE: the kernel's in-VMEM dequant (each sub-page's (1, 1)
    blocked-VMEM scale resolved by the same index map as its values)
    matches the quantized ragged oracle across the same mixed batch the
    bf16 tests pin — prefill chunk, mid-stream decode, fresh decode,
    idle slot — and never touches an unallocated page's values OR
    scales (both NaN-poisoned)."""
    fmt = quant.resolve(fmt_name)
    b, h, kv, d = 4, 8, 2, 32
    page_size, pmax = 8, 6
    n_pages = 4 * pmax
    start = np.array([11, 2 * page_size + 3, 0, 0], np.int32)
    valid = np.array([c, 1, 1, 0], np.int32)
    q, pk, pv, table = _random_paged_case(
        0, b, c, h, kv, d, n_pages, page_size, pmax, start, valid, dtype)
    qk, qv, sk, sv = _quantize_case(q, pk, pv, table, fmt)
    got = paged_attention(q, qk, qv, table, jnp.asarray(start),
                          jnp.asarray(valid), k_scales=sk, v_scales=sv,
                          interpret=True)
    got = np.asarray(got, np.float32)
    assert np.isfinite(got).all()
    want = kref.quantized_paged_attention_ref(
        q, qk, qv, sk, sv, table, jnp.asarray(start), jnp.asarray(valid))
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
    assert (got[3] == 0).all()          # idle slot: exact zeros
    if c > 1:
        assert (got[1, 1:] == 0).all() and (got[2, 1:] == 0).all()


@pytest.mark.parametrize("ppb", [2, 4])
def test_quantized_kernel_pages_per_block_parity(ppb):
    """Multi-page K-blocks dequantize each sub-page with its OWN page's
    scale before the VMEM concatenation — parity with ppb=1 and with the
    oracle on ragged lengths that straddle block boundaries."""
    fmt = quant.I8
    b, c, h, kv, d = 4, 8, 8, 2, 32
    page_size, pmax = 8, 6
    start = np.array([11, 2 * page_size + 3, 0, 0], np.int32)
    valid = np.array([c, 1, 1, 0], np.int32)
    q, pk, pv, table = _random_paged_case(
        0, b, c, h, kv, d, 4 * pmax, page_size, pmax, start, valid,
        jnp.float32)
    qk, qv, sk, sv = _quantize_case(q, pk, pv, table, fmt)
    args = (q, qk, qv, table, jnp.asarray(start), jnp.asarray(valid))
    got = paged_attention(*args, k_scales=sk, v_scales=sv,
                          pages_per_block=ppb, interpret=True)
    base = paged_attention(*args, k_scales=sk, v_scales=sv,
                           pages_per_block=1, interpret=True)
    got = np.asarray(got, np.float32)
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, np.asarray(base, np.float32),
                               atol=1e-6, rtol=1e-6)
    want = kref.quantized_paged_attention_ref(
        q, qk, qv, sk, sv, table, jnp.asarray(start), jnp.asarray(valid))
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               atol=2e-5, rtol=2e-5)


def test_quantized_kernel_requires_both_scales():
    b, c, h, kv, d, page_size, pmax = 2, 1, 4, 2, 16, 8, 2
    start = np.array([3, 0], np.int32)
    valid = np.array([1, 0], np.int32)
    q, pk, pv, table = _random_paged_case(
        5, b, c, h, kv, d, 2 * pmax, page_size, pmax, start, valid,
        jnp.float32)
    with pytest.raises(ValueError, match="together"):
        paged_attention(q, pk, pv, table, start, valid,
                        k_scales=jnp.ones((2 * pmax, kv)), interpret=True)


# --------------------------------------------------------------------------
# acceptance: the traced serve step has no gathered dense intermediate
# --------------------------------------------------------------------------

def _serve_jaxpr(use_kernel, kv_format="bf16"):
    from repro import mpx
    from repro.configs.base import ModelConfig
    from repro.models import transformer as T

    cfg = ModelConfig(
        name="jaxpr-probe", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=64, pattern=("attn",), mlp="swiglu",
        tie_embeddings=True, remat="none")
    b, pmax, page_size = 3, 5, 8
    params = mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), cfg))
    pages = T.init_paged_cache(cfg, n_pages=b * pmax, page_size=page_size,
                               kv_format=kv_format)
    table = jnp.zeros((b, pmax), jnp.int32)
    tokens = jnp.zeros((b, 4), jnp.int32)
    start = jnp.zeros((b,), jnp.int32)
    valid = jnp.ones((b,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda p, pg, tb, tk, st, vl: T.serve_forward(
            p, cfg, pg, tb, tk, st, vl, page_size=page_size,
            use_kernel=use_kernel, kv_format=kv_format))(
        params, pages, table, tokens, start, valid)
    # the gathered contiguous view is (B, Pmax*page_size, K, D)
    dense = re.compile(r"\[3,40,2,8\]")
    return dense.search(str(jaxpr)) is not None


def test_serve_forward_use_kernel_never_gathers():
    assert _serve_jaxpr(use_kernel=False)      # probe is valid: gather path
    assert not _serve_jaxpr(use_kernel=True)   # kernel path: no dense copy


@pytest.mark.parametrize("kv_format", ["i8", "f8_e4m3"])
def test_serve_forward_quantized_kernel_never_materializes_dense(kv_format):
    """ACCEPTANCE: with a quantized KV format the kernel path still traces
    with NO (B, Pmax*page_size, K, D) aval of ANY dtype — neither a
    gathered pool copy nor a dense dequantized bf16 view (dequant happens
    block-by-block in VMEM; write-requantization touches only the
    chunk's (B, wp, page_size, K, D) pages).  The gather fallback DOES
    materialize it — which is what validates the probe."""
    assert _serve_jaxpr(use_kernel=False, kv_format=kv_format)
    assert not _serve_jaxpr(use_kernel=True, kv_format=kv_format)
