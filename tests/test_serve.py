"""repro.serve: paged cache invariants, scheduler, ragged kernel, engine
e2e, and the speculative propose/verify/commit loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import mpx, serve
from repro.configs.base import ModelConfig
from repro.kernels import ref as kref
from repro.kernels.decode_attention import decode_attention
from repro.models import transformer as T
from repro.train.steps import make_serve_step

pytestmark = pytest.mark.serve

CFG = ModelConfig(
    name="serve-test", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, pattern=("attn",), mlp="swiglu",
    tie_embeddings=True, remat="none",
)


@pytest.fixture(scope="module")
def params():
    return mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), CFG))


def ragged_prompts(n, seed=0, lo=2, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, CFG.vocab_size, int(k)).tolist()
            for k in rng.integers(lo, hi, n)]


# --------------------------------------------------------------------------
# paged cache pool
# --------------------------------------------------------------------------

def test_paged_cache_alloc_free_invariants():
    cache = serve.PagedKVCache(CFG, n_slots=4, max_seq=64, page_size=8,
                               num_pages=20)
    assert cache.free_pages == 20
    assert cache.admit(0, 17)            # 3 pages
    assert cache.admit(1, 8)             # 1 page
    assert cache.admit(2, 64)            # 8 pages
    cache.check_invariants()
    assert cache.used_pages == 12 and cache.free_pages == 8
    with pytest.raises(ValueError):      # double admission of a busy slot
        cache.admit(0, 8)
    assert not cache.admit(3, 65)        # 9 pages > 8-page table row
    assert cache.free_pages == 8         # failed admit allocates nothing
    cache.retire(0)
    cache.check_invariants()
    assert cache.free_pages == 11
    assert not cache.admit(3, 8 * 12)    # 12 pages > 11 free (pool OOM)
    assert cache.admit(3, 8 * 8)
    cache.check_invariants()
    for s in (1, 2, 3):
        cache.retire(s)
    cache.check_invariants()
    assert cache.free_pages == 20 and cache.used_pages == 0
    # table rows fully reset to the sentinel
    assert (np.asarray(cache.table_device()) == cache.sentinel).all()


def test_paged_cache_page_math():
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=64, page_size=16)
    assert cache.pages_for(1) == 1
    assert cache.pages_for(16) == 1
    assert cache.pages_for(17) == 2
    with pytest.raises(ValueError):      # max_seq must align to pages
        serve.PagedKVCache(CFG, n_slots=2, max_seq=60, page_size=16)


def test_paged_cache_invariants_raise_runtime_error():
    """check_invariants must survive ``python -O``: RuntimeError, not
    assert.  Corrupt the pool by hand and expect each violation named."""
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=32, page_size=8)
    assert cache.admit(0, 10)
    cache.check_invariants()
    page = cache._owned[0][0]
    cache._free.append(page)                       # page both owned+free
    with pytest.raises(RuntimeError, match="free and referenced"):
        cache.check_invariants()
    cache._free.remove(page)
    cache._free.pop()                              # leaked page
    with pytest.raises(RuntimeError, match="leaked page"):
        cache.check_invariants()


def test_paged_cache_truncate_bookkeeping():
    """Speculative windows write ahead (note_write) and commit back
    (truncate); the watermarks respect committed <= written <= capacity."""
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=32, page_size=8)
    assert cache.admit(0, 12)                      # 2 pages = 16 capacity
    assert cache.capacity(0) == 16
    cache.note_write(0, 4)                         # prefill chunk
    cache.truncate(0, 4)
    assert cache.slot_length(0) == 4
    cache.note_write(0, 9)                         # window: 1 + 4 drafts
    cache.truncate(0, 6)                           # 1 accepted + committed
    assert cache.slot_length(0) == 6
    cache.check_invariants()
    with pytest.raises(RuntimeError, match="roll back"):
        cache.truncate(0, 5)                       # committed never shrinks
    with pytest.raises(RuntimeError, match="beyond written"):
        cache.truncate(0, 7)                       # nothing written there
    with pytest.raises(RuntimeError, match="exceeds reserved capacity"):
        cache.note_write(0, 17)                    # past the reservation
    cache._written[0] = 17                         # corrupt: past capacity
    with pytest.raises(RuntimeError, match="length invariant"):
        cache.check_invariants()
    cache._written[0] = 6
    cache.retire(0)
    assert cache.slot_length(0) == 0
    cache.check_invariants()


def test_paged_cache_truncate_zero_accepted_tokens():
    """A fully rejected window truncates back to exactly the committed
    length — including committed length 0 (a window written before any
    prefill committed, and truncate(0) on a virgin slot)."""
    cache = serve.PagedKVCache(CFG, n_slots=1, max_seq=32, page_size=8)
    assert cache.admit(0, 12)
    cache.truncate(0, 0)                     # virgin slot: trivially legal
    assert cache.slot_length(0) == 0
    cache.note_write(0, 5)                   # window written, nothing yet
    cache.truncate(0, 0)                     # ...committed: all rejected
    assert cache.slot_length(0) == 0
    assert cache._written[0] == 0            # watermark rolled back too
    cache.check_invariants()
    cache.note_write(0, 4)
    cache.truncate(0, 4)                     # prefill commits
    cache.note_write(0, 4 + 5)               # decode window: 1 + 4 drafts
    cache.truncate(0, 4)                     # accept 0 of the window
    assert cache.slot_length(0) == 4
    cache.check_invariants()
    with pytest.raises(RuntimeError, match="roll back"):
        cache.truncate(0, 3)                 # below committed: never


def test_paged_cache_truncate_across_page_boundary():
    """A speculative window straddling a page boundary truncates back
    into the earlier page; the later page stays owned (reserved at
    admission — no page churn) and the invariants hold."""
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=32, page_size=8)
    assert cache.admit(0, 20)                # 3 pages
    cache.note_write(0, 6)
    cache.truncate(0, 6)                     # committed mid-page-0
    cache.note_write(0, 6 + 5)               # window crosses into page 1
    assert cache._written[0] == 11
    cache.truncate(0, 7)                     # accept 1: back inside page 0
    assert cache.slot_length(0) == 7
    assert len(cache._owned[0]) == 3         # pages unchanged
    cache.check_invariants()
    # the next window re-crosses the boundary over the dead positions
    cache.note_write(0, 7 + 5)
    cache.truncate(0, 12)                    # accept all: lands in page 1
    assert cache.slot_length(0) == 12
    cache.check_invariants()


def test_paged_cache_interleaved_note_write_truncate_invariants():
    """A serving-shaped interleaving of note_write/truncate across two
    slots keeps committed <= written <= capacity checkable at every
    step, and retire resets the watermarks."""
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=32, page_size=8)
    assert cache.admit(0, 17)                # 3 pages: capacity 24
    assert cache.admit(1, 8)                 # 1 page:  capacity 8
    script = [
        (0, "write", 8), (0, "trunc", 8),        # slot 0 prefill chunk
        (1, "write", 3), (1, "trunc", 3),        # slot 1 short prefill
        (0, "write", 13), (1, "write", 7),       # both write windows
        (0, "trunc", 10), (1, "trunc", 3),       # partial / zero accept
        (0, "write", 14), (0, "trunc", 14),      # full accept
        (1, "write", 8), (1, "trunc", 8),        # to exact capacity
    ]
    for slot, op, n in script:
        if op == "write":
            cache.note_write(slot, n)
        else:
            cache.truncate(slot, n)
        cache.check_invariants()
    assert cache.slot_length(0) == 14 and cache.slot_length(1) == 8
    with pytest.raises(RuntimeError, match="capacity"):
        cache.note_write(1, 9)               # past slot 1's single page
    cache.retire(0)
    cache.check_invariants()
    assert cache._written[0] == 0 and cache.slot_length(0) == 0


# --------------------------------------------------------------------------
# scheduler
# --------------------------------------------------------------------------

def test_scheduler_occupancy_ragged(params):
    """12 ragged requests through 3 slots: continuous admission keeps the
    batch full, every request completes exactly once, pages drain to zero."""
    eng = serve.ServeEngine(CFG, params, n_slots=3, max_seq=64,
                            page_size=8, chunk_size=8)
    ids = [eng.submit(p, max_new=6) for p in ragged_prompts(12, seed=3)]
    results = eng.drain()
    assert [r.request_id for r in results] == sorted(ids)
    assert all(len(r.tokens) == 6 for r in results)
    eng.cache.check_invariants()
    assert eng.cache.used_pages == 0
    assert eng.scheduler.busy_slots == 0
    # occupancy: a 4-wave ragged queue keeps most slots busy most steps
    assert 0.5 < eng.stats.mean_occupancy <= 1.0
    # every request has a TTFT and it is ordered within the step timeline
    for r in results:
        assert r.metrics.ttft is not None and r.metrics.ttft >= 0


def test_scheduler_rejects_oversized_request():
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=32, page_size=8)
    sched = serve.Scheduler(cache, chunk_size=8)
    with pytest.raises(ValueError):
        sched.submit(serve.Request(0, list(range(1, 30)), max_new=8))
    with pytest.raises(ValueError):
        serve.Request(1, [], max_new=4)          # empty prompt


def test_scheduler_rejects_duplicate_request_id():
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=32, page_size=8)
    sched = serve.Scheduler(cache, chunk_size=8)
    sched.submit(serve.Request(5, [1, 2, 3], max_new=2))
    with pytest.raises(ValueError, match="already queued or in flight"):
        sched.submit(serve.Request(5, [4, 5], max_new=2))   # still queued
    sched.admit()
    with pytest.raises(ValueError, match="already queued or in flight"):
        sched.submit(serve.Request(5, [4, 5], max_new=2))   # now in flight
    # run request 5 to completion by hand; the id is reusable afterwards
    while sched.slots[0] is not None:
        plan = sched.plan()
        sched.commit(plan, [9] * sched.n_slots)
    sched.submit(serve.Request(5, [4, 5], max_new=2))


def test_engine_rejects_duplicate_request_id(params):
    eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=32, page_size=8)
    eng.submit([1, 2, 3], max_new=2, request_id=5)
    with pytest.raises(ValueError, match="already queued"):
        eng.submit([7, 8], max_new=2, request_id=5)
    # the failed submit corrupted nothing: the original drains normally
    results = eng.drain()
    assert [r.request_id for r in results] == [5]
    assert results[0].prompt == [1, 2, 3] and len(results[0].tokens) == 2
    # results accumulate for the engine's lifetime, so a finished id is
    # also rejected — it would collide in a later drain()'s sorted output
    with pytest.raises(ValueError, match="single-use"):
        eng.submit([9, 9], max_new=2, request_id=5)
    assert eng.submit([9, 9], max_new=2) == 6    # auto ids still fine


def test_scheduler_mixed_plan_and_token_budget():
    """Decode tokens are planned first; prefill chunks are truncated to the
    remaining per-step budget.  Host-only: commit with fake sampled ids."""
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=64, page_size=8)
    sched = serve.Scheduler(cache, chunk_size=8, max_batched_tokens=10)
    from repro.serve.scheduler import DECODE, PREFILL
    sched.submit(serve.Request(0, [1, 2, 3, 4], max_new=5))
    sched.admit()
    plan = sched.plan()                      # pure prefill, fits budget
    assert plan.kind == "prefill" and plan.n_tokens == 4
    sched.commit(plan, [7, 0])               # prompt done -> first token 7
    assert sched.slots[0].out == [7]

    sched.submit(serve.Request(1, [1] * 20, max_new=2))
    sched.admit()
    plan = sched.plan()                      # mixed: decode + capped chunk
    assert plan.kind == "mixed" and not plan.decode_only
    assert plan.kinds[0] == DECODE and plan.valid[0] == 1
    assert int(plan.start[0]) == 4           # fed at the 5th position
    assert plan.kinds[1] == PREFILL
    assert plan.valid[1] == 8                # min(chunk=8, 20 left, 10-1)
    assert plan.n_tokens <= 10               # budget holds
    sched.commit(plan, [8, 0])
    assert sched.slots[0].out == [7, 8]      # decode advanced during prefill

    # budget must cover one decode token per slot
    with pytest.raises(ValueError, match="max_batched_tokens"):
        serve.Scheduler(cache, chunk_size=8, max_batched_tokens=1)


def test_decode_slot_advances_during_prefill(params):
    """A decoding slot keeps emitting while another slot is mid-prefill —
    the head-of-line stall the prefill-priority scheduler had."""
    eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64, page_size=8,
                            chunk_size=4)
    eng.submit([5, 6, 7], max_new=20)                    # rid 0: short
    eng.step()                                           # prefilled + token
    slot0 = eng.scheduler.slots[0]
    assert slot0 is not None and not slot0.prefilling
    eng.submit(list(range(1, 41)), max_new=4)            # rid 1: 40 tokens
    grew_during_prefill = 0
    while True:
        before = len(slot0.out)
        eng.step()
        slot1 = eng.scheduler.slots[1]
        if slot1 is None or not slot1.prefilling:
            break                                        # prefill finished
        assert len(slot0.out) == before + 1              # no stall
        grew_during_prefill += 1
    assert grew_during_prefill >= 5                      # 40 tokens / C=4
    assert eng.stats.mixed_steps >= grew_during_prefill
    eng.drain()


def test_engine_token_identical_on_mixed_workload(params):
    """Long + short prompts through 2 slots (multiple waves, mixed steps)
    match the PR-1-era monolithic slot loop token-for-token — decode slots
    advancing during another slot's prefill changes scheduling, not math."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, CFG.vocab_size, n).tolist()
               for n in (3, 40, 5, 28, 4, 17)]
    max_new, max_seq = 6, 64
    want = _old_slot_loop(params, prompts, max_new, max_seq)

    eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=max_seq,
                            page_size=8, chunk_size=8)
    for p in prompts:
        eng.submit(p, max_new=max_new)
    got = [r.tokens for r in eng.drain()]
    assert got == want
    s = eng.stats.summary()
    assert s["mixed_steps"] >= 1                 # stall fix actually engaged
    assert "itl_p50_s" in s and "itl_p95_s" in s
    assert s["itl_p50_s"] <= s["itl_p95_s"]
    assert s["prefill_tokens_fed"] == sum(len(p) for p in prompts)
    assert sum(eng.stats.slot_decode_tokens) + s["requests"] \
        == s["new_tokens"]


# --------------------------------------------------------------------------
# speculative decoding: proposer, window planning, verify/commit
# --------------------------------------------------------------------------

def test_ngram_proposer_prompt_lookup():
    p = serve.NGramProposer(max_ngram=3)
    # suffix [4, 5] recurs earlier; continuation follows the occurrence
    assert p.propose([1, 4, 5, 6, 7, 4, 5], 3) == [6, 7, 4]
    assert p.propose([1, 4, 5, 6, 7, 4, 5], 1) == [6]
    # the MOST RECENT earlier occurrence wins (7 follows the later [2])
    assert p.propose([2, 3, 2, 7, 2], 1) == [7]
    # no recurring suffix -> no guess; short/empty contexts -> no guess
    assert p.propose([1, 2, 3, 4], 2) == []
    assert p.propose([5], 2) == []
    assert p.propose([1, 1, 1], 0) == []
    with pytest.raises(ValueError):
        serve.NGramProposer(max_ngram=0)


def test_ngram_proposer_memoized_index_matches_stateless_scan():
    """With a request_id the proposer serves lookups from an incremental
    per-request suffix index — same drafts as the O(context) rescan, on
    append-only contexts (repetitive, so lookups actually hit)."""
    rng = np.random.default_rng(7)
    memo = serve.NGramProposer(max_ngram=3)
    fresh = serve.NGramProposer(max_ngram=3)
    for rid in range(3):
        ctx = rng.integers(1, 5, 6).tolist()
        for _ in range(40):
            got = memo.propose(ctx, 3, request_id=rid)
            want = fresh.propose(ctx, 3)
            assert got == want, (rid, ctx)
            ctx = ctx + [int(rng.integers(1, 5))]
        # the index absorbed the whole context exactly once
        assert memo._index[rid][0] == ctx[:-1]
    memo.forget(1)
    assert 1 not in memo._index and 0 in memo._index
    # a non-extension context (defensive; engine ids are single-use so
    # this shouldn't happen) rebuilds rather than serving stale drafts
    assert memo.propose([9, 8, 9], 2, request_id=0) \
        == fresh.propose([9, 8, 9], 2)
    assert memo._index[0][0] == [9, 8, 9]


def test_scheduler_threads_request_id_and_forgets_on_retire(params):
    """The engine's default proposer gets request-keyed incremental state
    and drops it when the request retires."""
    eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                            page_size=8, chunk_size=8, spec_tokens=3)
    prop = eng.proposer
    assert isinstance(prop, serve.NGramProposer)
    rids = [eng.submit([7, 8, 9] * 2, max_new=6) for _ in range(2)]
    eng.step()          # prefill (6 tokens < chunk) + first sampled token
    eng.step()          # first decode window: proposer consulted
    assert set(prop._index) <= set(rids)
    assert prop._index, "proposer never consulted with a request_id"
    eng.drain()
    assert prop._index == {}            # forgotten on retire


def test_draft_model_proposer_is_an_actionable_stub(params):
    """Satellite: the stub constructs (so wiring can be written against
    it), propose() raises naming the ROADMAP follow-on, and the engine
    surfaces the error at submit() — before pages are reserved or a
    step traces — rather than mid-step from inside Scheduler.plan."""
    stub = serve.DraftModelProposer(draft_cfg="tiny")
    assert stub.draft_cfg == "tiny"
    with pytest.raises(NotImplementedError, match="ROADMAP"):
        stub.propose([1, 2, 3], 2)
    eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=32,
                            page_size=8, spec_tokens=2, proposer=stub)
    with pytest.raises(NotImplementedError, match="NGramProposer"):
        eng.submit([1, 2, 3], max_new=2)
    # nothing was enqueued: the engine is still clean and idle
    assert not eng.scheduler.has_work
    assert eng.cache.used_pages == 0
    assert eng.drain() == []


class _FixedProposer:
    """Always proposes the same tokens (test double)."""

    def __init__(self, tokens):
        self.tokens = list(tokens)
        self.calls = []

    def propose(self, context, k):
        self.calls.append((list(context), k))
        return self.tokens[:k]


def test_scheduler_spec_window_plan_and_commit():
    """A decoding slot contributes 1 + k tokens; commit keeps the accepted
    prefix + the corrected token and truncates the cache length back."""
    from repro.serve.scheduler import DECODE
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=64, page_size=8)
    prop = _FixedProposer([50, 51, 52])
    sched = serve.Scheduler(cache, chunk_size=8, spec_tokens=3,
                            proposer=prop)
    sched.submit(serve.Request(0, [1, 2, 3], max_new=8))
    sched.admit()
    plan = sched.plan()                      # prefill: no speculation
    assert plan.n_draft == 0
    sched.commit(plan, [9, 0])
    assert sched.slots[0].out == [9]

    plan = sched.plan()                      # decode window: 1 + 3 drafts
    assert plan.kinds[0] == DECODE
    assert plan.valid[0] == 4 and plan.draft_len[0] == 3
    assert list(plan.tokens[0, :4]) == [9, 50, 51, 52]
    assert list(plan.draft[0]) == [50, 51, 52]
    # window positions 0..3 are the sampled rows
    assert list(plan.logit_idx[0]) == [0, 1, 2, 3]
    # proposer saw the full committed context
    assert prop.calls[-1] == ([1, 2, 3, 9], 3)
    assert cache._written[0] == 3 + 4        # prompt + window written

    # verifier accepted 2 of 3 drafts + corrected token 60
    out = sched.commit(plan, [60, 0], accept=[2, 0])
    assert sched.slots[0].out == [9, 50, 51, 60]
    assert sched.slots[0].length == 3 + 1 + 2  # prompt + committed + accepted
    assert cache.slot_length(0) == sched.slots[0].length  # truncated back
    assert out.emitted == [(0, 3)]

    # window is capped so the request can never exceed max_new: 4 emitted,
    # 4 remain -> k <= remaining - 1 = 3; emit all -> finished exactly at 8
    plan = sched.plan()
    assert plan.draft_len[0] == 3
    out = sched.commit(plan, [61, 0], accept=[3, 0])
    assert out.finished and len(out.finished[0][1].out) == 8
    cache.check_invariants()


def test_scheduler_spec_budget_caps_drafts():
    """Draft tokens compete for the same max_batched_tokens budget as
    prefill chunks: each decode slot's committed token is funded first,
    drafts only from the remainder."""
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=64, page_size=8)
    prop = _FixedProposer([50, 51, 52])
    sched = serve.Scheduler(cache, chunk_size=8, max_batched_tokens=3,
                            spec_tokens=3, proposer=prop)
    for rid in (0, 1):
        sched.submit(serve.Request(rid, [1, 2], max_new=6))
    sched.admit()
    while any(s is not None and s.prefilling for s in sched.slots):
        plan = sched.plan()
        assert plan.n_tokens <= 3            # budget holds on every step
        sched.commit(plan, [9, 9])
    plan = sched.plan()                      # both decoding: 2 committed
    assert plan.n_tokens <= 3                # tokens + at most 1 draft
    assert plan.n_draft <= 1
    # the window (spec_tokens + the committed token) must fit the chunk
    with pytest.raises(ValueError, match="speculative window"):
        serve.Scheduler(cache, chunk_size=3, spec_tokens=3, proposer=prop)


def test_engine_rejects_proposer_without_spec_tokens(params):
    """A proposer with spec_tokens=0 would silently never be consulted —
    the engine refuses the misconfiguration instead."""
    with pytest.raises(ValueError, match="spec_tokens"):
        serve.ServeEngine(CFG, params, n_slots=2, max_seq=32, page_size=8,
                          proposer=serve.NGramProposer())


def test_rejection_sample_greedy_exact():
    """Greedy verification accepts exactly the argmax-matching prefix and
    corrects with the argmax — window semantics, fp32 over bf16 logits."""
    v = 16
    logits = np.full((3, 4, v), -5.0, np.float32)
    argmax = [[3, 5, 7, 9], [3, 5, 7, 9], [3, 5, 7, 9]]
    for b in range(3):
        for w, t in enumerate(argmax[b]):
            logits[b, w, t] = 5.0
    draft = np.array([
        [3, 5, 7],      # all match rows 0..2 -> accept 3, bonus = row 3
        [3, 2, 7],      # row-1 mismatch -> accept 1, correct = argmax row 1
        [0, 0, 0]],     # draft_len 0 -> plain sample from row 0
        np.int32)
    draft_len = np.array([3, 3, 0], np.int32)
    accept, token = serve.rejection_sample(
        jnp.asarray(logits, jnp.bfloat16), jnp.asarray(draft),
        jnp.asarray(draft_len), jax.random.key(0), serve.SamplingParams())
    assert list(np.asarray(accept)) == [3, 1, 0]
    assert list(np.asarray(token)) == [9, 5, 3]


def test_spec_engine_greedy_token_identical_mixed_workload(params):
    """ACCEPTANCE: the greedy speculative engine is token-identical to the
    non-speculative engine on a ragged mixed prefill+decode workload —
    speculation changes step count, never output."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, CFG.vocab_size, n).tolist()
               for n in (3, 40, 5, 28, 4, 17)]

    def run(spec_tokens):
        eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                                page_size=8, chunk_size=8,
                                spec_tokens=spec_tokens)
        for p in prompts:
            eng.submit(p, max_new=6)
        toks = [r.tokens for r in eng.drain()]
        eng.cache.check_invariants()
        assert eng.cache.used_pages == 0
        return toks, eng.stats.summary()

    base, sb = run(0)
    spec, ss = run(3)
    assert spec == base
    assert sb["spec_proposed"] == 0.0        # spec_tokens=0 never proposes
    assert ss["new_tokens"] == sb["new_tokens"]
    assert ss["spec_accepted"] <= ss["spec_proposed"]
    assert 0.0 <= ss["spec_accept_rate"] <= 1.0


def test_spec_engine_repeat_workload_fewer_steps(params):
    """ACCEPTANCE: on a repeat-heavy workload the n-gram proposer cuts
    engine steps per generated token by >= 1.5x, with the acceptance rate
    and tokens-per-step surfaced in EngineStats."""
    # zeroing every block makes the residual stream exactly the last
    # token's embedding, so greedy decode repeats it forever — the
    # deterministic best case for prompt-lookup proposals
    rep = dict(params)
    rep["scan"] = jax.tree.map(jnp.zeros_like, params["scan"])
    prompt = [7, 8, 9] * 4

    def run(spec_tokens):
        eng = serve.ServeEngine(CFG, rep, n_slots=2, max_seq=128,
                                page_size=8, chunk_size=8,
                                spec_tokens=spec_tokens)
        eng.submit(prompt, max_new=32)
        toks = eng.drain()[0].tokens
        return toks, eng.stats.summary()

    t0, s0 = run(0)
    t1, s1 = run(3)
    assert t1 == t0                                  # still greedy-exact
    steps_per_tok0 = s0["steps"] / s0["new_tokens"]
    steps_per_tok1 = s1["steps"] / s1["new_tokens"]
    assert steps_per_tok0 / steps_per_tok1 >= 1.5
    assert s1["spec_accept_rate"] >= 0.8             # near-perfect lookup
    assert s1["tokens_per_step"] > s0["tokens_per_step"]
    # per-request accounting flows to RequestMetrics too
    eng = serve.ServeEngine(CFG, rep, n_slots=1, max_seq=128, page_size=8,
                            chunk_size=8, spec_tokens=3)
    eng.submit(prompt, max_new=16)
    rm = eng.drain()[0].metrics
    assert rm.proposed_tokens > 0
    assert rm.acceptance_rate is not None and rm.acceptance_rate >= 0.8


def test_spec_engine_use_kernel_token_identical(params):
    """The speculative window rides the C>1 paged-attention kernel: with
    use_kernel=True the spec engine still matches the non-spec kernel
    engine token-for-token (the window's extra masked positions cannot
    perturb earlier rows' streaming softmax)."""
    prompts = ragged_prompts(5, seed=2, lo=3, hi=12)

    def run(spec_tokens):
        eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                                page_size=8, chunk_size=8, use_kernel=True,
                                spec_tokens=spec_tokens)
        for p in prompts:
            eng.submit(p, max_new=4)
        toks = [r.tokens for r in eng.drain()]
        eng.cache.check_invariants()
        return toks

    assert run(3) == run(0)


# --------------------------------------------------------------------------
# ragged-length decode kernel vs kernels/ref.py oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_kernel_ragged_lengths_vs_ref(dtype):
    b, h, kv, d, s = 4, 8, 2, 64, 512
    q = jax.random.normal(jax.random.key(0), (b, h, d), dtype)
    k = jax.random.normal(jax.random.key(1), (b, s, kv, d), dtype)
    v = jax.random.normal(jax.random.key(2), (b, s, kv, d), dtype)
    lengths = jnp.array([1, 130, 333, 512], jnp.int32)
    got = decode_attention(q, k, v, lengths, block_k=128, interpret=True)
    want = kref.decode_attention_ref(q, k, v, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_decode_kernel_zero_length_slot_outputs_zeros():
    """An idle slot (length 0) must not poison the batch: zeros out."""
    b, h, kv, d, s = 2, 4, 2, 32, 256
    q = jax.random.normal(jax.random.key(0), (b, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (b, s, kv, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (b, s, kv, d), jnp.bfloat16)
    lengths = jnp.array([0, 100], jnp.int32)
    got = np.asarray(decode_attention(q, k, v, lengths, block_k=128,
                                      interpret=True), np.float32)
    assert (got[0] == 0).all()
    want1 = kref.decode_attention_ref(q[1:], k[1:], v[1:], 100)
    np.testing.assert_allclose(got[1], np.asarray(want1[0], np.float32),
                               atol=3e-2, rtol=3e-2)


# --------------------------------------------------------------------------
# engine on the native paged-attention kernel (interpret mode off-TPU)
# --------------------------------------------------------------------------

def test_engine_use_kernel_end_to_end(params):
    """use_kernel=True drives EVERY step (prefill chunks, decode, mixed)
    through the paged-attention kernel: requests complete, pages drain,
    and runs are deterministic.  (Token-for-token identity with the
    gather path is NOT asserted — the streaming-softmax summation order
    differs in bf16 low bits, which can flip a greedy near-tie.)"""
    prompts = ragged_prompts(5, seed=2, lo=3, hi=12)

    def run():
        eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                                page_size=8, chunk_size=8, use_kernel=True)
        for p in prompts:
            eng.submit(p, max_new=4)
        results = eng.drain()
        eng.cache.check_invariants()
        assert eng.cache.used_pages == 0
        assert all(len(r.tokens) == 4 for r in results)
        assert eng.stats.summary()["prefill_tokens_fed"] \
            == sum(len(p) for p in prompts)
        return [r.tokens for r in results]

    assert run() == run()


def test_serve_forward_kernel_matches_gather_logits(params):
    """Kernel vs gather logits agree to bf16 tolerance on a genuinely
    mixed step: one slot decoding mid-stream, one mid-prefill, one idle."""
    page_size, pmax, b = 8, 6, 3
    pages = T.init_paged_cache(CFG, n_pages=b * pmax, page_size=page_size)
    table = np.full((b, pmax), b * pmax, np.int32)
    table[0, :3] = [3, 7, 1]
    table[1, :4] = [2, 5, 9, 11]
    rng = np.random.default_rng(4)

    # populate slot 0 with an 11-token prefix via two prefill chunks
    for lo, n in ((0, 8), (8, 3)):
        toks = np.zeros((b, 8), np.int32)
        toks[0, :n] = rng.integers(1, CFG.vocab_size, n)
        _, pages = T.serve_forward(
            params, CFG, pages, jnp.asarray(table), jnp.asarray(toks),
            jnp.asarray([lo, 0, 0], jnp.int32),
            jnp.asarray([n, 0, 0], jnp.int32), page_size=page_size)

    toks = np.zeros((b, 8), np.int32)
    toks[0, 0] = 42                                      # decode @ pos 11
    toks[1, :6] = rng.integers(1, CFG.vocab_size, 6)     # prefill chunk
    args = (jnp.asarray(table), jnp.asarray(toks),
            jnp.asarray([11, 0, 0], jnp.int32),
            jnp.asarray([1, 6, 0], jnp.int32))
    lg, _ = T.serve_forward(params, CFG, pages, *args, page_size=page_size,
                            use_kernel=False)
    lk, _ = T.serve_forward(params, CFG, pages, *args, page_size=page_size,
                            use_kernel=True)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lk, np.float32),
                               atol=3e-2, rtol=3e-2)


# --------------------------------------------------------------------------
# quantized KV cache (repro.quant): e2e logits tolerance + engine
# --------------------------------------------------------------------------

def _drive_mixed_schedule(params, kv_format, use_kernel):
    """A fixed ragged mixed prefill+decode schedule over serve_forward:
    slot 0 prefills 19 tokens in chunks then decodes 4 steps while slot 1
    prefills mid-stream and slot 2 idles.  Returns per-step (B, 1, V)
    logits — the same token schedule whatever the KV format, so logit
    deltas measure exactly the cache quantization error."""
    page_size, pmax, b = 8, 8, 3
    pages = T.init_paged_cache(CFG, n_pages=b * pmax, page_size=page_size,
                               kv_format=kv_format)
    table = np.full((b, pmax), b * pmax, np.int32)
    table[0, :4] = [3, 7, 1, 10]
    table[1, :4] = [2, 5, 9, 11]
    rng = np.random.default_rng(4)
    prompt0 = rng.integers(1, CFG.vocab_size, 19)
    prompt1 = rng.integers(1, CFG.vocab_size, 11)
    logs = []
    for lo in (0, 8, 16):                        # slot 0 chunked prefill
        n = min(8, 19 - lo)
        toks = np.zeros((b, 8), np.int32)
        toks[0, :n] = prompt0[lo:lo + n]
        lg, pages = T.serve_forward(
            params, CFG, pages, jnp.asarray(table), jnp.asarray(toks),
            jnp.asarray([lo, 0, 0], jnp.int32),
            jnp.asarray([n, 0, 0], jnp.int32), page_size=page_size,
            use_kernel=use_kernel, kv_format=kv_format)
        logs.append(np.asarray(lg, np.float32))
    for step in range(4):                        # mixed decode + prefill
        toks = np.zeros((b, 8), np.int32)
        toks[0, 0] = 42 + step                   # fixed decode token feed
        lo1 = step * 4
        n1 = max(min(4, 11 - lo1), 0)
        toks[1, :n1] = prompt1[lo1:lo1 + n1]
        lg, pages = T.serve_forward(
            params, CFG, pages, jnp.asarray(table), jnp.asarray(toks),
            jnp.asarray([19 + step, lo1, 0], jnp.int32),
            jnp.asarray([1, n1, 0], jnp.int32), page_size=page_size,
            use_kernel=use_kernel, kv_format=kv_format)
        logs.append(np.asarray(lg, np.float32))
    return logs


#: pinned max |logit delta| vs the bf16 cache on the mixed schedule
#: (measured ~0.08 for i8 / ~0.23 for fp8 against logits of scale ~0.6;
#: pinned at ~2x so real regressions trip it, bf16 noise never does)
KV_LOGIT_TOL = {"i8": 0.15, "f8_e4m3": 0.35, "f8_e3m4": 0.35}


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("kv_format", ["i8", "f8_e4m3", "f8_e3m4"])
def test_serve_forward_quantized_logits_within_pinned_tolerance(
        params, kv_format, use_kernel):
    """ACCEPTANCE: greedy decode logits with a quantized KV cache stay
    within a pinned tolerance of the bf16 baseline on a ragged mixed
    batch — prefill chunks, mid-stream decode, an idle slot — for both
    the gather fallback and the in-kernel dequant path."""
    base = _drive_mixed_schedule(params, "bf16", use_kernel)
    got = _drive_mixed_schedule(params, kv_format, use_kernel)
    worst = max(np.abs(g[:2] - bl[:2]).max() for g, bl in zip(got, base))
    assert worst <= KV_LOGIT_TOL[kv_format], worst
    # quantization is actually engaged (a passthrough would be exact)
    assert worst > 0


def test_engine_kv_i8_end_to_end(params):
    """The int8 engine serves a ragged workload end to end on both
    attention paths — with speculation on top — deterministically, with
    pool invariants intact, emitting exactly the requested tokens."""
    prompts = ragged_prompts(6, seed=9, lo=3, hi=14)

    def run(use_kernel, spec_tokens=0):
        eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                                page_size=8, chunk_size=8, kv_dtype="i8",
                                use_kernel=use_kernel,
                                spec_tokens=spec_tokens)
        for p in prompts:
            eng.submit(p, max_new=5)
        results = eng.drain()
        eng.cache.check_invariants()
        assert eng.cache.used_pages == 0
        assert all(len(r.tokens) == 5 for r in results)
        return [r.tokens for r in results]

    assert run(False) == run(False)              # deterministic
    assert run(True) == run(True)
    # speculation composes with quantization (windows write, truncate
    # rolls back, pages requantize).  Token identity with the non-spec
    # run is deliberately NOT asserted: a rejected window's writes leave
    # a requantization residue (the page's amax may have changed), so
    # quantized page content is write-history-dependent and a greedy
    # near-tie can flip — bounded by the pinned logit tolerance above,
    # but not bitwise.
    assert run(False, spec_tokens=3) == run(False, spec_tokens=3)
    # pool layout actually is int8 + sidecar
    eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=32,
                            page_size=8, kv_dtype="i8")
    leaf = eng.cache.pages["scan"]["b0"]
    assert leaf["k"].dtype == jnp.int8
    assert leaf["k_scale"].dtype == jnp.float32
    assert leaf["k_scale"].shape[-1] == CFG.n_kv_heads


def test_engine_kv_dtype_accepts_policy(params):
    """One policy string configures the serving cache: the kv= component
    flows Policy.parse -> ServeEngine -> PagedKVCache."""
    pol = mpx.Policy.parse("p=f32,c=bf16,o=bf16,kv=i8")
    eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=32,
                            page_size=8, kv_dtype=pol)
    assert eng.kv_format.name == "i8"
    assert eng.cache.kv_format.name == "i8"
    eng.submit([1, 2, 3], max_new=2)
    assert len(eng.drain()[0].tokens) == 2


# --------------------------------------------------------------------------
# sampling (fp32 policy)
# --------------------------------------------------------------------------

def test_sampling_greedy_matches_fp32_argmax():
    logits = jax.random.normal(jax.random.key(0), (4, 64), jnp.bfloat16)
    got = serve.sample_logits(logits, None, serve.SamplingParams())
    want = jnp.argmax(logits.astype(jnp.float32), axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sampling_top_k_top_p_support():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0, 10.0]], jnp.bfloat16)
    # top_k=1 and tiny top_p both collapse to the argmax whatever the key
    for sp in (serve.SamplingParams(temperature=1.0, top_k=1),
               serve.SamplingParams(temperature=1.0, top_p=1e-6)):
        for i in range(5):
            tok = serve.sample_logits(logits, jax.random.key(i), sp)
            assert int(tok[0]) == 4
    # temperature sampling stays inside the top-k support (the two top
    # logits are near-equiprobable, so 40 draws hit both w.p. ~1 - 2^-39)
    close = jnp.asarray([[0.0, 1.0, 2.0, 3.4, 3.5]], jnp.bfloat16)
    sp = serve.SamplingParams(temperature=2.0, top_k=2)
    toks = {int(serve.sample_logits(close, jax.random.key(i), sp)[0])
            for i in range(40)}
    assert toks == {3, 4}


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        serve.SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        serve.SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        serve.SamplingParams(top_k=-1)


# --------------------------------------------------------------------------
# end-to-end: engine vs the pre-refactor slot loop, token-for-token
# --------------------------------------------------------------------------

def _old_slot_loop(params, prompts, max_new, max_seq):
    """The pre-refactor examples/serve.py loop: prefill-by-decode, one
    shared monolithic cache, single wave (requests == slots)."""
    slots = len(prompts)
    serve_step = jax.jit(make_serve_step(CFG))
    cache = T.init_cache(CFG, slots, max_seq, jnp.bfloat16)
    state = [{"prompt": p, "fed": 1, "out": []} for p in prompts]
    tokens = jnp.asarray([[p[0]] for p in prompts], jnp.int32)
    pos = 0
    while any(len(s["out"]) < max_new for s in state):
        next_tok, cache = serve_step(params, cache, tokens, jnp.int32(pos))
        pos += 1
        nt = np.asarray(next_tok)
        for s, st in enumerate(state):
            if st["fed"] < len(st["prompt"]):          # still prefilling
                tokens = tokens.at[s, 0].set(st["prompt"][st["fed"]])
                st["fed"] += 1
            elif len(st["out"]) < max_new:             # generating
                tok = int(nt[s, 0])
                st["out"].append(tok)
                tokens = tokens.at[s, 0].set(tok)
    return [st["out"] for st in state]


def test_engine_token_identical_to_slot_loop(params):
    prompts = ragged_prompts(4, seed=0, lo=3, hi=12)
    max_new, max_seq = 8, 64
    want = _old_slot_loop(params, prompts, max_new, max_seq)

    eng = serve.ServeEngine(CFG, params, n_slots=len(prompts),
                            max_seq=max_seq, page_size=8, chunk_size=4)
    for p in prompts:
        eng.submit(p, max_new=max_new)
    got = [r.tokens for r in eng.drain()]
    assert got == want                     # token-for-token, greedy, bf16
    eng.cache.check_invariants()
    assert eng.cache.used_pages == 0
    s = eng.stats.summary()
    assert s["new_tokens"] == len(prompts) * max_new
    assert s["prefill_steps"] >= 2         # chunked: 11-token prompt, C=4


def test_engine_deterministic_across_runs(params):
    prompts = ragged_prompts(6, seed=5)

    def run():
        eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                                page_size=8, chunk_size=8)
        for p in prompts:
            eng.submit(p, max_new=5)
        return [r.tokens for r in eng.drain()]

    assert run() == run()
