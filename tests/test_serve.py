"""repro.serve: paged cache invariants, scheduler, ragged kernel, engine e2e."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import mpx, serve
from repro.configs.base import ModelConfig
from repro.kernels import ref as kref
from repro.kernels.decode_attention import decode_attention
from repro.models import transformer as T
from repro.train.steps import make_serve_step

pytestmark = pytest.mark.serve

CFG = ModelConfig(
    name="serve-test", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, pattern=("attn",), mlp="swiglu",
    tie_embeddings=True, remat="none",
)


@pytest.fixture(scope="module")
def params():
    return mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), CFG))


def ragged_prompts(n, seed=0, lo=2, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, CFG.vocab_size, int(k)).tolist()
            for k in rng.integers(lo, hi, n)]


# --------------------------------------------------------------------------
# paged cache pool
# --------------------------------------------------------------------------

def test_paged_cache_alloc_free_invariants():
    cache = serve.PagedKVCache(CFG, n_slots=4, max_seq=64, page_size=8,
                               num_pages=20)
    assert cache.free_pages == 20
    assert cache.admit(0, 17)            # 3 pages
    assert cache.admit(1, 8)             # 1 page
    assert cache.admit(2, 64)            # 8 pages
    cache.check_invariants()
    assert cache.used_pages == 12 and cache.free_pages == 8
    with pytest.raises(ValueError):      # double admission of a busy slot
        cache.admit(0, 8)
    assert not cache.admit(3, 65)        # 9 pages > 8-page table row
    assert cache.free_pages == 8         # failed admit allocates nothing
    cache.retire(0)
    cache.check_invariants()
    assert cache.free_pages == 11
    assert not cache.admit(3, 8 * 12)    # 12 pages > 11 free (pool OOM)
    assert cache.admit(3, 8 * 8)
    cache.check_invariants()
    for s in (1, 2, 3):
        cache.retire(s)
    cache.check_invariants()
    assert cache.free_pages == 20 and cache.used_pages == 0
    # table rows fully reset to the sentinel
    assert (np.asarray(cache.table_device()) == cache.sentinel).all()


def test_paged_cache_page_math():
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=64, page_size=16)
    assert cache.pages_for(1) == 1
    assert cache.pages_for(16) == 1
    assert cache.pages_for(17) == 2
    with pytest.raises(ValueError):      # max_seq must align to pages
        serve.PagedKVCache(CFG, n_slots=2, max_seq=60, page_size=16)


# --------------------------------------------------------------------------
# scheduler
# --------------------------------------------------------------------------

def test_scheduler_occupancy_ragged(params):
    """12 ragged requests through 3 slots: continuous admission keeps the
    batch full, every request completes exactly once, pages drain to zero."""
    eng = serve.ServeEngine(CFG, params, n_slots=3, max_seq=64,
                            page_size=8, chunk_size=8)
    ids = [eng.submit(p, max_new=6) for p in ragged_prompts(12, seed=3)]
    results = eng.drain()
    assert [r.request_id for r in results] == sorted(ids)
    assert all(len(r.tokens) == 6 for r in results)
    eng.cache.check_invariants()
    assert eng.cache.used_pages == 0
    assert eng.scheduler.busy_slots == 0
    # occupancy: a 4-wave ragged queue keeps most slots busy most steps
    assert 0.5 < eng.stats.mean_occupancy <= 1.0
    # every request has a TTFT and it is ordered within the step timeline
    for r in results:
        assert r.metrics.ttft is not None and r.metrics.ttft >= 0


def test_scheduler_rejects_oversized_request():
    cache = serve.PagedKVCache(CFG, n_slots=2, max_seq=32, page_size=8)
    sched = serve.Scheduler(cache, chunk_size=8)
    with pytest.raises(ValueError):
        sched.submit(serve.Request(0, list(range(1, 30)), max_new=8))
    with pytest.raises(ValueError):
        serve.Request(1, [], max_new=4)          # empty prompt


# --------------------------------------------------------------------------
# ragged-length decode kernel vs kernels/ref.py oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_kernel_ragged_lengths_vs_ref(dtype):
    b, h, kv, d, s = 4, 8, 2, 64, 512
    q = jax.random.normal(jax.random.key(0), (b, h, d), dtype)
    k = jax.random.normal(jax.random.key(1), (b, s, kv, d), dtype)
    v = jax.random.normal(jax.random.key(2), (b, s, kv, d), dtype)
    lengths = jnp.array([1, 130, 333, 512], jnp.int32)
    got = decode_attention(q, k, v, lengths, block_k=128, interpret=True)
    want = kref.decode_attention_ref(q, k, v, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_decode_kernel_zero_length_slot_outputs_zeros():
    """An idle slot (length 0) must not poison the batch: zeros out."""
    b, h, kv, d, s = 2, 4, 2, 32, 256
    q = jax.random.normal(jax.random.key(0), (b, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (b, s, kv, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (b, s, kv, d), jnp.bfloat16)
    lengths = jnp.array([0, 100], jnp.int32)
    got = np.asarray(decode_attention(q, k, v, lengths, block_k=128,
                                      interpret=True), np.float32)
    assert (got[0] == 0).all()
    want1 = kref.decode_attention_ref(q[1:], k[1:], v[1:], 100)
    np.testing.assert_allclose(got[1], np.asarray(want1[0], np.float32),
                               atol=3e-2, rtol=3e-2)


# --------------------------------------------------------------------------
# sampling (fp32 policy)
# --------------------------------------------------------------------------

def test_sampling_greedy_matches_fp32_argmax():
    logits = jax.random.normal(jax.random.key(0), (4, 64), jnp.bfloat16)
    got = serve.sample_logits(logits, None, serve.SamplingParams())
    want = jnp.argmax(logits.astype(jnp.float32), axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sampling_top_k_top_p_support():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0, 10.0]], jnp.bfloat16)
    # top_k=1 and tiny top_p both collapse to the argmax whatever the key
    for sp in (serve.SamplingParams(temperature=1.0, top_k=1),
               serve.SamplingParams(temperature=1.0, top_p=1e-6)):
        for i in range(5):
            tok = serve.sample_logits(logits, jax.random.key(i), sp)
            assert int(tok[0]) == 4
    # temperature sampling stays inside the top-k support (the two top
    # logits are near-equiprobable, so 40 draws hit both w.p. ~1 - 2^-39)
    close = jnp.asarray([[0.0, 1.0, 2.0, 3.4, 3.5]], jnp.bfloat16)
    sp = serve.SamplingParams(temperature=2.0, top_k=2)
    toks = {int(serve.sample_logits(close, jax.random.key(i), sp)[0])
            for i in range(40)}
    assert toks == {3, 4}


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        serve.SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        serve.SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        serve.SamplingParams(top_k=-1)


# --------------------------------------------------------------------------
# end-to-end: engine vs the pre-refactor slot loop, token-for-token
# --------------------------------------------------------------------------

def _old_slot_loop(params, prompts, max_new, max_seq):
    """The pre-refactor examples/serve.py loop: prefill-by-decode, one
    shared monolithic cache, single wave (requests == slots)."""
    slots = len(prompts)
    serve_step = jax.jit(make_serve_step(CFG))
    cache = T.init_cache(CFG, slots, max_seq, jnp.bfloat16)
    state = [{"prompt": p, "fed": 1, "out": []} for p in prompts]
    tokens = jnp.asarray([[p[0]] for p in prompts], jnp.int32)
    pos = 0
    while any(len(s["out"]) < max_new for s in state):
        next_tok, cache = serve_step(params, cache, tokens, jnp.int32(pos))
        pos += 1
        nt = np.asarray(next_tok)
        for s, st in enumerate(state):
            if st["fed"] < len(st["prompt"]):          # still prefilling
                tokens = tokens.at[s, 0].set(st["prompt"][st["fed"]])
                st["fed"] += 1
            elif len(st["out"]) < max_new:             # generating
                tok = int(nt[s, 0])
                st["out"].append(tok)
                tokens = tokens.at[s, 0].set(tok)
    return [st["out"] for st in state]


def test_engine_token_identical_to_slot_loop(params):
    prompts = ragged_prompts(4, seed=0, lo=3, hi=12)
    max_new, max_seq = 8, 64
    want = _old_slot_loop(params, prompts, max_new, max_seq)

    eng = serve.ServeEngine(CFG, params, n_slots=len(prompts),
                            max_seq=max_seq, page_size=8, chunk_size=4)
    for p in prompts:
        eng.submit(p, max_new=max_new)
    got = [r.tokens for r in eng.drain()]
    assert got == want                     # token-for-token, greedy, bf16
    eng.cache.check_invariants()
    assert eng.cache.used_pages == 0
    s = eng.stats.summary()
    assert s["new_tokens"] == len(prompts) * max_new
    assert s["prefill_steps"] >= 2         # chunked: 11-token prompt, C=4


def test_engine_deterministic_across_runs(params):
    prompts = ragged_prompts(6, seed=5)

    def run():
        eng = serve.ServeEngine(CFG, params, n_slots=2, max_seq=64,
                                page_size=8, chunk_size=8)
        for p in prompts:
            eng.submit(p, max_new=5)
        return [r.tokens for r in eng.drain()]

    assert run() == run()
