"""repro.quant — sub-bf16 quantized KV-cache storage for serving.

The MPX policy machinery, applied to the inference side: the KV cache's
storage precision becomes a policy component (``Policy.parse("p=f32,
c=bf16,o=bf16,kv=i8")``, ``ServeEngine(kv_dtype="i8")``) instead of a
bf16 constant baked into the page pools.  Decode is HBM-bound on KV page
reads (the paged-attention kernel already streams only allocated pages);
storing pages in int8 or fp8 with per-page/per-head amax scales halves
the remaining bytes per cached token, and the scales ride in a tiny fp32
sidecar pool that the kernel multiplies back onto K/V blocks *in VMEM* —
the dense bf16 view of the cache is never materialized.

- :mod:`~repro.quant.formats`   — :class:`KVFormat` registry (``bf16``
  passthrough, ``i8``, ``f8_e4m3``, ``f8_e3m4``; fp8 emulated exactly in
  bf16 off-TPU) and the pool+sidecar container layout (:func:`pool_spec`)
- :mod:`~repro.quant.ops`       — write-quantize (:func:`quantized_paged_write`:
  gather the touched pages, splice, fresh amax, requantize) and the one
  dequant rule (:func:`dequantize`) shared by kernel and oracle
- :mod:`~repro.quant.reference` — loop-based reference numerics the
  vectorized ops are tested against
"""
from repro.quant.formats import (BF16, F8_E3M4, F8_E4M3, FORMATS, I8,
                                 KVFormat, canonical_name, pool_spec,
                                 resolve)
from repro.quant.ops import (amax_scale, dequantize, max_write_pages,
                             quantize, quantized_paged_write,
                             quantized_pool_write)

__all__ = [
    "BF16",
    "F8_E3M4",
    "F8_E4M3",
    "FORMATS",
    "I8",
    "KVFormat",
    "amax_scale",
    "canonical_name",
    "dequantize",
    "max_write_pages",
    "pool_spec",
    "quantize",
    "quantized_paged_write",
    "quantized_pool_write",
    "resolve",
]
