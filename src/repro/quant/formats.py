"""Sub-bf16 KV storage formats: the dtype half of the ``kv=`` policy axis.

MPX treats precision as a *policy* threaded through a pipeline, not a
property baked into arrays.  On the serving side the policy axis that
matters is the KV cache: decode is HBM-bound on KV page reads (the paged
kernel already streams only allocated pages), so the next lever is the
*bytes per cached token*.  A :class:`KVFormat` names one storage format
for the paged KV pools:

- ``bf16``     — the passthrough baseline (2 bytes/elem, no scales);
- ``i8``       — symmetric int8 with per-page, per-head amax scales
                 (1 byte/elem + a tiny fp32 scale sidecar);
- ``f8_e4m3``  — fp8 e4m3 (4-bit exponent, 3-bit mantissa, max 448);
- ``f8_e3m4``  — fp8 e3m4 (3-bit exponent, 4-bit mantissa, max 15.5 —
                 one more mantissa bit for amax-scaled tensors whose
                 dynamic range the scale already absorbed).

Quantized formats store values *scaled into the format's representable
range*: ``scale = amax / fmax`` per (page, kv-head), ``q = round(x /
scale)`` on the format's value grid, ``x~ = q * scale`` on read.  The
scales live in a small fp32 sidecar pool (``(num_pages, n_kv_heads)``
per K and V pool — see :func:`pool_spec`), and dequantization happens
*inside* the paged-attention kernel, so the dense bf16 view of the cache
is never materialized.

Off-TPU the fp8 formats are **emulated in bf16**: every fp8 value is
exactly representable in bf16 (3- or 4-bit mantissa into bf16's 7, 3- or
4-bit exponent range inside bf16's 8), so rounding through the fp8 dtype
and storing the result in a bf16 pool is bit-identical in value to native
fp8 storage — the numerics are the TPU numerics, only the HBM bytes
differ (which is why the benchmark's HBM accounting uses
:attr:`KVFormat.itemsize`, not the emulation dtype's).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class KVFormat:
    """One KV-cache storage format (hashable; jit-static).

    ``name`` is the canonical spelling (what ``Policy.parse`` normalizes
    ``kv=`` values to); ``kind`` is ``"none"`` (bf16 passthrough),
    ``"int"`` or ``"float"``; ``fmax`` the largest representable
    magnitude on the format's value grid; ``grid_dtype`` the dtype whose
    value grid quantization rounds to; ``itemsize`` the HBM bytes per
    element in *native* storage (1 for int8/fp8 — the quantity the
    serving trajectory tracks, independent of off-TPU emulation).
    """
    name: str
    kind: str
    fmax: float
    grid_dtype: Any
    itemsize: int

    @property
    def quantized(self) -> bool:
        return self.kind != "none"

    def storage_dtype(self, backend: str = None):
        """The dtype of the page pool arrays.

        int8 stores natively everywhere.  fp8 stores natively on TPU and
        as exact bf16 emulation elsewhere (fp8 values are a subset of
        bf16, so emulation is value-identical — see module docstring).
        """
        if self.kind == "none":
            return jnp.bfloat16
        if self.kind == "int":
            return jnp.int8
        if backend is None:
            backend = jax.default_backend()
        return self.grid_dtype if backend == "tpu" else jnp.bfloat16

    def __str__(self) -> str:
        return self.name


#: bf16 passthrough — the PR-1..4 serving layout, no scales.
BF16 = KVFormat("bf16", "none", 0.0, jnp.bfloat16, 2)
#: symmetric int8, per-page/per-head amax scales.
I8 = KVFormat("i8", "int", 127.0, jnp.int8, 1)
#: fp8 e4m3 (finite-only fn variant): max 448, 3-bit mantissa.
F8_E4M3 = KVFormat("f8_e4m3", "float", 448.0, jnp.float8_e4m3fn, 1)
#: fp8 e3m4: max 15.5, 4-bit mantissa — finer grid, narrower range.
F8_E3M4 = KVFormat("f8_e3m4", "float", 15.5, jnp.float8_e3m4, 1)

FORMATS = {f.name: f for f in (BF16, I8, F8_E4M3, F8_E3M4)}

_ALIASES = {
    "bfloat16": "bf16",
    "int8": "i8",
    "fp8": "f8_e4m3",
    "f8": "f8_e4m3",
    "f8e4m3": "f8_e4m3",
    "e4m3": "f8_e4m3",
    "f8e3m4": "f8_e3m4",
    "e3m4": "f8_e3m4",
}


def resolve(fmt: Union[str, KVFormat, None]) -> KVFormat:
    """A :class:`KVFormat` from a name/alias (``None`` -> bf16)."""
    if fmt is None:
        return BF16
    if isinstance(fmt, KVFormat):
        return fmt
    key = str(fmt).strip().lower()
    key = _ALIASES.get(key, key)
    if key not in FORMATS:
        raise ValueError(
            f"unknown KV format {fmt!r}; known: "
            f"{sorted(FORMATS) + sorted(_ALIASES)}")
    return FORMATS[key]


def canonical_name(fmt: Union[str, KVFormat, None]) -> str:
    """Canonical format name (what ``Policy.kv_dtype`` stores)."""
    return resolve(fmt).name


def pool_spec(n_pages: int, page_size: int, n_kv_heads: int, head_dim: int,
              fmt: Union[str, KVFormat], dtype=jnp.bfloat16) -> dict:
    """Abstract paged K/V pool container for one attention layer.

    bf16 passthrough: ``{"k", "v"}`` pools of ``dtype`` — the PR-3
    layout, unchanged.  Quantized formats add the fp32 scale sidecar:
    ``{"k", "v", "k_scale", "v_scale"}`` with the pools in the format's
    storage dtype and ``(n_pages, n_kv_heads)`` scales (one amax scale
    per page per kv head — K rows of one page share a head's scale, so
    the sidecar is ~``page_size * head_dim * itemsize / 4`` times smaller
    than the pool it describes).
    """
    fmt = resolve(fmt)
    shape = (n_pages, page_size, n_kv_heads, head_dim)
    if not fmt.quantized:
        return {"k": jax.ShapeDtypeStruct(shape, dtype),
                "v": jax.ShapeDtypeStruct(shape, dtype)}
    sdt = fmt.storage_dtype()
    sc = jax.ShapeDtypeStruct((n_pages, n_kv_heads), jnp.float32)
    return {"k": jax.ShapeDtypeStruct(shape, sdt),
            "v": jax.ShapeDtypeStruct(shape, sdt),
            "k_scale": sc, "v_scale": sc}
