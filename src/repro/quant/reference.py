"""Loop-based reference numerics for the quant ops (test oracles).

Deliberately naive: numpy loops over slots/tokens/pages, one page at a
time, mirroring the *contract* of :mod:`repro.quant.ops` (write-quantize
with per-page/per-head amax requantization, read-dequantize) without any
of the vectorized gather/scatter machinery.  ``tests/test_quant.py``
asserts the vectorized ops match these exactly.
"""
from __future__ import annotations

from typing import Union

import numpy as np

import jax.numpy as jnp

from repro.quant.formats import KVFormat, resolve
from repro.quant.ops import SCALE_FLOOR


def quantize_ref(x: np.ndarray, scale: float,
                 fmt: Union[str, KVFormat]) -> np.ndarray:
    """Scalar-scale quantization of one group, loop-reference semantics.

    int8 is pure numpy.  The fp8 grid cast goes through the SAME jnp
    primitive the op uses: XLA's CPU fp8 cast double-rounds through f16
    at exact grid midpoints (a ~half-ulp tie-break difference from
    ml_dtypes' numpy cast on a handful of values), and this reference
    exists to pin the paging/amax/requantization *contract* — the
    rounding primitive itself is covered by the round-trip error-bound
    tests, which hold under either tie-break.
    """
    fmt = resolve(fmt)
    scaled = np.clip(np.asarray(x, np.float32) / np.float32(scale),
                     -fmt.fmax, fmt.fmax)
    if fmt.kind == "int":
        return np.rint(scaled).astype(np.int8)
    return np.asarray(jnp.asarray(scaled).astype(fmt.grid_dtype)
                      .astype(jnp.float32))


def dequantize_ref(q: np.ndarray, scale: float) -> np.ndarray:
    return np.asarray(q, np.float32) * np.float32(scale)


def roundtrip_ref(x: np.ndarray, fmt: Union[str, KVFormat]) -> np.ndarray:
    """amax-scale -> quantize -> dequantize one group (fp32 out)."""
    fmt = resolve(fmt)
    scale = max(float(np.max(np.abs(np.asarray(x, np.float32)))) / fmt.fmax,
                SCALE_FLOOR)
    return dequantize_ref(quantize_ref(x, scale, fmt), scale)


def quantized_paged_write_ref(pages: np.ndarray, scales: np.ndarray,
                              vals: np.ndarray, page_table: np.ndarray,
                              positions: np.ndarray, valid: np.ndarray, *,
                              page_size: int, fmt: Union[str, KVFormat],
                              ) -> tuple[np.ndarray, np.ndarray]:
    """Token-at-a-time reference of :func:`repro.quant.ops
    .quantized_paged_write`: dequantize the touched page, splice, fresh
    amax per (page, head), requantize — in plain loops.

    Returns ``(pages, scales)`` with the pages' *grid values* as fp32
    (int levels for i8, fp8-grid values for the float formats) — compare
    against the vectorized op's pages via ``.astype(float32)``.
    """
    fmt = resolve(fmt)
    n_pages, ps, n_kv, d = pages.shape
    pages = np.asarray(jnp.asarray(pages).astype(jnp.float32)).copy()
    scales = np.asarray(scales, np.float32).copy()
    b, c = positions.shape

    # dequantized image of every touched page, keyed by physical index;
    # rows at positions >= the owning slot's write end are zeroed (they
    # are unreachable through the slot's length mask and may hold a
    # prior tenant's or a rejected window's stale values — the fresh
    # amax must not see them)
    touched: dict[int, np.ndarray] = {}
    for s in range(b):
        end = int(positions[s, 0]) + int(valid[s])
        for t in range(int(valid[s])):
            pos = int(positions[s, t])
            logical = pos // ps
            phys = int(page_table[s, logical])
            if phys >= n_pages:
                continue
            if phys not in touched:
                x = pages[phys] * scales[phys][None, :, None]
                for r in range(ps):
                    if logical * ps + r >= end:
                        x[r] = 0.0
                touched[phys] = x
            touched[phys][pos % ps] = np.asarray(vals[s, t], np.float32)

    for phys, x in touched.items():
        for h in range(n_kv):
            amax = float(np.max(np.abs(x[:, h])))
            scale = max(amax / fmt.fmax, SCALE_FLOOR)
            q = quantize_ref(x[:, h], scale, fmt)
            pages[phys][:, h] = np.asarray(q, np.float32)
            scales[phys, h] = scale
    return pages, scales
