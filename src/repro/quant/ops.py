"""Quantize/dequantize ops for the paged KV cache (write-quantize,
read-dequantize).

The serving contract is asymmetric:

- **Writes quantize.**  :func:`quantized_paged_write` scatters a chunk's
  new K/V values into the quantized page pools.  Because the scale is
  per *page* (per kv head) and pages fill incrementally — a decode step
  appends one token to a partially-filled page — a write is a
  read-modify-write of exactly the pages the chunk touches: gather those
  pages, dequantize with their current scales, splice the new bf16
  values in, recompute the page's amax, requantize the whole page with
  the new scale, scatter pages + scales back.  Untouched pages keep
  their bits and scales verbatim.  The number of touched pages per slot
  is a *static* function of the chunk width (a C-token contiguous range
  straddles at most ``(C - 1) // page_size + 2`` pages), so the gather
  stays a fixed tiny multiple of the chunk size — never the pool, never
  a slot's whole prefix.

- **Reads dequantize in the consumer.**  The paged-attention kernel
  multiplies the scales back onto K/V blocks in VMEM
  (:mod:`repro.kernels.paged_attention`); the gather fallback uses
  :func:`dequantize` on the gathered view.  Dequantization is the same
  two ops everywhere — ``q.astype(f32) * scale``, cast to the compute
  dtype — so kernel and oracle agree exactly.

Requantization error: re-rounding a page's existing values on each write
adds at most half an ulp *of the dequantized value* per write, and the
page's scale only changes when a new amax enters — bounded, and pinned by
the round-trip tests in ``tests/test_quant.py``.
"""
from __future__ import annotations

from typing import Union

import jax.numpy as jnp

from repro.quant.formats import KVFormat, resolve

#: scale floor — keeps ``x / scale`` finite for all-zero pages without
#: perturbing any real amax (bf16 subnormals bottom out ~1e-38).
SCALE_FLOOR = 1e-30


def quantize(x: jnp.ndarray, scale: jnp.ndarray,
             fmt: Union[str, KVFormat]) -> jnp.ndarray:
    """``x`` (any float) -> values on ``fmt``'s grid in its storage dtype.

    ``scale`` broadcasts against ``x`` (fp32).  int8 rounds to nearest
    (ties to even) and clips to ±127; fp8 rounds through the fp8 dtype
    (RTNE) after a ±fmax clip (e3m4 would otherwise overflow to inf on
    a half-ulp-above-max round).
    """
    fmt = resolve(fmt)
    if not fmt.quantized:
        raise ValueError(f"{fmt.name} is a passthrough format")
    scaled = x.astype(jnp.float32) / scale
    scaled = jnp.clip(scaled, -fmt.fmax, fmt.fmax)
    if fmt.kind == "int":
        return jnp.rint(scaled).astype(jnp.int8)
    return scaled.astype(fmt.grid_dtype).astype(fmt.storage_dtype())


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, out_dtype=jnp.float32,
               ) -> jnp.ndarray:
    """``q * scale`` in fp32, cast to ``out_dtype`` — THE dequant rule.

    The paged-attention kernel applies exactly this per K/V block in
    VMEM; keeping one definition makes kernel-vs-oracle comparisons
    meaningful at tight tolerances.
    """
    return (q.astype(jnp.float32) * scale).astype(out_dtype)


def amax_scale(x: jnp.ndarray, fmt: Union[str, KVFormat],
               axes) -> jnp.ndarray:
    """Per-group symmetric scale: ``max|x| / fmax`` over ``axes``,
    floored so a group of zeros quantizes (to zeros) without dividing
    by zero."""
    fmt = resolve(fmt)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes)
    return jnp.maximum(amax / fmt.fmax, SCALE_FLOOR)


def max_write_pages(chunk: int, page_size: int, pmax: int) -> int:
    """Pages a ``chunk``-token contiguous positional range can straddle."""
    return min((max(chunk, 1) - 1) // page_size + 2, pmax)


def quantized_paged_write(pages: jnp.ndarray, scales: jnp.ndarray,
                          vals: jnp.ndarray, page_table: jnp.ndarray,
                          positions: jnp.ndarray, valid: jnp.ndarray, *,
                          page_size: int, fmt: Union[str, KVFormat],
                          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantizing scatter of ``vals`` (B, C, K, D) into ``pages``
    (P, ps, K, D) with the ``(P, K)`` fp32 ``scales`` sidecar.

    ``positions`` (B, C) are absolute token positions (``positions[:, 0]``
    is the slot's chunk start, the serving layout), ``valid`` (B,) the
    real-token counts (0 = idle slot).  Touched pages are requantized
    with a fresh per-page/per-head amax; padding tokens, idle slots and
    sentinel table entries drop out of the scatter exactly like
    :func:`repro.nn.attention.paged_write`.  Returns ``(pages, scales)``
    with untouched pages bit-identical.

    Rows of a touched page at positions **at or beyond the slot's write
    end** (``start + valid``) are zeroed before the amax: attention can
    never read them (it masks by position), but they can hold garbage a
    previous *tenant* of the physical page left behind (``retire()``
    frees pages without clearing the device pool) or a rejected
    speculative tail — either would silently inflate the fresh amax and
    crush the live rows' precision.  Zeroing them makes a page's scale a
    function of exactly the values that are reachable through it.

    Ownership contract: this is a whole-page **read-modify-write** —
    every touched page is dequantized, merged and requantized against a
    fresh amax, so even rows this call doesn't write change bit pattern
    (same values, new scale).  A physical page shared across slots via
    prefix caching must therefore be copied-on-write *before* the
    requantizing scatter reaches it — not merely before its rows
    diverge — and the copy must carry the page's ``scales`` sidecar row
    along with the values.  ``PagedStatePool`` enforces exactly this
    (COW queued at admission boundaries and in ``note_write``, flushed
    before the device step); callers going around the pool must not
    target pages with refcount > 1.
    """
    fmt = resolve(fmt)
    n_pages = pages.shape[0]
    b, c = positions.shape
    ps = page_size
    pmax = page_table.shape[1]
    wp = max_write_pages(c, ps, pmax)

    start = positions[:, 0]
    first = start // ps                                        # (B,)
    last = (start + jnp.maximum(valid, 1) - 1) // ps
    j = jnp.arange(wp)[None, :]                                # (1, wp)
    logical = first[:, None] + j                               # (B, wp)
    live = (j <= (last - first)[:, None]) & (valid[:, None] > 0)
    phys = jnp.take_along_axis(page_table,
                               jnp.clip(logical, 0, pmax - 1), axis=1)
    phys = jnp.where(live, phys, n_pages)          # dead/sentinel -> OOB
    safe = jnp.clip(phys, 0, n_pages - 1)

    # gather the touched pages, dequantize with their current scales
    cur = pages[safe]                              # (B, wp, ps, K, D)
    cur_s = scales[safe]                           # (B, wp, K)
    x = dequantize(cur, cur_s[:, :, None, :, None])
    kd = x.shape[3:]

    # splice the chunk's new values in at page-local positions
    local = positions - (first * ps)[:, None]                  # (B, C)
    ok = jnp.arange(c)[None, :] < valid[:, None]
    local = jnp.where(ok, local, wp * ps)                      # OOB -> drop
    x = x.reshape((b, wp * ps) + kd)
    x = x.at[jnp.arange(b)[:, None], local].set(
        vals.astype(jnp.float32), mode="drop")
    # zero rows past the slot's write end: unreachable through THIS
    # slot's length mask, but possibly stale (prior tenant of a reused
    # page, rejected speculative tail) — they must not feed the amax
    row_pos = (first * ps)[:, None] + jnp.arange(wp * ps)[None, :]
    reachable = row_pos < (start + valid)[:, None]             # (B, wp*ps)
    x = jnp.where(reachable[(...,) + (None,) * len(kd)], x, 0.0)
    x = x.reshape((b, wp, ps) + kd)

    # fresh per-(page, head) amax over the whole page, requantize
    new_s = amax_scale(x, fmt, axes=(2, 4))                    # (B, wp, K)
    q = quantize(x, new_s[:, :, None, :, None], fmt)

    flat = phys.reshape(-1)
    pages = pages.at[flat].set(q.reshape((-1, ps) + kd), mode="drop")
    scales = scales.at[flat].set(
        new_s.astype(jnp.float32).reshape(-1, kd[0]), mode="drop")
    return pages, scales


def quantized_pool_write(pool: dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                         page_table: jnp.ndarray, positions: jnp.ndarray,
                         valid: jnp.ndarray, *, page_size: int,
                         fmt: Union[str, KVFormat]) -> dict:
    """One attention layer's write step: quantize K and V chunks into the
    ``{"k", "v", "k_scale", "v_scale"}`` container."""
    k, ks = quantized_paged_write(pool["k"], pool["k_scale"], k_new,
                                  page_table, positions, valid,
                                  page_size=page_size, fmt=fmt)
    v, vs = quantized_paged_write(pool["v"], pool["v_scale"], v_new,
                                  page_table, positions, valid,
                                  page_size=page_size, fmt=fmt)
    return {"k": k, "v": v, "k_scale": ks, "v_scale": vs}
