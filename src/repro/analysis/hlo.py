"""Compiled-HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` exposes FLOPs and HBM bytes of the *partitioned*
(per-device) module, but not collective traffic.  :func:`collective_bytes`
parses the compiled HLO text and sums the result-shape bytes of every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op (per device, matching cost_analysis semantics).

:func:`roofline` combines the three terms against TPU v5e constants:
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# -- hardware constants (TPU v5e) ------------------------------------------
PEAK_FLOPS = 197e12          # bf16 MXU, per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result can be a plain shape `f32[8,128]{1,0}` or a tuple of shapes
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind byte totals + counts from compiled HLO text."""
    stats = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # avoid double-counting async start/done pairs
        stats[kind]["bytes"] += _shape_bytes(shape_str)
        stats[kind]["count"] += 1
    return stats


def collective_bytes(hlo_text: str) -> int:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    chips: int
    model_flops: Optional[float] = None   # 6·N·D (global, per step)

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time estimate: max of the three overlapped terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / total compiled FLOPs (remat/redundancy waste)."""
        if not self.model_flops:
            return None
        return self.model_flops / max(self.flops_per_dev * self.chips, 1.0)

    @property
    def mfu(self) -> Optional[float]:
        """Roofline-implied model-FLOPs utilization at the step estimate."""
        if not self.model_flops:
            return None
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / self.step_s

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "mfu": self.mfu,
        }


def model_flops_per_step(n_params: int, tokens: int, kind: str = "train",
                         active_params: Optional[int] = None) -> float:
    """6·N·D for training (fwd+bwd), 2·N·D for inference forward."""
    n = active_params if active_params is not None else n_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions.

    Old jax wraps the properties dict in a one-element list.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def roofline_from_compiled(compiled, chips: int,
                           model_flops: Optional[float] = None) -> Roofline:
    cost = cost_dict(compiled)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(flops, byts, float(coll), chips, model_flops)
