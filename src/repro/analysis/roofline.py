"""Roofline report generator: results/dryrun/*.json -> markdown tables.

Run after the dry-run matrix:
    PYTHONPATH=src python -m repro.analysis.roofline [--mesh 16x16]
Prints the §Roofline table (all three terms, dominant bottleneck, model
FLOPs, usefulness ratio, roofline MFU) and the §Dry-run memory table.
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

ARCH_ORDER = ["llama3-8b", "gemma2-2b", "starcoder2-3b", "qwen1.5-32b",
              "mixtral-8x7b", "phi3.5-moe-42b-a6.6b", "recurrentgemma-9b",
              "hubert-xlarge", "phi-3-vision-4.2b", "mamba2-130m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, optimized: bool = False) -> list[dict]:
    recs = []
    for p in sorted(RESULTS.glob(f"*@{mesh}.json")):
        if p.name.startswith("OPT_") != optimized:
            continue
        recs.append(json.loads(p.read_text()))
    recs.sort(key=lambda r: (ARCH_ORDER.index(r["arch"])
                             if r["arch"] in ARCH_ORDER else 99,
                             SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 99))
    return recs


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 0.1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "MODEL_FLOPS | useful | MFU |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:70]
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — |")
            lines[-1] = lines[-1][:-1] + f" {reason} |" if False else lines[-1]
            continue
        rf = r["roofline"]
        useful = rf.get("useful_ratio")
        mfu = rf.get("mfu")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['dominant']} | {rf['model_flops']:.2e} | "
            f"{useful*100:.0f}% | {mfu*100:.1f}% |"
            if useful else
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['dominant']} | — | — | — |")
    return "\n".join(lines)


def memory_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | args GiB/dev | temp GiB/dev | total | fits 16G? |"
        " collectives |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | {r.get('reason','')[:50]} |")
            continue
        m = r["memory"]
        a, t = m["argument_bytes"] / 2**30, m["temp_bytes"] / 2**30
        tot = a + t
        colls = ", ".join(f"{k}×{v['count']}"
                          for k, v in r.get("collectives", {}).items())
        lines.append(f"| {r['arch']} | {r['shape']} | {a:.2f} | {t:.2f} | "
                     f"{tot:.2f} | {'YES' if tot <= 16 else 'no'} | "
                     f"{colls} |")
    return "\n".join(lines)


def perf_table(mesh: str) -> str:
    """Before/after for the hillclimbed cells (OPT_*.json vs baseline)."""
    opt = {(r["arch"], r["shape"]): r for r in load(mesh, optimized=True)}
    if not opt:
        return "(no optimized cells recorded)"
    base = {(r["arch"], r["shape"]): r for r in load(mesh)}
    lines = ["| cell | variant | compute | memory | collective | MFU | "
             "fits 16G? |", "|---|---|---|---|---|---|---|"]
    for key, ro in opt.items():
        for tag, r in (("baseline", base.get(key)), ("optimized", ro)):
            if r is None or r["status"] != "ok":
                continue
            rf, m = r["roofline"], r["memory"]
            tot = (m["argument_bytes"] + m["temp_bytes"]) / 2**30
            lines.append(
                f"| {key[0]} {key[1]} | {tag} | {fmt_s(rf['compute_s'])} | "
                f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
                f"{rf['mfu']*100:.1f}% | "
                f"{'YES' if tot <= 16 else f'{tot:.0f}GiB'} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    recs = load(args.mesh)
    print(f"## Roofline — mesh {args.mesh} ({len(recs)} cells)\n")
    print(roofline_table(recs))
    print(f"\n## Memory / dry-run — mesh {args.mesh}\n")
    print(memory_table(recs))
    print(f"\n## Hillclimbed cells — mesh {args.mesh}\n")
    print(perf_table(args.mesh))


if __name__ == "__main__":
    main()
