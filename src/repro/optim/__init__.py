from repro.optim.optimizers import adamw, sgd, adafactor, make_optimizer
from repro.optim.schedules import (constant_schedule, cosine_schedule,
                                   linear_warmup_cosine)
from repro.optim.clip import clip_by_global_norm, global_norm

__all__ = ["adamw", "sgd", "adafactor", "make_optimizer",
           "constant_schedule", "cosine_schedule", "linear_warmup_cosine",
           "clip_by_global_norm", "global_norm"]
