"""Learning-rate schedules (pure functions of an int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.asarray(lr, jnp.float32) * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(lr, max(total_steps - warmup_steps, 1), final_frac)

    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.asarray(lr, jnp.float32) * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm,
                         cos(jnp.maximum(step - warmup_steps, 0)))
    return fn
