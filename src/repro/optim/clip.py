"""Global-norm gradient clipping (fp32 accumulation, as always)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.filtering import is_inexact_array


def global_norm(tree) -> jax.Array:
    leaves = [x for x in jax.tree.leaves(tree) if is_inexact_array(x)]
    if not leaves:
        return jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def clip_by_global_norm(tree, max_norm: float):
    """Scale the whole tree so its global norm is <= max_norm.

    Non-finite norms leave the tree untouched (the loss-scaling machinery
    owns the skip decision; clipping must not turn an inf gradient into a
    NaN-free lie).
    """
    norm = global_norm(tree)
    scale = jnp.where(jnp.isfinite(norm),
                      jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9)),
                      1.0)
    return jax.tree.map(
        lambda x: x * scale.astype(x.dtype) if is_inexact_array(x) else x,
        tree), norm
