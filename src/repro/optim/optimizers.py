"""Optimizers (optax ``init``/``update`` protocol, built from scratch).

All state and arithmetic are fp32 — this is the "master weights + master
moments" half of mixed-precision training; the half-precision half lives in
``mpx.filter_value_and_grad``.  ``update`` returns *updates* to be applied
via ``mpx.apply_updates`` (or guarded via ``mpx.optimizer_update``).

- :func:`adamw`     — decoupled weight decay, bias-corrected moments.
- :func:`sgd`       — momentum SGD.
- :func:`adafactor` — factored second moments for memory-constrained runs
  (row/col statistics for rank-2+ params), a standard large-scale trick.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.filtering import is_inexact_array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params=None) -> (updates, new_state)


def _treemap(f, *trees):
    """Map over inexact leaves; None / static leaves pass through as None.

    Keeps optimizers compatible with Equinox-style model pytrees where
    ``filter_grad`` leaves ``None`` holes at non-differentiable leaves
    (paper Example 2) as well as with pure array-dict framework models.
    """
    return jax.tree.map(
        lambda *xs: f(*xs) if (xs[0] is not None
                               and is_inexact_array(xs[0])) else None,
        *trees, is_leaf=lambda x: x is None)


def _zeros_like_f32(tree):
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32)
        if is_inexact_array(x) else None, tree)


def adamw(learning_rate=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          schedule: Optional[Callable] = None) -> Optimizer:
    lr_fn = schedule or (lambda step: jnp.asarray(learning_rate, jnp.float32))

    def init(params):
        return {"mu": _zeros_like_f32(params), "nu": _zeros_like_f32(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        count = state["count"] + 1
        mu = _treemap(lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      grads, state["mu"])
        nu = _treemap(lambda g, v: b2 * v + (1 - b2) *
                      jnp.square(g.astype(jnp.float32)), grads, state["nu"])
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        lr = lr_fn(count)

        def _upd(m, v, p):
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay > 0 and p is not None:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        if params is not None and weight_decay > 0:
            updates = jax.tree.map(
                lambda m, v, p: _upd(m, v, p)
                if (m is not None and is_inexact_array(m)) else None,
                mu, nu, params, is_leaf=lambda x: x is None)
        else:
            updates = _treemap(lambda m, v: _upd(m, v, None), mu, nu)
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init, update)


def sgd(learning_rate=1e-2, momentum=0.9,
        schedule: Optional[Callable] = None) -> Optimizer:
    lr_fn = schedule or (lambda step: jnp.asarray(learning_rate, jnp.float32))

    def init(params):
        return {"mu": _zeros_like_f32(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        del params
        count = state["count"] + 1
        mu = _treemap(lambda g, m: momentum * m + g.astype(jnp.float32),
                      grads, state["mu"])
        lr = lr_fn(count)
        updates = _treemap(lambda m: -lr * m, mu)
        return updates, {"mu": mu, "count": count}

    return Optimizer(init, update)


def adafactor(learning_rate=1e-3, decay=0.8, eps=1e-30,
              schedule: Optional[Callable] = None) -> Optimizer:
    """Factored second moments: O(n+m) state for an (n,m) matrix instead of
    O(n·m) — the memory-term lever for the largest configs (qwen 32B)."""
    lr_fn = schedule or (lambda step: jnp.asarray(learning_rate, jnp.float32))

    def _factored(x):
        return is_inexact_array(x) and x.ndim >= 2

    def init(params):
        def _state(x):
            if not is_inexact_array(x):
                return None
            if _factored(x):
                return {"row": jnp.zeros(x.shape[:-1], jnp.float32),
                        "col": jnp.zeros(x.shape[:-2] + x.shape[-1:],
                                         jnp.float32)}
            return {"v": jnp.zeros(x.shape, jnp.float32)}
        return {"stats": jax.tree.map(_state, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        del params
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay
        lr = lr_fn(count)

        def _upd(g, st):
            if g is None or not is_inexact_array(g):
                return None, st
            g32 = g.astype(jnp.float32)
            sq = jnp.square(g32) + eps
            if "row" in st:
                row = beta * st["row"] + (1 - beta) * sq.mean(axis=-1)
                col = beta * st["col"] + (1 - beta) * sq.mean(axis=-2)
                rfac = row / jnp.maximum(row.mean(axis=-1, keepdims=True), eps)
                prec = (rfac[..., None] * col[..., None, :]) ** -0.5
                return -lr * g32 * prec, {"row": row, "col": col}
            v = beta * st["v"] + (1 - beta) * sq
            return -lr * g32 * v ** -0.5, {"v": v}

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["stats"])
        out = [_upd(g, s) for g, s in zip(flat_g, flat_s)]
        updates = jax.tree.unflatten(treedef, [u for u, _ in out])
        stats = jax.tree.unflatten(treedef, [s for _, s in out])
        return updates, {"stats": stats, "count": count}

    return Optimizer(init, update)


def make_optimizer(run_cfg) -> Optimizer:
    """Build the optimizer named in a RunConfig."""
    if run_cfg.optimizer == "adamw":
        return adamw(run_cfg.learning_rate, run_cfg.beta1, run_cfg.beta2,
                     weight_decay=run_cfg.weight_decay)
    if run_cfg.optimizer == "sgd":
        return sgd(run_cfg.learning_rate)
    if run_cfg.optimizer == "adafactor":
        return adafactor(run_cfg.learning_rate)
    raise ValueError(f"unknown optimizer {run_cfg.optimizer!r}")
