"""Dynamic loss scaling — Section 3.3 of the MPX paper.

:class:`DynamicLossScaling` implements the Micikevicius et al. (2017)
heuristic:

- multiply the loss by ``scaling`` before differentiation so small gradients
  survive fp16's limited range,
- after the backward pass divide the gradients by ``scaling`` (in fp32),
- if any gradient is non-finite: halve ``scaling`` (clamped at ``min_scaling``)
  and signal the optimizer to skip the step,
- after ``period`` consecutive finite steps: double ``scaling`` (clamped at
  ``max_scaling``).

The object is registered as a JAX pytree (dynamic leaves: ``scaling`` and
``counter``; static aux: the hyper-parameters), so it can live inside jitted
train steps, be donated, and be replicated across a mesh — the property the
paper gets from inheriting ``eqx.Module``, reproduced here without Equinox.

Also exported: :class:`NoOpLossScaling` with the same interface (scale=1,
never skips), letting full-precision and bf16-without-scaling pipelines run
through the identical train-step code path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.filtering import is_float_array, is_inexact_array

PyTree = Any


@jax.tree_util.register_pytree_node_class
class DynamicLossScaling:
    """Pytree-compatible dynamic loss scaling state + transition rules."""

    def __init__(self, loss_scaling=2.0 ** 15, *, counter=None,
                 period: int = 2000, factor: float = 2.0,
                 min_loss_scaling: float = 1.0,
                 max_loss_scaling: float = 2.0 ** 24):
        self.loss_scaling = jnp.asarray(loss_scaling, jnp.float32)
        self.counter = (jnp.asarray(counter, jnp.int32)
                        if counter is not None else jnp.zeros((), jnp.int32))
        self.period = int(period)
        self.factor = float(factor)
        self.min_loss_scaling = float(min_loss_scaling)
        self.max_loss_scaling = float(max_loss_scaling)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        children = (self.loss_scaling, self.counter)
        aux = (self.period, self.factor, self.min_loss_scaling,
               self.max_loss_scaling)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        loss_scaling, counter = children
        period, factor, min_ls, max_ls = aux
        obj = cls.__new__(cls)
        obj.loss_scaling = loss_scaling
        obj.counter = counter
        obj.period = period
        obj.factor = factor
        obj.min_loss_scaling = min_ls
        obj.max_loss_scaling = max_ls
        return obj

    # -- paper API ---------------------------------------------------------
    def scale(self, tree: PyTree) -> PyTree:
        """Multiply every floating leaf by the current scaling factor."""
        s = self.loss_scaling
        return jax.tree.map(
            lambda x: x * s.astype(x.dtype) if is_float_array(x) else x, tree)

    def unscale(self, tree: PyTree) -> PyTree:
        """Divide every floating leaf by the scaling and cast to fp32.

        The cast-to-fp32 *before* the divide is deliberate (paper step 4→5):
        scaled fp16 grads may sit near the top of fp16's range; converting
        first makes the divide exact and the result a full-precision
        gradient ready for the optimizer.
        """
        inv = (1.0 / self.loss_scaling).astype(jnp.float32)
        return jax.tree.map(
            lambda x: x.astype(jnp.float32) * inv if is_float_array(x) else x,
            tree)

    def adjust(self, grads_finite: jax.Array) -> "DynamicLossScaling":
        """Return updated scaling state given this step's finiteness bit."""
        grown = self.counter + 1 >= self.period
        # on finite step: maybe grow; on overflow: shrink and reset counter
        new_scale = jnp.where(
            grads_finite,
            jnp.where(grown,
                      jnp.minimum(self.loss_scaling * self.factor,
                                  self.max_loss_scaling),
                      self.loss_scaling),
            jnp.maximum(self.loss_scaling / self.factor,
                        self.min_loss_scaling),
        )
        new_counter = jnp.where(
            grads_finite & ~grown, self.counter + 1, jnp.zeros((), jnp.int32))
        return DynamicLossScaling(
            new_scale, counter=new_counter, period=self.period,
            factor=self.factor, min_loss_scaling=self.min_loss_scaling,
            max_loss_scaling=self.max_loss_scaling)

    def telemetry(self) -> dict:
        """Host-side view of the scaling state for ``repro.obs``.

        Transfers two scalars (scale, consecutive-finite counter) — call
        at logging cadence, never inside the jitted step; feed the dict
        to :meth:`repro.obs.precision.PrecisionStats.record_step` /
        ``record_scaling`` to build the §3.3 trajectory.
        """
        return {"loss_scale": float(self.loss_scaling),
                "counter": int(self.counter)}

    def __repr__(self):
        return (f"DynamicLossScaling(scaling={self.loss_scaling}, "
                f"counter={self.counter}, period={self.period}, "
                f"factor={self.factor})")


@jax.tree_util.register_pytree_node_class
class NoOpLossScaling:
    """Identity scaling: same interface, scale 1, never adjusts.

    Lets a single train-step implementation serve full-precision and
    bf16-no-scaling configurations with zero overhead (XLA folds the
    multiply-by-one away).
    """

    @property
    def loss_scaling(self):
        """Identity scale factor, materialized lazily.

        A class-level ``jnp.float32(1.0)`` would allocate a device buffer
        at *import* time — on the default device, before any user code can
        set ``jax.default_device`` (or pick a backend at all).  Computing
        it on access keeps the attribute contract (train steps read
        ``scaling.loss_scaling`` for metrics) without touching a device at
        import; under jit it folds to a constant exactly like the class
        attribute did.
        """
        return jnp.float32(1.0)

    def tree_flatten(self):
        return (), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls()

    def scale(self, tree: PyTree) -> PyTree:
        return tree

    def unscale(self, tree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda x: x.astype(jnp.float32) if is_float_array(x) else x, tree)

    def adjust(self, grads_finite: jax.Array) -> "NoOpLossScaling":
        del grads_finite
        return self

    def telemetry(self) -> dict:
        """Same shape as :meth:`DynamicLossScaling.telemetry` (scale 1,
        no counter) so observability code needs no isinstance checks —
        and no device transfer here."""
        return {"loss_scale": 1.0, "counter": 0}


def all_finite(tree: PyTree) -> jax.Array:
    """Scalar bool: every element of every inexact leaf is finite.

    This is the reduction MPX performs between unscale and the optimizer
    step.  On a sharded tree XLA lowers it to a tree of local reductions
    plus one tiny all-reduce — see ``repro/kernels/unscale_finite.py`` for
    the fused Pallas version used on the hot path.
    """
    leaves = [x for x in jax.tree.leaves(tree) if is_inexact_array(x)]
    if not leaves:
        return jnp.asarray(True)
    finite = [jnp.all(jnp.isfinite(x)) for x in leaves]
    return jnp.stack(finite).all()
