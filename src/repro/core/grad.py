"""Mixed-precision gradient transforms — Section 3.4 of the MPX paper.

``filter_grad`` / ``filter_value_and_grad`` are drop-in replacements for the
Equinox gradient transforms that add mixed precision + dynamic loss scaling.
The transformed function, applied to ``(model, *args, **kwargs)``:

1. casts all inputs (model and batch) to half precision,
2. runs the original forward + loss,
3. multiplies the loss by the current scaling factor,
4. differentiates w.r.t. the *inexact array leaves of the first argument*
   (master fp32 parameters — the half-precision cast is inside the
   differentiated graph, so cotangents flow back through it and arrive
   already converted to fp32),
5. unscales the gradients (divide by scaling, in fp32),
6. reduces an ``all-finite`` bit over the gradients,
7. adjusts the loss-scaling state,
8. returns ``(new_scaling, grads_finite, grads[, aux])`` —
   ``filter_value_and_grad`` inserts the (unscaled, fp32) loss value before
   the gradients.

With ``use_mixed_precision=False`` the same code path degrades gracefully to
plain full-precision differentiation with the identical return signature, so
a pipeline can be A/B'd by flipping one flag (this is what the paper's
fp32-vs-mixed figures do).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.casting import cast_to_half_precision, half_dtype, cast_tree
from repro.core.filtering import combine, is_inexact_array, partition
from repro.core.loss_scaling import DynamicLossScaling, NoOpLossScaling, all_finite

PyTree = Any


def filter_value_and_grad(func, scaling, *, has_aux: bool = False,
                          use_mixed_precision: bool = True,
                          compute_dtype=None):
    """Mixed-precision ``value_and_grad`` with dynamic loss scaling.

    Args:
      func: ``func(model, *args, **kwargs) -> loss`` or ``(loss, aux)``.
      scaling: a :class:`DynamicLossScaling` (or ``NoOpLossScaling``).
      has_aux: whether ``func`` returns ``(loss, aux)``.
      use_mixed_precision: disable to get a full-precision pipeline with the
        same return signature.
      compute_dtype: override the half dtype for this transform (defaults to
        the global ``mpx.half_dtype()``).

    Returns a function returning
    ``(new_scaling, grads_finite, value, grads)`` (+ ``aux`` appended to
    ``value`` as ``(value, aux)`` when ``has_aux``).
    """
    cdtype = compute_dtype if compute_dtype is not None else None

    @functools.wraps(func)
    def transformed(model, *args, **kwargs):
        diff, static = partition(model, is_inexact_array)

        def scaled_loss_fn(diff_part, *a, **kw):
            m = combine(diff_part, static)
            if use_mixed_precision:
                dt = cdtype if cdtype is not None else half_dtype()
                m = cast_tree(m, dt)
                a = cast_tree(a, dt)
                kw = cast_tree(kw, dt)
            out = func(m, *a, **kw)
            loss, aux = (out if has_aux else (out, None))
            scaled = scaling.scale(loss)
            return scaled, (loss, aux)

        grad_fn = jax.value_and_grad(scaled_loss_fn, has_aux=True)
        (_, (loss, aux)), grads = grad_fn(diff, *args, **kwargs)

        grads = scaling.unscale(grads)           # fp32 grads, original scale
        grads_finite = all_finite(grads)
        new_scaling = scaling.adjust(grads_finite)
        value = loss.astype(jnp.float32)
        if has_aux:
            return new_scaling, grads_finite, (value, aux), grads
        return new_scaling, grads_finite, value, grads

    return transformed


def filter_grad(func, scaling, *, has_aux: bool = False,
                use_mixed_precision: bool = True, compute_dtype=None):
    """Gradient-only variant: returns ``(new_scaling, grads_finite, grads[, aux])``.

    Mirrors the paper's Example 2::

        loss_scaling, grads_finite, grads = mpx.filter_grad(loss, loss_scaling)(
            model, batch)
    """
    vag = filter_value_and_grad(func, scaling, has_aux=has_aux,
                                use_mixed_precision=use_mixed_precision,
                                compute_dtype=compute_dtype)

    @functools.wraps(func)
    def transformed(model, *args, **kwargs):
        out = vag(model, *args, **kwargs)
        new_scaling, grads_finite, value, grads = out
        if has_aux:
            _, aux = value
            return new_scaling, grads_finite, grads, aux
        return new_scaling, grads_finite, grads

    return transformed
