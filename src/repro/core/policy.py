"""Precision policies (JMP-style), the knob the framework layers consume.

A :class:`Policy` names three dtypes:

- ``param_dtype``   — storage dtype of the master parameters (fp32 in mixed
  precision training; the optimizer always updates these),
- ``compute_dtype`` — dtype the forward/backward pass runs in,
- ``output_dtype``  — dtype activations/losses are returned in.

``Policy.cast_to_compute(tree)`` etc. apply :func:`repro.core.casting.cast_tree`.
Policies parse from compact strings, e.g.::

    Policy.parse("params=float32,compute=bfloat16,output=float32")
    Policy.parse("p=f32,c=bf16,o=f32")          # aliases
    Policy.parse("f32")                          # uniform full precision

The framework default for the TPU target is ``MIXED_BF16``; ``MIXED_F16``
reproduces the paper's GPU configuration (and is what turns dynamic loss
scaling from a safety net into a necessity).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.casting import cast_tree

_DTYPE_ALIASES = {
    "f32": jnp.float32, "float32": jnp.float32,
    "f16": jnp.float16, "float16": jnp.float16, "half": jnp.float16,
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
    "f64": jnp.float64, "float64": jnp.float64,
}

_FIELD_ALIASES = {
    "p": "param_dtype", "params": "param_dtype", "param": "param_dtype",
    "c": "compute_dtype", "compute": "compute_dtype",
    "o": "output_dtype", "output": "output_dtype",
}


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.bfloat16
    output_dtype: object = jnp.float32

    # -- casting helpers ---------------------------------------------------
    def cast_to_param(self, tree):
        return cast_tree(tree, self.param_dtype)

    def cast_to_compute(self, tree):
        return cast_tree(tree, self.compute_dtype)

    def cast_to_output(self, tree):
        return cast_tree(tree, self.output_dtype)

    # -- properties --------------------------------------------------------
    @property
    def is_mixed(self) -> bool:
        return jnp.dtype(self.compute_dtype) != jnp.dtype(self.param_dtype)

    @property
    def needs_loss_scaling(self) -> bool:
        """fp16's 5-bit exponent underflows small grads; bf16 does not."""
        return jnp.dtype(self.compute_dtype) == jnp.dtype(jnp.float16)

    def __str__(self) -> str:
        n = lambda d: jnp.dtype(d).name
        return (f"params={n(self.param_dtype)},compute={n(self.compute_dtype)},"
                f"output={n(self.output_dtype)}")

    # -- parsing -----------------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> "Policy":
        spec = spec.strip().lower()
        if "=" not in spec:  # uniform, e.g. "f32" / "bf16"
            if spec in ("mixed", "mixed_bf16"):
                return MIXED_BF16
            if spec == "mixed_f16":
                return MIXED_F16
            d = _DTYPE_ALIASES[spec]
            return cls(param_dtype=d, compute_dtype=d, output_dtype=d)
        kwargs = {}
        for part in spec.split(","):
            key, _, val = part.partition("=")
            field = _FIELD_ALIASES[key.strip()]
            kwargs[field] = _DTYPE_ALIASES[val.strip()]
        return cls(**kwargs)


#: TPU-native mixed precision (DESIGN.md §3): fp32 master, bf16 compute.
MIXED_BF16 = Policy(jnp.float32, jnp.bfloat16, jnp.float32)
#: Paper-faithful GPU mixed precision: fp32 master, fp16 compute (+ scaling).
MIXED_F16 = Policy(jnp.float32, jnp.float16, jnp.float32)
#: Full-precision baseline (the thing the paper's figures compare against).
FULL_F32 = Policy(jnp.float32, jnp.float32, jnp.float32)
