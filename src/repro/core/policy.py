"""Precision policies (JMP-style), the knob the framework layers consume.

A :class:`Policy` names three dtypes and one serving-side storage format:

- ``param_dtype``   — storage dtype of the master parameters (fp32 in mixed
  precision training; the optimizer always updates these),
- ``compute_dtype`` — dtype the forward/backward pass runs in,
- ``output_dtype``  — dtype activations/losses are returned in,
- ``kv_dtype``      — storage format of the serving KV-cache pages
  (``repro.quant`` format name: "bf16" passthrough, "i8", "f8_e4m3",
  "f8_e3m4").  Inference-side only; training never consults it.

``Policy.cast_to_compute(tree)`` etc. apply :func:`repro.core.casting.cast_tree`.
Policies parse from compact strings, e.g.::

    Policy.parse("params=float32,compute=bfloat16,output=float32")
    Policy.parse("p=f32,c=bf16,o=f32")          # aliases
    Policy.parse("p=f32,c=bf16,o=bf16,kv=i8")   # int8 serving KV cache
    Policy.parse("f32")                          # uniform full precision

The framework default for the TPU target is ``MIXED_BF16``; ``MIXED_F16``
reproduces the paper's GPU configuration (and is what turns dynamic loss
scaling from a safety net into a necessity).  The ``kv=`` component is
what ``ServeEngine(kv_dtype=...)`` consumes — precision as a policy
threaded through the pipeline, training and serving alike.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.casting import cast_tree

_DTYPE_ALIASES = {
    "f32": jnp.float32, "float32": jnp.float32,
    "f16": jnp.float16, "float16": jnp.float16, "half": jnp.float16,
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
    "f64": jnp.float64, "float64": jnp.float64,
}

_FIELD_ALIASES = {
    "p": "param_dtype", "params": "param_dtype", "param": "param_dtype",
    "c": "compute_dtype", "compute": "compute_dtype",
    "o": "output_dtype", "output": "output_dtype",
    "kv": "kv_dtype", "kv_cache": "kv_dtype",
}


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.bfloat16
    output_dtype: object = jnp.float32
    #: serving KV-cache storage format (canonical ``repro.quant`` name);
    #: a string, not a jnp dtype — "i8"/"f8_*" name value grids + scale
    #: sidecars, not bare array dtypes.
    kv_dtype: str = "bf16"

    # -- casting helpers ---------------------------------------------------
    def cast_to_param(self, tree):
        return cast_tree(tree, self.param_dtype)

    def cast_to_compute(self, tree):
        return cast_tree(tree, self.compute_dtype)

    def cast_to_output(self, tree):
        return cast_tree(tree, self.output_dtype)

    # -- properties --------------------------------------------------------
    @property
    def is_mixed(self) -> bool:
        return jnp.dtype(self.compute_dtype) != jnp.dtype(self.param_dtype)

    @property
    def needs_loss_scaling(self) -> bool:
        """fp16's 5-bit exponent underflows small grads; bf16 does not."""
        return jnp.dtype(self.compute_dtype) == jnp.dtype(jnp.float16)

    def __str__(self) -> str:
        n = lambda d: jnp.dtype(d).name
        s = (f"params={n(self.param_dtype)},compute={n(self.compute_dtype)},"
             f"output={n(self.output_dtype)}")
        if self.kv_dtype != "bf16":     # baseline kv is implicit, so every
            s += f",kv={self.kv_dtype}"  # pre-quant policy string round-trips
        return s

    # -- parsing -----------------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> "Policy":
        spec = spec.strip().lower()
        if "=" not in spec:  # uniform, e.g. "f32" / "bf16"
            if spec in ("mixed", "mixed_bf16"):
                return MIXED_BF16
            if spec == "mixed_f16":
                return MIXED_F16
            d = _DTYPE_ALIASES[spec]
            return cls(param_dtype=d, compute_dtype=d, output_dtype=d)
        kwargs = {}
        for part in spec.split(","):
            key, _, val = part.partition("=")
            field = _FIELD_ALIASES[key.strip()]
            if field == "kv_dtype":
                # kv= names a quant FORMAT (value grid + scale sidecar),
                # not a bare dtype — "i8", "f8_e4m3", "f8_e3m4", "bf16"
                from repro.quant.formats import canonical_name
                kwargs[field] = canonical_name(val.strip())
            else:
                kwargs[field] = _DTYPE_ALIASES[val.strip()]
        return cls(**kwargs)


#: TPU-native mixed precision (DESIGN.md §3): fp32 master, bf16 compute.
MIXED_BF16 = Policy(jnp.float32, jnp.bfloat16, jnp.float32)
#: Paper-faithful GPU mixed precision: fp32 master, fp16 compute (+ scaling).
MIXED_F16 = Policy(jnp.float32, jnp.float16, jnp.float32)
#: Full-precision baseline (the thing the paper's figures compare against).
FULL_F32 = Policy(jnp.float32, jnp.float32, jnp.float32)
