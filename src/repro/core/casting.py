"""PyTree casting — Section 3.1 of the MPX paper.

``cast_tree(tree, dtype)`` casts every *floating point array* leaf of an
arbitrary pytree to ``dtype``; all other leaves — integer arrays, PRNG keys,
bools, python scalars, arbitrary static objects — pass through untouched.
The paper calls out PRNG keys explicitly: accidentally casting them corrupts
the random stream, so the predicate excludes them.

Convenience casts mirror the paper's API:
``cast_to_half_precision`` / ``cast_to_float16`` / ``cast_to_bfloat16`` /
``cast_to_float32``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.filtering import is_float_array

PyTree = Any

#: The half-precision dtype used by ``cast_to_half_precision``.  bfloat16 is
#: the TPU-native choice (see DESIGN.md §3); switch to float16 for strict
#: paper-fidelity on GPU-style hardware via ``set_half_dtype``.
_HALF_DTYPE = jnp.bfloat16


def set_half_dtype(dtype) -> None:
    """Set the global half-precision dtype (jnp.float16 or jnp.bfloat16)."""
    global _HALF_DTYPE
    dtype = jnp.dtype(dtype)
    if dtype not in (jnp.dtype(jnp.float16), jnp.dtype(jnp.bfloat16)):
        raise ValueError(f"half dtype must be float16 or bfloat16, got {dtype}")
    _HALF_DTYPE = dtype


def half_dtype():
    return _HALF_DTYPE


def cast_leaf(x: Any, dtype) -> Any:
    """Cast a single leaf if it is a floating-point array, else passthrough."""
    if is_float_array(x):
        return x.astype(dtype) if x.dtype != jnp.dtype(dtype) else x
    return x


def cast_tree(tree: PyTree, dtype) -> PyTree:
    """Cast all floating-point array leaves of ``tree`` to ``dtype``.

    Integer arrays (e.g. token ids), boolean masks and PRNG keys are left
    unchanged — casting them would be a correctness bug, not a precision
    choice.  Non-array leaves (static fields) also pass through, so this
    works on Equinox-style module pytrees, Flax param dicts, and plain
    containers alike.
    """
    return jax.tree.map(lambda x: cast_leaf(x, dtype), tree)


def cast_to_float16(tree: PyTree) -> PyTree:
    return cast_tree(tree, jnp.float16)


def cast_to_bfloat16(tree: PyTree) -> PyTree:
    return cast_tree(tree, jnp.bfloat16)


def cast_to_float32(tree: PyTree) -> PyTree:
    return cast_tree(tree, jnp.float32)


def cast_to_half_precision(tree: PyTree) -> PyTree:
    """Cast to the globally-configured half dtype (default bfloat16)."""
    return cast_tree(tree, _HALF_DTYPE)


def cast_function(func, dtype, return_dtype=None):
    """Section 3.2: wrap ``func`` so all inputs are cast to ``dtype``.

    Returns a new function that casts every argument pytree to ``dtype``,
    invokes ``func``, and (optionally) casts outputs to ``return_dtype``.
    Because JAX type promotion keeps weakly-typed constants on the left of
    the lattice, the body then executes in ``dtype``.
    """

    def wrapped(*args, **kwargs):
        args = cast_tree(args, dtype)
        kwargs = cast_tree(kwargs, dtype)
        out = func(*args, **kwargs)
        if return_dtype is not None:
            out = cast_tree(out, return_dtype)
        return out

    wrapped.__name__ = getattr(func, "__name__", "cast_function")
    return wrapped


def force_full_precision(func, return_dtype=None):
    """Section 3.2: run ``func`` in float32 regardless of input precision.

    The canonical MPX guard for overflow/precision-critical ops — softmax,
    sum, mean, variance, layer norm statistics, logit softcaps.  Inputs are
    upcast to float32, the body runs in fp32, and outputs are cast to
    ``return_dtype`` (pass the incoming dtype to drop back to half
    precision, or ``None`` to keep fp32 outputs).
    """
    return cast_function(func, jnp.float32, return_dtype=return_dtype)
