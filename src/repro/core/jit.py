"""``filter_jit`` — jit for pytrees that mix arrays and static leaves.

Equinox pipelines (the paper's Example 2 wraps its train step in
``eqx.filter_jit``) freely carry static metadata — strings, ints, callables —
inside model pytrees.  ``jax.jit`` rejects those.  ``filter_jit`` partitions
every argument into (arrays, static), traces a jitted function of the array
part only, and caches one executable per distinct static part.

Static leaves must be hashable for caching; unhashable static leaves fall
back to tracing on every call (correct, slower, warned once).
"""
from __future__ import annotations

import functools
import warnings
from typing import Any

import jax

from repro.core.filtering import combine, is_array, partition

_CACHE: dict[Any, Any] = {}


def filter_jit(func=None, **jit_kwargs):
    """Drop-in ``jax.jit`` that tolerates non-array pytree leaves."""
    if func is None:
        return functools.partial(filter_jit, **jit_kwargs)

    @functools.wraps(func)
    def wrapper(*args, **kwargs):
        dynamic, static = partition((args, kwargs), is_array)
        static_leaves, static_def = jax.tree.flatten(static)
        try:
            key = (func, static_def, tuple(static_leaves))
            hash(key)
        except TypeError:
            warnings.warn("filter_jit: unhashable static leaf; re-tracing "
                          "every call", stacklevel=2)
            key = None

        def call(dyn):
            a, kw = combine(dyn, static)
            return func(*a, **kw)

        if key is None:
            return jax.jit(call, **jit_kwargs)(dynamic)
        if key not in _CACHE:
            _CACHE[key] = jax.jit(call, **jit_kwargs)
        return _CACHE[key](dynamic)

    return wrapper
