"""Equinox-free pytree filtering.

MPX (the paper) leans on Equinox's ``filter_*`` machinery to differentiate
with respect to *inexact array leaves only* while carrying every other leaf
(ints, bools, PRNG keys, static configuration) through untouched.  Equinox is
not available in this environment, so this module rebuilds the minimal core:

- predicates: ``is_array``, ``is_inexact_array``
- ``partition(tree, pred)``   -> (filtered, static) two trees with ``None``
  holes, such that ``combine(filtered, static) == tree``
- ``combine(*trees)``         -> merge trees filling ``None`` holes
- ``select_tree(pred, a, b)`` -> elementwise jnp.where on matching pytrees
  (used by the loss-scaling optimizer guard)

All functions treat ``None`` as an empty subtree (JAX default).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def is_array(x: Any) -> bool:
    """True for JAX and NumPy arrays (not python scalars)."""
    return isinstance(x, (jax.Array, np.ndarray))


def is_inexact_array(x: Any) -> bool:
    """True for floating-point (or complex) array leaves.

    PRNG typed keys report an ``issubdtype`` of ``prng_key`` — they are
    explicitly excluded, as are integer and boolean arrays.  This is the
    predicate MPX casts / differentiates by.
    """
    if not is_array(x):
        return False
    if jnp.issubdtype(x.dtype, jax.dtypes.prng_key):
        return False
    return jnp.issubdtype(x.dtype, jnp.inexact)


def is_float_array(x: Any) -> bool:
    """True for real floating-point array leaves (complex excluded)."""
    return is_array(x) and not jnp.issubdtype(x.dtype, jax.dtypes.prng_key) \
        and jnp.issubdtype(x.dtype, jnp.floating)


def partition(tree: PyTree, pred: Callable[[Any], bool] = is_inexact_array,
              ) -> tuple[PyTree, PyTree]:
    """Split ``tree`` into (dynamic, static) by a leaf predicate.

    Both outputs have the same structure as ``tree`` with ``None`` at the
    positions claimed by the other side.  ``combine`` is the inverse.
    """
    dynamic = jax.tree.map(lambda x: x if pred(x) else None, tree)
    static = jax.tree.map(lambda x: None if pred(x) else x, tree)
    return dynamic, static


def combine(*trees: PyTree) -> PyTree:
    """Merge trees produced by :func:`partition` (first non-None wins)."""

    def _merge(*leaves):
        for leaf in leaves:
            if leaf is not None:
                return leaf
        return None

    return jax.tree.map(_merge, *trees, is_leaf=lambda x: x is None)


def select_tree(pred: jax.Array, true_tree: PyTree, false_tree: PyTree) -> PyTree:
    """``jnp.where(pred, a, b)`` over matching pytrees (pred is a scalar bool).

    Non-array leaves must be identical in both trees and are passed through.
    This is the primitive behind ``mpx.optimizer_update``'s skip-on-inf logic.
    """

    def _sel(a, b):
        if is_array(a) or is_array(b):
            return jnp.where(pred, a, b)
        return a

    return jax.tree.map(_sel, true_tree, false_tree)


def tree_size_bytes(tree: PyTree) -> int:
    """Total bytes of all array leaves (host-side accounting helper)."""
    leaves = [x for x in jax.tree.leaves(tree) if is_array(x)]
    return int(sum(x.size * x.dtype.itemsize for x in leaves))
