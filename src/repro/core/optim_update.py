"""Loss-scaling-aware optimizer step — Section 3.5 of the MPX paper.

``optimizer_update(model, optimizer, optimizer_state, grads, grads_finite)``
replaces the usual ``optimizer.update(...)`` + ``apply_updates(...)`` pair
and applies the update *only when the gradients are finite* — the skipped
step is how dynamic loss scaling recovers from an overflow without poisoning
the parameters or the optimizer moments.

Works with any optimizer following the optax ``init/update`` protocol
(``repro.optim`` provides AdamW/SGD/Adafactor implementations).  The select
is a pair of ``jnp.where``-on-pytrees, which XLA fuses into the update — a
skipped step costs the same FLOPs but commits no state change.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.core.filtering import (combine, is_inexact_array, partition,
                                  select_tree)

PyTree = Any


def apply_updates(model: PyTree, updates: PyTree) -> PyTree:
    """``model + updates`` over inexact leaves; ``None`` updates are skipped.

    Update leaves are cast to the parameter dtype before the add so a
    half-precision update cannot silently downcast an fp32 master param.
    """

    def _add(p, u):
        if u is None or p is None:
            return p
        return p + u.astype(p.dtype) if is_inexact_array(p) else p

    return jax.tree.map(_add, model, updates,
                        is_leaf=lambda x: x is None)


def optimizer_update(model: PyTree, optimizer, optimizer_state: PyTree,
                     grads: PyTree, grads_finite: jax.Array,
                     ) -> tuple[PyTree, PyTree]:
    """Conditionally-applied optimizer step (paper Example 2b).

    Returns ``(new_model, new_optimizer_state)``; both are unchanged when
    ``grads_finite`` is False.
    """
    params, static = partition(model, is_inexact_array)
    updates, new_opt_state = optimizer.update(grads, optimizer_state,
                                              params=params)
    new_params = apply_updates(params, updates)

    new_params = select_tree(grads_finite, new_params, params)
    new_opt_state = select_tree(grads_finite, new_opt_state, optimizer_state)
    return combine(new_params, static), new_opt_state
