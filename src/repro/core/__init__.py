"""MPX core — the paper's contribution as a composable JAX module.

Import as ``from repro import mpx`` (or ``import repro.core as mpx``) and the
API reads exactly like the paper:

    loss_scaling, grads_finite, grads = mpx.filter_grad(loss, loss_scaling)(
        model, batch)
    model, opt_state = mpx.optimizer_update(
        model, optimizer, opt_state, grads, grads_finite)
"""
from repro.core.casting import (cast_function, cast_leaf, cast_to_bfloat16,
                                cast_to_float16, cast_to_float32,
                                cast_to_half_precision, cast_tree,
                                force_full_precision, half_dtype,
                                set_half_dtype)
from repro.core.filtering import (combine, is_array, is_float_array,
                                  is_inexact_array, partition, select_tree,
                                  tree_size_bytes)
from repro.core.grad import filter_grad, filter_value_and_grad
from repro.core.jit import filter_jit
from repro.core.loss_scaling import (DynamicLossScaling, NoOpLossScaling,
                                     all_finite)
from repro.core.optim_update import apply_updates, optimizer_update
from repro.core.policy import FULL_F32, MIXED_BF16, MIXED_F16, Policy

__all__ = [
    "cast_function", "cast_leaf", "cast_to_bfloat16", "cast_to_float16",
    "cast_to_float32", "cast_to_half_precision", "cast_tree",
    "force_full_precision", "half_dtype", "set_half_dtype",
    "combine", "is_array", "is_float_array", "is_inexact_array", "partition",
    "select_tree", "tree_size_bytes",
    "filter_grad", "filter_value_and_grad", "filter_jit",
    "DynamicLossScaling", "NoOpLossScaling", "all_finite",
    "apply_updates", "optimizer_update",
    "FULL_F32", "MIXED_BF16", "MIXED_F16", "Policy",
]
