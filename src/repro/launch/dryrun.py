import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: JAX locks the device count on first use.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:

1. builds the production mesh (16×16 ``("data","model")``; with
   ``--multi_pod`` 2×16×16 ``("pod","data","model")``),
2. builds ShapeDtypeStruct stand-ins (no allocation) for the train state /
   KV cache / batch via ``jax.eval_shape`` + the logical-axis rule table,
3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``,
4. records ``memory_analysis()``, ``cost_analysis()`` and parsed
   collective bytes into ``results/dryrun/<arch>@<shape>@<mesh>.json``.

Usage::

    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi_pod] [--skip-existing]

Skipped cells (encoder decode, full-attention long_500k) are recorded with
their reason so the roofline table shows the complete 40-cell matrix.
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as Pspec

from repro.analysis import hlo as hlo_lib
from repro.configs import registry, shapes as shp
from repro.configs.base import RunConfig
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.nn import param as P
from repro.optim import make_optimizer
from repro.sharding import rules as R
from repro.train import state as S
from repro.train.steps import make_serve_step, make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _attach(tree_sds, tree_sh):
    return jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        tree_sds, tree_sh)


#: logical axes of each decode-state leaf, by its dict key (without the
#: optional leading "layers" scan-stacking dim, added by rank delta).
_CACHE_LOGICAL = {
    "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
    "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
    "h": ("batch", "rnn"),
    "conv": ("batch", None, "rnn"),
    "conv_x": ("batch", None, "ssm_inner"),
    "conv_B": ("batch", None, "ssm_state"),
    "conv_C": ("batch", None, "ssm_state"),
    "ssm": ("batch", "ssm_heads", None, "ssm_state"),
}


def _cache_shardings(cache_sds, cfg, mesh):
    rules = R.rules_with(dict(cfg.rules_overrides)
                         | dict(cfg.decode_rules_overrides))

    def _sh(path, sd):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        logical = _CACHE_LOGICAL[key]
        if len(sd.shape) == len(logical) + 1:      # scan-stacked
            logical = ("layers",) + logical
        assert len(logical) == len(sd.shape), (key, sd.shape)
        return NamedSharding(mesh, R.resolve_spec(logical, sd.shape, mesh,
                                                  rules))

    return jax.tree_util.tree_map_with_path(
        _sh, cache_sds,
        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))


def _compile_cell(cfg, kind: str, mesh, run: RunConfig, global_batch: int,
                  seq_len: int):
    """Lower + compile one step function; returns the compiled artifact."""
    rules = R.rules_with(dict(cfg.rules_overrides))
    with R.axis_rules(mesh, rules):
        if kind in ("train", "prefill"):
            optimizer = make_optimizer(run)
            state_sds = S.abstract_state(cfg, run, optimizer)
            state_sh = S.state_shardings(cfg, run, optimizer, mesh)
            batch_sds = shp.token_batch_shapes(cfg, global_batch, seq_len)
            batch_sh = S.batch_shardings(batch_sds, mesh)
            if kind == "train":
                step = make_train_step(cfg, run, optimizer)
                args = (_attach(state_sds, state_sh),
                        _attach(batch_sds, batch_sh))
                in_sh = (state_sh, batch_sh)
                lowered = jax.jit(step, in_shardings=in_sh,
                                  donate_argnums=(0,)).lower(*args)
                return lowered.compile()
            else:
                # prefill: forward pass only (inference), params in bf16
                params_sds = jax.tree.map(
                    lambda sd: jax.ShapeDtypeStruct(sd.shape, jnp.bfloat16),
                    state_sds["params"])

                def step(params, batch):
                    logits, _ = tfm.forward(params, cfg, batch)
                    return jnp.argmax(logits.astype(jnp.float32), axis=-1)

                args = (_attach(params_sds, state_sh["params"]),
                        _attach(batch_sds, batch_sh))
                in_sh = (state_sh["params"], batch_sh)
            lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
        else:  # decode
            params_sds = jax.tree.map(
                lambda sd: jax.ShapeDtypeStruct(sd.shape, jnp.bfloat16),
                tfm.param_shapes(cfg))
            params_sh = S.state_shardings(cfg, run, make_optimizer(run),
                                          mesh)["params"]
            cache_sds = tfm.abstract_cache(cfg, global_batch, seq_len,
                                           jnp.bfloat16)
            cache_sh = _cache_shardings(cache_sds, cfg, mesh)
            tok_sds = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
            tok_sh = NamedSharding(mesh, R.resolve_spec(
                ("batch", None), tok_sds.shape, mesh, rules))
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            pos_sh = NamedSharding(mesh, Pspec())
            step = make_serve_step(cfg)
            lowered = jax.jit(step, in_shardings=(params_sh, cache_sh,
                                                  tok_sh, pos_sh)).lower(
                _attach(params_sds, params_sh),
                _attach(cache_sds, cache_sh),
                _attach(tok_sds, tok_sh), _attach(pos_sds, pos_sh))
        return lowered.compile()


def _cell_costs(compiled) -> dict:
    cost = hlo_lib.cost_dict(compiled)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(hlo_lib.collective_bytes(compiled.as_text()))}


def corrected_costs(cfg, kind: str, mesh, run: RunConfig, global_batch: int,
                    seq_len: int) -> dict:
    """Loop-corrected per-device costs (see EXPERIMENTS.md §Methodology).

    XLA's ``cost_analysis`` counts while-loop bodies ONCE, so a scanned
    model under-reports FLOPs/bytes by the trip counts.  We reconstruct

        total = F_fixed + K · (F_microbatch + G · F_layer_group)

    from small UNROLLED compiles: A (g=1 groups, k=1 microbatch),
    B (g=2, k=1), and — when grad accumulation is active — C (g=1, k=2):
    F_layer = B−A, F_mb = C−B (or A−F_fixed when K=1), F_fixed = 2A−C.
    """
    lp = len(cfg.pattern)
    rem = cfg.n_layers - (cfg.n_layers // lp) * lp
    groups = cfg.n_layers // lp
    k_prod = run.grad_accum if kind == "train" else 1
    mb = global_batch // k_prod

    def variant(g, k, batch):
        vcfg = dataclasses.replace(cfg, n_layers=lp * g + rem,
                                   scan_layers=False)
        vrun = dataclasses.replace(run, grad_accum=k, accum_unroll=True)
        comp = _compile_cell(vcfg, kind, mesh, vrun, batch, seq_len)
        return _cell_costs(comp)

    a = variant(1, 1, mb)
    b = variant(2, 1, mb)
    out = {}
    if kind == "train" and k_prod > 1:
        c = variant(1, 2, 2 * mb)
        for key in ("flops", "bytes", "coll"):
            f_layer = b[key] - a[key]
            f_mb = c[key] - b[key]
            f_fixed = 2 * a[key] - c[key]
            out[key] = f_fixed + k_prod * (f_mb + groups * f_layer)
    else:
        for key in ("flops", "bytes", "coll"):
            f_layer = b[key] - a[key]
            f_fixed = a[key] - f_layer
            out[key] = f_fixed + groups * f_layer
    return out


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool = False,
                run: RunConfig | None = None, verbose: bool = True,
                mesh=None, cfg=None, correct_costs: bool = True) -> dict:
    """Lower+compile one cell; returns the result record (also JSON'd).

    ``mesh``/``cfg`` overrides exist for tests (reduced meshes/configs) and
    for the perf hillclimb (modified configs on the production mesh).
    """
    cfg = cfg or registry.get_config(arch)
    shape = shp.SHAPES[shape_name]
    mesh_name = ("x".join(str(s) for s in mesh.devices.shape) if mesh is not
                 None else ("2x16x16" if multi_pod else "16x16"))
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "kind": shape.kind}

    runnable, reason = shp.cell_status(cfg, shape_name)
    if not runnable:
        record.update(status="skipped", reason=reason)
        return record

    run = run or RunConfig(grad_accum=8 if shape.kind == "train" else 1)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()

    compiled = _compile_cell(cfg, shape.kind, mesh, run,
                             shape.global_batch, shape.seq_len)
    compile_s = time.time() - t0

    if True:
        mem = compiled.memory_analysis()
        n_params = tfm.count_params(cfg)
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        active = None
        if cfg.moe_experts > 0:
            # active params: replace expert count with top_k in MoE blocks
            dense_like = tfm.count_params(cfg) - _moe_param_delta(cfg)
            active = dense_like
        mf = hlo_lib.model_flops_per_step(
            n_params, tokens, "train" if shape.kind == "train" else "serve",
            active_params=active)
        coll = hlo_lib.collective_stats(compiled.as_text())

        if correct_costs:
            costs = corrected_costs(cfg, shape.kind, mesh, run,
                                    shape.global_batch, shape.seq_len)
        else:
            costs = _cell_costs(compiled)
        roof = hlo_lib.Roofline(costs["flops"], costs["bytes"],
                                costs["coll"], chips, mf)

        record.update(
            status="ok", compile_s=round(compile_s, 1), chips=chips,
            n_params=n_params, tokens_per_step=tokens,
            grad_accum=run.grad_accum, cost_corrected=bool(correct_costs),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "total_nonalias_bytes": (mem.argument_size_in_bytes
                                         + mem.output_size_in_bytes
                                         + mem.temp_size_in_bytes
                                         - mem.alias_size_in_bytes),
            },
            roofline=roof.as_dict(),
            collectives={k: v for k, v in coll.items() if v["count"]},
        )
        if verbose:
            ma = record["memory"]
            print(f"  mem/dev: args={ma['argument_bytes']/2**30:.2f}GiB "
                  f"temp={ma['temp_bytes']/2**30:.2f}GiB | "
                  f"compute={roof.compute_s*1e3:.1f}ms "
                  f"memory={roof.memory_s*1e3:.1f}ms "
                  f"coll={roof.collective_s*1e3:.1f}ms "
                  f"-> {roof.dominant}-bound (compile {compile_s:.0f}s)")
    return record


def _moe_param_delta(cfg) -> int:
    """Params in inactive experts (for 6·N_active·D)."""
    if cfg.moe_experts == 0:
        return 0
    glu = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    per_expert = glu * cfg.d_model * cfg.d_ff
    return (cfg.moe_experts - cfg.moe_top_k) * per_expert * cfg.n_layers


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(shp.SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-correct", action="store_true",
                    help="skip the loop-correction compiles (multi-pod "
                         "compile-proof pass; roofline comes from the "
                         "single-pod run)")
    args = ap.parse_args(argv)

    archs = registry.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes_ = list(shp.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    RESULTS.mkdir(parents=True, exist_ok=True)

    failures = []
    for arch in archs:
        for shape_name in shapes_:
            mesh_name = "2x16x16" if args.multi_pod else "16x16"
            out = RESULTS / f"{arch}@{shape_name}@{mesh_name}.json"
            if args.skip_existing and out.exists():
                print(f"[skip-existing] {arch} × {shape_name} × {mesh_name}")
                continue
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}")
            try:
                rec = dryrun_cell(arch, shape_name, args.multi_pod,
                                  correct_costs=not args.no_correct)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
                failures.append((arch, shape_name))
            out.write_text(json.dumps(rec, indent=2, default=float))
            if rec["status"] == "skipped":
                print(f"  skipped: {rec['reason']}")
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
