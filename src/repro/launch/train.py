"""Training launcher CLI.

Runs real training (CPU-scale with smoke/reduced configs; on a TPU fleet the
same entry point drives the production mesh) with the full production stack:
MPX mixed precision + dynamic loss scaling, sharded state, data pipeline,
fault-tolerant trainer (checkpoint/resume/SIGTERM).

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1
    # kill it mid-run, then relaunch the same command: resumes from latest.

Key=value overrides apply to RunConfig, e.g. ``--set learning_rate=1e-4
grad_accum=2 policy=params=float32,compute=float16,output=float32``.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import single_device_mesh
from repro.optim import make_optimizer
from repro.train.trainer import Trainer, TrainerConfig


def _apply_overrides(run: RunConfig, pairs: list[str]) -> RunConfig:
    out = {}
    fields = {f.name: f.type for f in dataclasses.fields(RunConfig)}
    for pair in pairs:
        key, _, val = pair.partition("=")
        if key not in fields:
            raise SystemExit(f"unknown RunConfig field {key!r}")
        cur = getattr(run, key)
        out[key] = type(cur)(val) if not isinstance(cur, bool) \
            else val.lower() in ("1", "true", "yes")
    return dataclasses.replace(run, **out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--set", nargs="*", default=[], metavar="K=V")
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    run = _apply_overrides(RunConfig(), args.set)
    optimizer = make_optimizer(run)
    data = SyntheticTokens(cfg, batch=args.batch, seq=args.seq, seed=run.seed)

    trainer = Trainer(
        cfg, run, optimizer, data,
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, log_every=args.log_every),
        mesh=single_device_mesh() if jax.device_count() == 1 else None)
    trainer.fit()


if __name__ == "__main__":
    main()
