"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches JAX device state — the dry-run must set
``XLA_FLAGS`` before the first device query, and smoke tests must keep
seeing one CPU device.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 tags mesh axes for explicit sharding; Auto == old default
    from jax.sharding import AxisType

    def _auto_axes(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # older jax: every axis is implicitly Auto
    AxisType = None

    def _auto_axes(n: int) -> dict:
        return {}


def make_mesh(shape, names):
    """``jax.make_mesh`` with all axes Auto, across jax versions."""
    return jax.make_mesh(shape, names, **_auto_axes(len(names)))


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16×16 per pod; 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small explicit mesh for tests (requires forced host device count)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def single_device_mesh():
    return make_host_mesh(1, 1)
