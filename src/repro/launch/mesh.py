"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches JAX device state — the dry-run must set
``XLA_FLAGS`` before the first device query, and smoke tests must keep
seeing one CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16×16 per pod; 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small explicit mesh for tests (requires forced host device count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def single_device_mesh():
    return make_host_mesh(1, 1)
