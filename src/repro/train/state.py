"""Train state construction + sharding derivation.

The train state is a plain dict pytree::

    {"params": ..., "opt_state": ..., "scaling": DynamicLossScaling|NoOp,
     "step": int32[]}

Every helper exists in an *abstract* form (ShapeDtypeStructs via
``jax.eval_shape`` — used by the dry-run and by elastic checkpoint restore)
and a *concrete* form (used by the trainer).  Shardings are derived from the
model's logical-axis metadata (:mod:`repro.nn.param`) through the rule table
(:mod:`repro.sharding.rules`); optimizer state inherits each parameter's
logical axes via ``Optimizer``-specific mapping, with optional ZeRO-1
augmentation (moments additionally sharded over the data axis).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as Pspec

from repro import mpx
from repro.configs.base import ModelConfig, RunConfig
from repro.models import transformer as tfm
from repro.nn import param as P
from repro.sharding import rules as R

PyTree = Any


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------

def make_scaling(run: RunConfig):
    if run.loss_scaling == "dynamic":
        return mpx.DynamicLossScaling(run.init_scale,
                                      period=run.scaling_period)
    return mpx.NoOpLossScaling()


def _compute_dtype(run: RunConfig):
    from repro.core.policy import Policy
    return Policy.parse(run.policy).compute_dtype


def abstract_state(cfg: ModelConfig, run: RunConfig, optimizer) -> PyTree:
    params = tfm.param_shapes(cfg)
    opt_state = jax.eval_shape(optimizer.init, params)
    if run.master_weights == "opt":
        # bf16 working weights; fp32 master lives (data-sharded) in opt state
        cdt = _compute_dtype(run)
        opt_state = {"master": params, **opt_state}
        params = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(sd.shape, cdt), params)
    scaling = make_scaling(run)
    scaling_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        scaling)
    return {"params": params, "opt_state": opt_state,
            "scaling": scaling_abs,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def init_state(key: jax.Array, cfg: ModelConfig, run: RunConfig,
               optimizer) -> PyTree:
    params = tfm.init_params(key, cfg)
    opt_state = optimizer.init(params)
    if run.master_weights == "opt":
        opt_state = {"master": params, **opt_state}
        params = jax.tree.map(
            lambda p: p.astype(_compute_dtype(run)), params)
    return {"params": params, "opt_state": opt_state,
            "scaling": make_scaling(run),
            "step": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------------------------
# sharding derivation
# --------------------------------------------------------------------------

def _opt_state_logical(opt_state_shapes: PyTree, params_logical: PyTree,
                       params_shapes: PyTree) -> PyTree:
    """Logical axes for optimizer state: shape-match against the param.

    Any state leaf whose shape equals its parameter's shape inherits the
    parameter's logical axes (adam mu/nu, sgd momentum).  Leaves with
    reduced shapes (adafactor row/col) inherit the surviving dims' axes.
    Scalars are replicated.
    """
    flat_params = {id_path: (lg, sd.shape) for id_path, (lg, sd) in enumerate(
        zip(jax.tree.leaves(params_logical,
                            is_leaf=lambda x: isinstance(x, tuple)),
            jax.tree.leaves(params_shapes)))}
    shapes_to_logical: dict[tuple, tuple] = {}
    for lg, shp in flat_params.values():
        shapes_to_logical.setdefault(shp, lg)
        # reduced variants for factored stats
        if len(shp) >= 2:
            shapes_to_logical.setdefault(shp[:-1], lg[:-1])
            shapes_to_logical.setdefault(shp[:-2] + shp[-1:],
                                         lg[:-2] + lg[-1:])

    def _lg(sd):
        return shapes_to_logical.get(sd.shape, (None,) * len(sd.shape))

    return jax.tree.map(_lg, opt_state_shapes)


def _zero1_spec(spec: Pspec, shape, mesh: Mesh) -> Pspec:
    """Add the data axis to the first free, divisible dim (ZeRO-1)."""
    if "data" not in mesh.shape or not shape:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for p in parts:
        for ax in (p if isinstance(p, tuple) else (p,)):
            if ax:
                used.add(ax)
    if "data" in used:
        return spec
    dsize = mesh.shape["data"]
    for i, (p, dim) in enumerate(zip(parts, shape)):
        cur = p if isinstance(p, tuple) else ((p,) if p else ())
        size = 1
        for ax in cur:
            size *= mesh.shape[ax]
        if dim % (size * dsize) == 0:
            parts[i] = tuple(cur) + ("data",) if cur else "data"
            return Pspec(*parts)
    return spec


def make_grad_sharder(cfg: ModelConfig):
    """ZeRO-2-style constraint: gradients sharded over (data, model).

    Applied inside the microbatch-accumulation loop, this turns the per-
    microbatch gradient all-reduce into a reduce-scatter (half the bytes)
    and shrinks the fp32 accumulator by the data-axis size — for
    mixtral-8x7b that is an 11.7 GiB -> 0.73 GiB temp reduction
    (EXPERIMENTS.md §Perf iteration A-5).  No-op without a mesh.
    """
    from repro.nn import param as nn_param
    logical = nn_param.logical_axes(tfm.abstract_params(cfg))

    def sharder(grads):
        mesh, rules = R._get_ctx()
        if mesh is None:
            return grads

        def _c(lg, g):
            if g is None:
                return g
            spec = R.resolve_spec(lg, g.shape, mesh, rules)
            spec = _zero1_spec(spec, g.shape, mesh)
            return jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, spec))

        return jax.tree.map(
            _c, logical, grads,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    return sharder


def state_shardings(cfg: ModelConfig, run: RunConfig, optimizer,
                    mesh: Mesh) -> PyTree:
    """NamedSharding tree matching :func:`abstract_state`'s structure."""
    rules = R.rules_with(dict(cfg.rules_overrides))
    params_shapes = tfm.param_shapes(cfg)
    params_logical = P.logical_axes(tfm.abstract_params(cfg))
    param_sh = R.tree_pspecs(params_logical, params_shapes, mesh, rules)

    opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
    if run.master_weights == "opt":
        opt_shapes = {"master": params_shapes, **opt_shapes}
    opt_logical = _opt_state_logical(opt_shapes, params_logical,
                                     params_shapes)

    def _opt_sh(lg, sd):
        spec = R.resolve_spec(lg, sd.shape, mesh, rules)
        if run.zero1:
            spec = _zero1_spec(spec, sd.shape, mesh)
        return NamedSharding(mesh, spec)

    opt_sh = jax.tree.map(
        _opt_sh, opt_logical, opt_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    repl = NamedSharding(mesh, Pspec())
    scaling_abs = jax.tree.map(lambda x: repl, make_scaling(run))
    return {"params": param_sh, "opt_state": opt_sh,
            "scaling": scaling_abs, "step": repl}


def batch_shardings(batch_shapes: PyTree, mesh: Mesh) -> PyTree:
    """Batch arrays shard dim0 over ("pod","data") with divisibility check."""

    def _sh(sd):
        logical = ("batch",) + (None,) * (len(sd.shape) - 1)
        return NamedSharding(mesh, R.resolve_spec(logical, sd.shape, mesh,
                                                  R.DEFAULT_RULES))

    return jax.tree.map(_sh, batch_shapes)


def with_shardings(abstract: PyTree, shardings: PyTree) -> PyTree:
    """Attach shardings to ShapeDtypeStructs (for ``.lower()`` arguments)."""
    return jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        abstract, shardings)
