"""The jitted train / serve steps with MPX mixed precision wired in.

``make_train_step`` is the paper's Example 2 embedded in a production step:

    scaling, finite, (loss, metrics), grads = mpx.filter_value_and_grad(
        loss_fn, scaling, has_aux=True)(params, batch)
    grads, gnorm = clip_by_global_norm(grads, ...)
    params, opt_state = mpx.optimizer_update(params, optimizer, opt_state,
                                             grads, finite)

plus: microbatched gradient accumulation (``run.grad_accum > 1``) with a
single unscale/finite-check/adjust at the end (cheaper and numerically
identical to per-microbatch handling), metrics, and step counting.

``make_serve_step`` wraps the unified transformer's single-token decode with
greedy sampling — the function the decode/long-context dry-run cells lower.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import mpx
from repro.configs.base import ModelConfig, RunConfig
from repro.core.policy import Policy
from repro.models import transformer as tfm
from repro.optim import clip_by_global_norm, global_norm

PyTree = Any


def _accum_grads(loss_fn, scaling, policy: Policy, params, batch, k: int,
                 unroll: bool = False, grad_sharder=None,
                 compress: bool = False):
    """Gradient accumulation over k microbatches via lax.scan.

    Each microbatch computes *scaled* bf16/fp16 gradients; the fp32
    accumulator sums them; one unscale + finite-check at the end.  The
    per-microbatch reduce-scatter of cotangents overlaps the next
    microbatch's compute under the XLA latency-hiding scheduler.
    """
    for leaf in jax.tree.leaves(batch):
        if getattr(leaf, "ndim", 0) and leaf.shape[0] % k:
            raise ValueError(
                f"grad_accum={k} does not divide the batch size "
                f"{leaf.shape[0]} (batch leaf shape {leaf.shape}); use a "
                f"global batch size that is a multiple of run.grad_accum")
    mb = jax.tree.map(
        lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)
    diff, static = mpx.partition(params, mpx.is_inexact_array)

    def scaled_loss(d, b):
        p = mpx.combine(d, static)
        if policy.is_mixed:
            p = policy.cast_to_compute(p)
            b = policy.cast_to_compute(b)
        loss, metrics = loss_fn(p, b)
        return scaling.scale(loss), (loss, metrics)

    def body(acc, b):
        (_, (loss, metrics)), g = jax.value_and_grad(
            scaled_loss, has_aux=True)(diff, b)
        if compress:
            # gradient compression: per-microbatch cotangents cross the DP
            # links in bf16 (half the reduce bytes); the accumulator stays
            # fp32 so the K-step sum keeps full precision — made safe by
            # the loss scaling this framework exists for.
            g = mpx.cast_tree(g, jnp.bfloat16)
        acc = jax.tree.map(
            lambda a, x: a + x.astype(jnp.float32) if mpx.is_inexact_array(a)
            else a, acc, g)
        if grad_sharder is not None:
            acc = grad_sharder(acc)    # ZeRO-2: reduce-scatter into shards
        return acc, (loss.astype(jnp.float32), metrics)

    acc0 = jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32)
        if mpx.is_inexact_array(x) else x, diff)
    if grad_sharder is not None:
        acc0 = grad_sharder(acc0)
    acc, (losses, metrics) = jax.lax.scan(body, acc0, mb,
                                          unroll=k if unroll else 1)
    grads = scaling.unscale(acc)
    grads = jax.tree.map(
        lambda g: g / k if mpx.is_inexact_array(g) else g, grads)
    finite = mpx.all_finite(grads)
    new_scaling = scaling.adjust(finite)
    loss = losses.mean()
    metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
    return new_scaling, finite, (loss, metrics), grads


def make_train_step(cfg: ModelConfig, run: RunConfig, optimizer,
                    loss_fn: Callable | None = None,
                    grad_stats: bool = False) -> Callable:
    """Returns ``train_step(state, batch) -> (new_state, metrics)``.

    ``grad_stats=True`` adds the :mod:`repro.obs.precision` per-layer
    gradient summary (amax / nonfinite fraction / underflow fraction as
    fixed-shape ``(L,)`` fp32 arrays) to the metrics dict — computed
    inside the jitted step, no host callbacks, no extra syncs; layer
    names come from :func:`repro.obs.precision.grad_layer_names`.
    """
    policy = Policy.parse(run.policy)
    custom_loss = loss_fn is not None
    loss_fn = loss_fn or tfm.make_loss_fn(cfg, run.moe_aux_weight)
    grad_sharder = None
    if not custom_loss and run.zero1:
        from repro.train.state import make_grad_sharder
        grad_sharder = make_grad_sharder(cfg)

    def train_step(state: PyTree, batch: PyTree):
        scaling = state["scaling"]
        if run.grad_accum > 1:
            new_scaling, finite, (loss, metrics), grads = _accum_grads(
                loss_fn, scaling, policy, state["params"], batch,
                run.grad_accum, unroll=run.accum_unroll,
                grad_sharder=grad_sharder, compress=run.compress_grads)
        else:
            vag = mpx.filter_value_and_grad(
                loss_fn, scaling, has_aux=True,
                use_mixed_precision=policy.is_mixed,
                compute_dtype=policy.compute_dtype)
            new_scaling, finite, (loss, metrics), grads = vag(
                state["params"], batch)

        if grad_stats:
            # per-layer precision telemetry on the *unscaled, unclipped*
            # fp32 grads — the magnitudes §3.3's control loop reacts to
            from repro.obs.precision import per_layer_grad_summary
            layer_stats = per_layer_grad_summary(grads)
        else:
            layer_stats = {}

        if run.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        else:
            gnorm = global_norm(grads)

        if run.master_weights == "opt":
            # Megatron-style distributed optimizer: fp32 master weights live
            # data-sharded inside opt state; the working params are compute-
            # dtype and re-materialized (one gather) per applied step.
            opt_state = state["opt_state"]
            master = opt_state["master"]
            inner = {k: v for k, v in opt_state.items() if k != "master"}
            updates, inner_new = optimizer.update(grads, inner, params=master)
            master_new = mpx.apply_updates(master, updates)
            params_new = policy.cast_to_compute(master_new)
            params = mpx.select_tree(finite, params_new, state["params"])
            opt_new = {"master": master_new, **inner_new}
            opt_state = mpx.select_tree(finite, opt_new, opt_state)
        else:
            params, opt_state = mpx.optimizer_update(
                state["params"], optimizer, state["opt_state"], grads,
                finite)
        new_state = {"params": params, "opt_state": opt_state,
                     "scaling": new_scaling, "step": state["step"] + 1}
        out_metrics = {"loss": loss, "grad_norm": gnorm,
                       "grads_finite": finite.astype(jnp.float32),
                       "loss_scale": jnp.asarray(new_scaling.loss_scaling,
                                                 jnp.float32)}
        out_metrics.update(layer_stats)
        for k, v in metrics.items():
            out_metrics[k] = v
        return new_state, out_metrics

    return train_step


def make_eval_step(cfg: ModelConfig, run: RunConfig,
                   loss_fn: Callable | None = None) -> Callable:
    policy = Policy.parse(run.policy)
    loss_fn = loss_fn or tfm.make_loss_fn(cfg, run.moe_aux_weight)

    def eval_step(params, batch):
        p, b = params, batch
        if policy.is_mixed:
            p = policy.cast_to_compute(p)
            b = policy.cast_to_compute(b)
        loss, metrics = loss_fn(p, b)
        return loss.astype(jnp.float32), metrics

    return eval_step


def make_serve_step(cfg: ModelConfig, sampling=None) -> Callable:
    """``serve_step(params, cache, tokens, pos) -> (next_tokens, new_cache)``.

    Params are expected pre-cast to the serving dtype (bf16); sampling runs
    in fp32.  The default (``sampling=None`` or greedy
    :class:`~repro.serve.sampling.SamplingParams`) keeps the historical
    4-arg argmax signature — the function the ``decode_*`` / ``long_*``
    dry-run cells lower and compile.  With stochastic ``SamplingParams``
    the step takes a PRNG key: ``serve_step(params, cache, tokens, pos,
    key)``.

    This is the monolithic-slab serving step; the paged-KV-cache engine in
    :mod:`repro.serve` (which re-exports this) supersedes it for
    continuous-batching workloads.
    """
    if sampling is None or sampling.is_greedy:
        def serve_step(params, cache, tokens, pos):
            logits, new_cache = tfm.decode(params, cfg, cache, tokens, pos)
            next_tokens = jnp.argmax(logits.astype(jnp.float32), axis=-1)
            return next_tokens.astype(jnp.int32), new_cache

        return serve_step

    from repro.serve.sampling import make_sampler  # lazy: avoid import cycle
    sampler = make_sampler(sampling)

    def serve_step(params, cache, tokens, pos, key):
        logits, new_cache = tfm.decode(params, cfg, cache, tokens, pos)
        next_tokens, _ = sampler(logits[:, -1], key)   # ids only; the probs
        return next_tokens[:, None], new_cache         # feed spec verify

    return serve_step
