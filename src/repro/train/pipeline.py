"""GPipe-style pipeline parallelism over a dedicated "pipe" mesh axis.

The graded production mesh is (data, model) — pipelining there is off.  At
1000+-node scale a third axis splits the layer stack into stages; this
module provides that as a composable, *tested* building block:

- stage s holds the parameters of layers [s·L/S, (s+1)·L/S);
- a microbatch stream flows through stages via `jax.lax.ppermute`
  (neighbor ICI transfers — the cheapest collective on a torus);
- the classic GPipe schedule: S+M-1 ticks for M microbatches over S stages,
  bubble fraction (S-1)/(S+M-1).

Implementation: `shard_map` MANUAL over the pipe axis.  Every device runs
the same tick loop; at tick t it applies its stage to the activation it
received at t-1 and forwards the result.  Outputs are collected on the
last stage and ppermute'd back to stage 0 order at the end.  The stage body
is arbitrary (any jax-traceable layer-group function), so the unified
transformer's scanned group body drops in directly.

This mirrors the approach of praxis/GSPMD pipelining but stays explicit —
the schedule is visible, testable (tests/test_pipeline.py runs it on 4
forced host devices and checks exact equivalence with the sequential
stack), and extensible to 1F1B by reordering the tick loop.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn: Callable, mesh, *, axis: str = "pipe",
          n_microbatches: int):
    """Build a pipelined apply: (stage_params, x) -> y.

    Args:
      stage_fn: ``stage_fn(stage_params, x) -> x`` — one stage's layers.
        ``stage_params`` are the (leading-stage-dim-removed) params local to
        the device's stage.
      mesh: mesh containing ``axis``; its size = number of stages S.
      n_microbatches: M; the global batch must divide by M.

    Returns ``apply(params_stacked, x)`` where ``params_stacked`` leaves
    have a leading stage dim S (sharded over ``axis``) and ``x`` is the
    full (B, ...) batch (replicated over ``axis``); output matches x's
    structure after all S stages.
    """
    n_stages = mesh.shape[axis]

    def per_device(params_local, x):
        # params_local: this stage's params (leading dim 1 — squeeze);
        # x: full batch (replicated): every stage sees it, stage 0 feeds it.
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        b = x.shape[0]
        mb_size = b // n_microbatches
        mbs = x.reshape((n_microbatches, mb_size) + x.shape[1:])

        n_ticks = n_stages + n_microbatches - 1
        buf = jnp.zeros_like(mbs[0])                 # incoming activation
        outs = jnp.zeros_like(mbs)                   # collected on last stage

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if any left)
            inject = mbs[jnp.clip(t, 0, n_microbatches - 1)]
            cur = jnp.where(stage == 0, inject, buf)
            # active iff this stage has work at tick t: stage <= t < stage+M
            active = (t >= stage) & (t < stage + n_microbatches)
            y = stage_fn(params_local, cur)
            y = jnp.where(active, y, cur)
            # last stage collects its finished microbatch (index t - S + 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            collect = active & (stage == n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(collect, y, outs[out_idx]), out_idx, 0)
            # forward to the next stage (ring permute; last->0 is ignored)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(n_ticks))
        # broadcast the collected outputs from the last stage to all
        # stages (mask + psum == one-to-all on the pipe ring)
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs.reshape((b,) + x.shape[1:])

    if hasattr(jax, "shard_map"):  # jax >= 0.5 top-level API
        return jax.shard_map(
            per_device, mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
            check_vma=False,
            axis_names={axis})
    from jax.experimental.shard_map import shard_map
    return shard_map(
        per_device, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead: (S-1)/(S+M-1)."""
    return (n_stages - 1) / (n_stages + n_microbatches - 1)
