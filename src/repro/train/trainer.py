"""Fault-tolerant trainer: checkpoint/restart, SIGTERM, step watchdog.

The production loop every launcher entry point drives:

- builds (or **resumes**) sharded train state on the given mesh,
- jits the MPX train step with explicit in/out shardings + donation,
- checkpoints every N steps (async) including **data-iterator state** and
  the loss-scaling state — a resumed run replays the identical batch and
  scaling schedule (tested bit-exact),
- installs a SIGTERM/SIGINT handler: on preemption the current state is
  checkpointed synchronously before exit (standard TPU-fleet etiquette),
- runs a **step watchdog**: a step exceeding ``watchdog_s`` marks the run
  unhealthy and raises after checkpointing — in a fleet, the scheduler
  relaunches and the run resumes from the last checkpoint; on restart with
  a different device count, elastic restore re-shards (see Checkpointer).
  This is the restart-based straggler/failure mitigation appropriate to
  synchronous SPMD (DESIGN.md §5),
- records **precision telemetry** (``repro.obs``): every logged step
  feeds the loss-scale trajectory, overflow/skip counters and
  halving/doubling events into ``trainer.precision``
  (:class:`~repro.obs.precision.PrecisionStats` — export with
  ``trainer.precision.snapshot()`` or
  ``trainer.precision.registry.prometheus()``); with
  ``tcfg.grad_stats=True`` the jitted step additionally returns per-layer
  grad amax / nonfinite / underflow-fraction arrays (fixed shapes, no
  host callbacks) that land in the same snapshot.  Set ``log_every=1``
  to capture every scale transition.  ``tcfg.jax_trace_dir`` brackets
  the run with a ``jax.profiler`` device trace.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import Prefetcher
from repro.obs.precision import PrecisionStats, grad_layer_names
from repro.obs.trace import profiler_trace
from repro.sharding import rules as R
from repro.train import state as S
from repro.train.steps import make_train_step

# metrics keys produced by per_layer_grad_summary — array-valued, routed
# to PrecisionStats instead of the scalar history
_PER_LAYER_KEYS = ("grad_amax_per_layer", "grad_nonfinite_frac_per_layer",
                   "grad_underflow_frac_per_layer")

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    ckpt_keep: int = 3
    log_every: int = 10
    watchdog_s: float = 0.0        # 0 = disabled
    prefetch: int = 2
    grad_stats: bool = False       # per-layer grad telemetry in the step
    jax_trace_dir: Optional[str] = None   # jax.profiler trace around fit()


class WatchdogTimeout(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, optimizer, data,
                 tcfg: TrainerConfig, mesh=None):
        self.cfg, self.run, self.optimizer = cfg, run, optimizer
        self.tcfg = tcfg
        self.mesh = mesh
        self.data = Prefetcher(data, tcfg.prefetch) if tcfg.prefetch else data
        self.ckpt = (Checkpointer(tcfg.ckpt_dir, tcfg.ckpt_keep)
                     if tcfg.ckpt_dir else None)
        self._preempted = False
        self._prev_handlers = {}
        self.rules = R.rules_with(dict(cfg.rules_overrides))

        self.state_shardings = (
            S.state_shardings(cfg, run, optimizer, mesh) if mesh else None)
        step_fn = make_train_step(cfg, run, optimizer,
                                  grad_stats=tcfg.grad_stats)
        if mesh is not None:
            self._step = jax.jit(step_fn,
                                 in_shardings=(self.state_shardings, None),
                                 out_shardings=(self.state_shardings, None),
                                 donate_argnums=(0,))
        else:
            self._step = jax.jit(step_fn, donate_argnums=(0,))
        self.state = self._init_or_resume()
        self.metrics_history: list[dict] = []
        self.precision = PrecisionStats()
        self._layer_names = (grad_layer_names(self.state["params"])
                             if tcfg.grad_stats else [])

    # ------------------------------------------------------------------ init
    def _init_or_resume(self) -> PyTree:
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            abstract = S.abstract_state(self.cfg, self.run, self.optimizer)
            state, extra = self.ckpt.restore(
                abstract, shardings=self.state_shardings)
            if "data" in extra and hasattr(self.data, "load_state"):
                self.data.load_state(extra["data"])
            print(f"[trainer] resumed from step {int(state['step'])}")
            return state
        key = jax.random.key(self.run.seed)
        with R.axis_rules(self.mesh, self.rules):
            state = S.init_state(key, self.cfg, self.run, self.optimizer)
            if self.state_shardings is not None:
                state = jax.device_put(state, self.state_shardings)
        return state

    # ----------------------------------------------------------- preemption
    def _install_signals(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev_handlers[sig] = signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _restore_signals(self):
        for sig, h in self._prev_handlers.items():
            signal.signal(sig, h)

    def _checkpoint(self, sync: bool = False):
        if self.ckpt is None:
            return
        extra = {}
        if hasattr(self.data, "state"):
            extra["data"] = self.data.state()
        step = int(jax.device_get(self.state["step"]))
        if sync:
            self.ckpt.save(step, self.state, extra)
        else:
            self.ckpt.save_async(step, self.state, extra)

    # ------------------------------------------------------------------ fit
    def fit(self) -> list[dict]:
        self._install_signals()
        try:
            start = int(jax.device_get(self.state["step"]))
            ctx = R.axis_rules(self.mesh, self.rules)
            with ctx, profiler_trace(self.tcfg.jax_trace_dir):
                for step in range(start, self.tcfg.total_steps):
                    t0 = time.time()
                    batch = self.data.next_batch()
                    self.state, metrics = self._step(self.state, batch)
                    if (self.tcfg.log_every and
                            (step + 1) % self.tcfg.log_every == 0):
                        m, layers = {}, {}
                        for k, v in metrics.items():
                            arr = np.asarray(v)
                            if arr.ndim == 0:
                                m[k] = float(arr)
                            elif k in _PER_LAYER_KEYS:
                                layers[k] = arr
                        m["step"] = step + 1
                        m["step_time_s"] = time.time() - t0
                        self.metrics_history.append(m)
                        self.precision.record_step(
                            step + 1, m.get("loss_scale", 1.0),
                            m.get("grads_finite", 1.0) >= 0.5)
                        if layers:
                            self.precision.record_layer_summary(
                                self._layer_names, layers)
                        print(f"[trainer] step {step+1} "
                              f"loss={m['loss']:.4f} "
                              f"scale={m.get('loss_scale', 1):.0f} "
                              f"({m['step_time_s']*1e3:.0f}ms)")
                    dt = time.time() - t0
                    if self.tcfg.watchdog_s and dt > self.tcfg.watchdog_s:
                        self._checkpoint(sync=True)
                        raise WatchdogTimeout(
                            f"step {step+1} took {dt:.1f}s > "
                            f"{self.tcfg.watchdog_s}s — checkpointed; "
                            "relaunch to resume")
                    if (self.ckpt is not None and
                            (step + 1) % self.tcfg.ckpt_every == 0):
                        self._checkpoint()
                    if self._preempted:
                        print("[trainer] preemption signal — checkpointing")
                        self._checkpoint(sync=True)
                        return self.metrics_history
            self._checkpoint(sync=True)
            return self.metrics_history
        finally:
            if self.ckpt is not None:
                self.ckpt.wait()
            if hasattr(self.data, "close"):
                self.data.close()
            self._restore_signals()
