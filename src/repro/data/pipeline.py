"""Data pipelines: deterministic synthetic streams + memmap token files.

Production posture:

- **Determinism / checkpointability**: every iterator exposes ``state()`` /
  ``load_state()`` (a tiny dict) that the checkpointer persists — resuming a
  run replays the exact batch sequence (bit-identical loss curves, verified
  in tests).
- **DP sharding**: each data-parallel rank reads only its slice
  (``shard_id`` / ``num_shards``); on a single host this is a no-op but the
  slicing logic is exercised by tests.
- **Straggler hiding**: a background prefetch thread keeps a small queue of
  ready batches, so host-side input processing never stalls the device step
  (the first line of straggler mitigation in synchronous SPMD training).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticTokens:
    """Deterministic synthetic batches matching an arch's input structure.

    Uses a counter-keyed PRNG (numpy Philox) so ``state()`` is just the step
    counter — restore is O(1), no stream replay needed.
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 shard_id: int = 0, num_shards: int = 1):
        if batch % num_shards:
            raise ValueError("batch must divide across data shards")
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.shard_id, self.num_shards = seed, shard_id, num_shards
        self._step = 0

    # -- checkpointable iterator protocol -----------------------------------
    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed}

    def load_state(self, state: dict) -> None:
        self._step = int(state["step"])
        self.seed = int(state["seed"])

    # -- iteration -----------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(
            key=self.seed, counter=[step, self.shard_id, 0, 0]))

    def next_batch(self) -> dict:
        rng = self._rng(self._step)
        self._step += 1
        b = self.batch // self.num_shards
        cfg = self.cfg
        if cfg.frontend == "frames":
            dim = cfg.frontend_dim or cfg.d_model
            return {
                "features": rng.standard_normal(
                    (b, self.seq, dim), dtype=np.float32),
                "targets": rng.integers(
                    0, cfg.vocab_size, (b, self.seq), dtype=np.int32),
            }
        out = {
            "inputs": rng.integers(0, cfg.vocab_size, (b, self.seq),
                                   dtype=np.int32),
            "targets": rng.integers(0, cfg.vocab_size, (b, self.seq),
                                    dtype=np.int32),
        }
        if cfg.frontend == "patches":
            dim = cfg.frontend_dim or cfg.d_model
            n_p = max(4, min(64, self.seq // 4))
            out["patches"] = rng.standard_normal(
                (b, n_p, dim), dtype=np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


class MemmapTokens:
    """Packed next-token-prediction batches from a flat binary token file.

    File format: raw little-endian int32 tokens (``make_token_file`` builds
    one).  Sequences are drawn as contiguous windows; window ``w`` of rank
    ``r`` at step ``t`` is a pure function of (seed, t, r) — checkpointable
    like the synthetic stream.
    """

    def __init__(self, path: str, batch: int, seq: int, seed: int = 0,
                 shard_id: int = 0, num_shards: int = 1):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        if len(self.tokens) < seq + 2:
            raise ValueError("token file too small for seq length")
        self.batch, self.seq = batch, seq
        self.seed, self.shard_id, self.num_shards = seed, shard_id, num_shards
        self._step = 0

    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed}

    def load_state(self, state: dict) -> None:
        self._step = int(state["step"])
        self.seed = int(state["seed"])

    def next_batch(self) -> dict:
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[self._step, self.shard_id, 0, 0]))
        self._step += 1
        b = self.batch // self.num_shards
        starts = rng.integers(0, len(self.tokens) - self.seq - 1, size=b)
        rows = np.stack([self.tokens[s:s + self.seq + 1] for s in starts])
        return {"inputs": rows[:, :-1].astype(np.int32),
                "targets": rows[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


def make_token_file(path: str, n_tokens: int, vocab: int, seed: int = 0):
    rng = np.random.Generator(np.random.Philox(key=seed))
    arr = rng.integers(0, vocab, size=n_tokens, dtype=np.int32)
    arr.tofile(path)
    return path


class Prefetcher:
    """Background-thread prefetch queue over any checkpointable iterator.

    ``state()`` reflects the number of batches *consumed*, not produced, so
    a checkpoint/restore never skips or replays batches that were sitting
    in the queue.
    """

    def __init__(self, source, depth: int = 2):
        self.source = source
        self._consumed = 0
        self._queue: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        while not self._stop.is_set():
            batch = self.source.next_batch()
            while not self._stop.is_set():
                try:
                    self._queue.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next_batch(self) -> dict:
        batch = self._queue.get()
        self._consumed += 1
        return batch

    def state(self) -> dict:
        st = self.source.state()
        st["step"] = self._consumed  # ignore produced-but-unconsumed
        return st

    def load_state(self, state: dict) -> None:
        self.source.load_state(state)
        self._consumed = int(state["step"])

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
