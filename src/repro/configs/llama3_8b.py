"""llama3-8b — dense GQA decoder, 128k vocab. [arXiv:2407.21783]

32L, d_model 4096, 32 heads (GQA kv=8, head_dim 128), d_ff 14336 (SwiGLU),
vocab 128256, RoPE theta 500k, RMSNorm, untied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    pattern=("attn",), mlp="swiglu", norm="rmsnorm",
    rope_theta=500000.0, tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        pattern=("attn",), mlp="swiglu", norm="rmsnorm",
        rope_theta=500000.0, tie_embeddings=False, remat="none",
    )
