"""recurrentgemma-9b — Griffin: RG-LRU + local attention, 2:1 pattern.
[arXiv:2402.19427]

38L in repeating (RG-LRU, RG-LRU, local-attn) triples (12 full groups + 2
remainder RG-LRU layers), d_model 4096, attention layers use 16 heads with
MQA (kv=1, head_dim 256) and a 2048-token window, d_ff 12288 (GeGLU),
vocab 256000, embeddings scaled by sqrt(d) and tied.  RG-LRU width equals
d_model (as in the released recurrentgemma configs).

Constant-size recurrent state + bounded attention window => the long_500k
cell runs for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    pattern=("rglru", "rglru", "local_attn"), window=2048,
    mlp="geglu", norm="rmsnorm",
    d_rnn=4096, conv_width=4,
    rope_theta=10000.0, tie_embeddings=True, emb_scale=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", family="hybrid",
        n_layers=5, d_model=48, n_heads=4, n_kv_heads=1, head_dim=12,
        d_ff=96, vocab_size=256,
        pattern=("rglru", "rglru", "local_attn"), window=8,
        mlp="geglu", norm="rmsnorm",
        d_rnn=48, conv_width=4,
        rope_theta=10000.0, tie_embeddings=True, emb_scale=True,
        remat="none",
    )
