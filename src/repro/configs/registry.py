"""Architecture registry: ``--arch <id>`` ids -> (full config, smoke config).

The ten assigned architectures plus the paper's own ViT evaluation model
(the latter lives in :mod:`repro.models.vit` with its own config type and
is exposed here for the benchmarks, not for the LM dry-run matrix).
"""
from __future__ import annotations

from repro.configs import (gemma2_2b, hubert_xlarge, llama3_8b, mamba2_130m,
                           mixtral_8x7b, phi3_5_moe, phi3_vision_4_2b,
                           qwen1_5_32b, recurrentgemma_9b, starcoder2_3b)
from repro.configs.base import ModelConfig

_MODULES = {
    "llama3-8b": llama3_8b,
    "gemma2-2b": gemma2_2b,
    "starcoder2-3b": starcoder2_3b,
    "qwen1.5-32b": qwen1_5_32b,
    "mixtral-8x7b": mixtral_8x7b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe,
    "recurrentgemma-9b": recurrentgemma_9b,
    "hubert-xlarge": hubert_xlarge,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
    "mamba2-130m": mamba2_130m,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke()


def all_configs() -> dict[str, ModelConfig]:
    return {name: mod.CONFIG for name, mod in _MODULES.items()}
