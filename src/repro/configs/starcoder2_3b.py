"""starcoder2-3b — GQA kv=2, RoPE, GELU MLP with biases, LayerNorm.
[arXiv:2402.19173; hf:bigcode/starcoder2-3b]

30L, d_model 3072, 24 heads (GQA kv=2, head_dim 128), d_ff 12288,
vocab 49152, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab_size=49152,
    pattern=("attn",), mlp="gelu", mlp_bias=True, norm="layernorm",
    qkv_bias=True, out_bias=True,
    rope_theta=999999.0, tie_embeddings=True,
    # 24 heads don't split the 16-way model axis.  Baseline used
    # head_dim->model (contraction-sharded attention: psums of (B,H,S,S)
    # scores, measured collective-bound at 50.5s — EXPERIMENTS.md §Perf
    # iter B).  Sequence sharding instead: activations shard on seq over
    # the model axis, attention q is seq-local against all-gathered K/V
    # (GQA kv=2 makes the gather tiny), MLP runs seq-sharded with weight
    # all-gathers — no S² psums anywhere.
    rules_overrides=(("seq", "model"),),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke", family="dense",
        n_layers=3, d_model=48, n_heads=6, n_kv_heads=2, head_dim=8,
        d_ff=96, vocab_size=256,
        pattern=("attn",), mlp="gelu", mlp_bias=True, norm="layernorm",
        qkv_bias=True, out_bias=True,
        rope_theta=999999.0, tie_embeddings=True, remat="none",
    )
