"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE.
[hf:microsoft/Phi-3.5-MoE-instruct]

32L, d_model 4096, 32 heads (GQA kv=8, head_dim 128), expert d_ff 6400,
vocab 32064, full attention.  16 experts divide the 16-way model axis
exactly — this config exercises *pure expert parallelism* (one expert per
TP shard), in contrast to mixtral's TP-inside-expert fallback.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    pattern=("attn",), mlp="swiglu", norm="rmsnorm",
    moe_experts=16, moe_top_k=2, capacity_factor=1.25,
    rope_theta=10000.0, tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke", family="moe",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256,
        pattern=("attn",), mlp="swiglu", norm="rmsnorm",
        moe_experts=8, moe_top_k=2, capacity_factor=2.0,
        rope_theta=10000.0, tie_embeddings=False, remat="none",
    )
