"""mamba2-130m — SSD (state-space duality), attention-free.
[arXiv:2405.21060]

24L of pure SSD blocks (no MLP: d_ff=0), d_model 768, d_inner 1536
(expand=2), 24 SSD heads × headdim 64, state 128, conv width 4, chunk 256,
vocab 50280, tied embeddings.  Constant-size decode state => long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    pattern=("ssd",), mlp="none", norm="rmsnorm",
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    conv_width=4, rope_theta=0.0, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m-smoke", family="ssm",
        n_layers=3, d_model=48, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=256,
        pattern=("ssd",), mlp="none", norm="rmsnorm",
        ssm_state=16, ssm_headdim=24, ssm_expand=2, ssm_chunk=8,
        conv_width=4, rope_theta=0.0, tie_embeddings=True, remat="none",
    )
