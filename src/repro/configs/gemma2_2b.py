"""gemma2-2b — local/global alternating attention, logit softcaps.
[arXiv:2408.00118; hf:google/gemma-2-2b]

26L, d_model 2304, 8 heads (GQA kv=4, head_dim 256), d_ff 9216 (GeGLU),
vocab 256000, window 4096 on local layers, attn softcap 50, final softcap
30, pre+post norms, embeddings scaled by sqrt(d), tied unembedding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    pattern=("local_attn", "attn"), window=4096,
    mlp="geglu", norm="rmsnorm", post_norm=True,
    attn_softcap=50.0, final_softcap=30.0,
    rope_theta=10000.0, tie_embeddings=True, emb_scale=True,
    # 8 heads don't split 16-way TP.  Sequence sharding won the §Perf
    # rollout (head_dim sharding psums S² scores: mem 22.4->3.9s,
    # coll 16.9->6.7s, MFU 1.5->4.9%).
    rules_overrides=(("seq", "model"),),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b-smoke", family="dense",
        n_layers=4, d_model=48, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256,
        pattern=("local_attn", "attn"), window=8,
        mlp="geglu", norm="rmsnorm", post_norm=True,
        attn_softcap=50.0, final_softcap=30.0,
        rope_theta=10000.0, tie_embeddings=True, emb_scale=True,
        remat="none",
    )
