"""Model / run configuration dataclasses.

One :class:`ModelConfig` drives every assigned architecture through the
unified transformer in :mod:`repro.models.transformer` via a repeating
layer ``pattern`` (see DESIGN.md §4).  :class:`RunConfig` adds the
training-time knobs (precision policy, loss scaling, optimizer, sharding
overrides, remat, grad accumulation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|audio|vlm|vision
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    pattern: Tuple[str, ...] = ("attn",)  # cycled: attn|local_attn|rglru|ssd
    window: int = 0                   # sliding window for local_attn
    mlp: str = "swiglu"               # swiglu|geglu|gelu|none
    mlp_bias: bool = False
    moe_experts: int = 0
    moe_top_k: int = 2
    capacity_factor: float = 1.25
    qkv_bias: bool = False
    out_bias: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    rope_theta: float = 10000.0       # 0 -> no RoPE (hubert: stub frontend owns positions)
    norm: str = "rmsnorm"             # rmsnorm|layernorm
    post_norm: bool = False           # gemma2: post-block norms
    causal: bool = True               # False: encoder-only (hubert)
    tie_embeddings: bool = True
    emb_scale: bool = False           # gemma: embeddings * sqrt(d_model)
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # rglru (recurrentgemma)
    d_rnn: int = 0
    conv_width: int = 4
    # modality frontends (STUBS per the brief: input_specs provides embeddings)
    frontend: str = "none"            # none|frames|patches
    frontend_dim: int = 0
    num_patches: int = 0
    # execution
    scan_layers: bool = True
    remat: str = "full"               # full|dots|none
    rules_overrides: Tuple[Tuple[str, Any], ...] = ()
    decode_rules_overrides: Tuple[Tuple[str, Any], ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_kinds(self) -> Tuple[str, ...]:
        """The concrete kind of each of the n_layers layers."""
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    def supports_decode(self) -> bool:
        return self.causal and self.family not in ("audio", "vision")

    def sub_quadratic(self) -> bool:
        """True if every layer's decode state is bounded (or constant) —
        the criterion for running the long_500k cell (DESIGN.md §4)."""
        kinds = set(self.layer_kinds())
        if "attn" in kinds:
            # full-attention layers: unbounded KV growth
            return False
        return True


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving-time knobs, orthogonal to the architecture."""
    policy: str = "params=float32,compute=bfloat16,output=float32"
    loss_scaling: str = "dynamic"     # dynamic|none  (dynamic is the paper)
    init_scale: float = 2.0 ** 15
    scaling_period: int = 2000
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    grad_accum: int = 1
    accum_unroll: bool = False        # unroll the microbatch scan (analysis)
    zero1: bool = True                # shard optimizer state over data axis
    master_weights: str = "params"    # params: paper-faithful fp32 params;
                                      # opt: bf16 working weights + fp32
                                      # master inside (data-sharded) opt
                                      # state — Megatron-style distributed
                                      # optimizer (§Perf iteration A-4)
    compress_grads: bool = False      # bf16 cross-DP gradient reduction
    moe_aux_weight: float = 0.01
    seed: int = 0
