"""qwen1.5-32b — MHA with QKV bias. [hf:Qwen/Qwen1.5-32B]

64L, d_model 5120, 40 heads (kv=40, head_dim 128), d_ff 27392 (SwiGLU),
vocab 152064, RMSNorm, untied embeddings.  The largest assigned config
(~32B params) — the memory-term stress test.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab_size=152064,
    pattern=("attn",), mlp="swiglu", norm="rmsnorm", qkv_bias=True,
    rope_theta=1000000.0, tie_embeddings=False,
    # 40 heads don't split 16-way TP.  Sequence sharding won the §Perf
    # rollout (coll 183->93s, mem 173->49s, MFU 2.4->4.7%).
    rules_overrides=(("seq", "model"),),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-smoke", family="dense",
        n_layers=2, d_model=40, n_heads=5, n_kv_heads=5, head_dim=8,
        d_ff=112, vocab_size=256,
        pattern=("attn",), mlp="swiglu", norm="rmsnorm", qkv_bias=True,
        rope_theta=1000000.0, tie_embeddings=False, remat="none",
    )
