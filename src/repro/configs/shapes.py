"""The assigned input-shape set (per arch) + batch construction.

Four shapes per LM-family architecture:

- ``train_4k``:    seq 4,096  × global batch 256   (train_step)
- ``prefill_32k``: seq 32,768 × global batch 32    (forward / encoder pass)
- ``decode_32k``:  KV cache 32,768, batch 128      (serve_step, one token)
- ``long_500k``:   KV cache 524,288, batch 1       (serve_step; sub-quadratic
                   archs only — see ``cell_status``)

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (dry-run; no allocation).  ``make_batch`` materializes a
deterministic synthetic batch of the same structure at arbitrary (reduced)
sizes for smoke tests and real CPU training.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: VLM patch-grid stand-in (phi-3-vision: 336px/14 = 576 patches + cls).
VLM_PATCHES = 576


def cell_status(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch × shape) cell."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and not cfg.supports_decode():
        return False, f"{cfg.family}: encoder-only / no autoregressive step"
    if shape_name == "long_500k" and not cfg.sub_quadratic():
        return False, ("pure full-attention KV cache is unbounded at 500k; "
                       "per brief, long_500k runs only for SSM/hybrid/"
                       "windowed archs")
    return True, ""


def token_batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs of one training/prefill batch for this arch."""
    i32 = jnp.int32
    if cfg.frontend == "frames":
        dim = cfg.frontend_dim or cfg.d_model
        return {"features": jax.ShapeDtypeStruct((batch, seq, dim),
                                                 jnp.float32),
                "targets": jax.ShapeDtypeStruct((batch, seq), i32)}
    out = {"inputs": jax.ShapeDtypeStruct((batch, seq), i32),
           "targets": jax.ShapeDtypeStruct((batch, seq), i32)}
    if cfg.frontend == "patches":
        dim = cfg.frontend_dim or cfg.d_model
        out["patches"] = jax.ShapeDtypeStruct((batch, VLM_PATCHES, dim),
                                              jnp.float32)
    return out


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Dry-run input stand-ins for the given cell.

    ``train``/``prefill`` -> the token batch; ``decode`` -> one-token batch
    (the KV cache is a separate lowering argument built by the launcher).
    """
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                               jnp.int32)}
    return token_batch_shapes(cfg, shape.global_batch, shape.seq_len)


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> dict:
    """Deterministic synthetic batch (smoke tests / CPU training)."""
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.frontend == "frames":
        dim = cfg.frontend_dim or cfg.d_model
        return {"features": jax.random.normal(k1, (batch, seq, dim)),
                "targets": jax.random.randint(k2, (batch, seq), 0,
                                              cfg.vocab_size)}
    out = {"inputs": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size),
           "targets": jax.random.randint(k2, (batch, seq), 0,
                                         cfg.vocab_size)}
    if cfg.frontend == "patches":
        dim = cfg.frontend_dim or cfg.d_model
        n_p = min(VLM_PATCHES, max(4, seq // 4))
        out["patches"] = jax.random.normal(k3, (batch, n_p, dim))
    return out
