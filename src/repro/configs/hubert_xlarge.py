"""hubert-xlarge — encoder-only audio transformer (w2v2 architecture).
[arXiv:2106.07447]

48L, d_model 1280, 16 MHA heads (head_dim 80), d_ff 5120 (GELU+bias),
LayerNorm, bidirectional.  Masked-prediction head over 504 cluster units.

The convolutional waveform frontend is a STUB per the brief:
``input_specs()`` supplies precomputed (B, T, 1280) frame embeddings
(which, in the real model, also carry the conv positional information —
hence no RoPE in the backbone).  Encoder-only => decode cells are skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    pattern=("attn",), mlp="gelu", mlp_bias=True, norm="layernorm",
    qkv_bias=True, out_bias=True, causal=False,
    rope_theta=0.0, tie_embeddings=False,
    frontend="frames", frontend_dim=1280,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64,
        pattern=("attn",), mlp="gelu", mlp_bias=True, norm="layernorm",
        qkv_bias=True, out_bias=True, causal=False,
        rope_theta=0.0, tie_embeddings=False,
        frontend="frames", frontend_dim=32, remat="none",
    )
