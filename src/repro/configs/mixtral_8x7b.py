"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention.
[arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1]

32L, d_model 4096, 32 heads (GQA kv=8, head_dim 128), expert d_ff 14336
(SwiGLU), vocab 32000, SWA window 4096 on every layer — which bounds the
decode KV cache and makes the long_500k cell runnable.

Expert parallelism: 8 experts don't divide the 16-way model axis, so the
rule table TP-shards d_ff (14336) inside each expert instead (moe_mlp ->
model) — automatic via divisibility fallback.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    pattern=("local_attn",), window=4096,
    mlp="swiglu", norm="rmsnorm",
    moe_experts=8, moe_top_k=2, capacity_factor=1.25,
    rope_theta=1000000.0, tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke", family="moe",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256,
        pattern=("local_attn",), window=8,
        mlp="swiglu", norm="rmsnorm",
        moe_experts=4, moe_top_k=2, capacity_factor=2.0,
        rope_theta=1000000.0, tie_embeddings=False, remat="none",
    )
