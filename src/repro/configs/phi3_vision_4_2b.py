"""phi-3-vision-4.2b — phi3-mini LM backbone + CLIP frontend (stubbed).
[hf:microsoft/Phi-3-vision-128k-instruct]

32L, d_model 3072, 32 MHA heads (kv=32, head_dim 96), d_ff 8192 (SwiGLU),
vocab 32064.  The CLIP ViT-L/14 image tower is a STUB per the brief:
``input_specs()`` supplies precomputed (B, 576, 1024) patch embeddings,
projected and prepended to the token sequence; logits cover text positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    pattern=("attn",), mlp="swiglu", norm="rmsnorm",
    rope_theta=10000.0, tie_embeddings=False,
    frontend="patches", frontend_dim=1024,
    # head_dim 96 = 16×6 divides the model axis; 32 heads also divide —
    # default rules shard heads.
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-smoke", family="vlm",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        d_ff=96, vocab_size=256,
        pattern=("attn",), mlp="swiglu", norm="rmsnorm",
        rope_theta=10000.0, tie_embeddings=False,
        frontend="patches", frontend_dim=24, remat="none",
    )
