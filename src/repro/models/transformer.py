"""The unified transformer: one model, ten architectures.

A :class:`~repro.configs.base.ModelConfig` describes the stack as a repeating
``pattern`` of layer kinds (``attn`` / ``local_attn`` / ``rglru`` / ``ssd``),
each followed by a dense or MoE MLP (or none).  Repeated pattern groups are
parameter-stacked and driven by ``jax.lax.scan`` — the single most important
compile-time lever for the 512-device dry-run (HLO contains one group body,
not ``n_layers`` copies).  Remainder layers (n_layers % len(pattern)) are
unrolled.

Public API (all pure functions; ``params`` is a nested dict pytree):

- ``abstract_params(cfg)``                 -> ParamSpec tree
- ``init_params(key, cfg)``                -> fp32 parameter tree
- ``forward(params, cfg, batch, policy)``  -> (logits, aux_loss)
- ``loss_fn(params, cfg, batch)``          -> (loss, metrics)   [MPX-ready]
- ``abstract_cache(cfg, batch, max_seq)``  -> decode-state tree (ShapeDtype)
- ``decode(params, cfg, cache, tokens, pos)`` -> (logits, new_cache)
- ``init_paged_cache(cfg, n_pages, page_size, n_slots=...)`` -> per-layer-kind
  state-pool tree (paged K/V pools for attention layers; O(1) per-slot
  recurrent state for rglru/ssd layers)
- ``serve_forward(params, cfg, pages, table, tokens, start, valid)``
  -> (per-window-position logits (B, W, V), new_pages)
  [mixed chunked-prefill / ragged decode / speculative-verify steps,
  repro.serve — ``logit_idx`` names the W chunk positions to unembed]

Precision: the *caller* (``mpx.filter_value_and_grad``) casts params and
batch to the compute dtype; this module only pins the known-fragile spots to
fp32 (softmax, norms, router, recurrent gates/decays, softcaps, loss lse) —
exactly the paper's Example-1 discipline.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import mpx
from repro.configs.base import ModelConfig
from repro.nn import attention, embedding, moe as moe_lib, mlp as mlp_lib
from repro.nn import param as P
from repro.nn import rglru, ssd
from repro.nn.norms import apply_norm, norm_spec
from repro.sharding.rules import shard

PyTree = Any


# ==========================================================================
# parameter specs
# ==========================================================================

def _block_spec(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    spec: dict = {"pre_norm": norm_spec(cfg.norm, d)}
    if kind in ("attn", "local_attn"):
        spec["attn"] = attention.attention_spec(
            d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias, out_bias=cfg.out_bias)
    elif kind == "rglru":
        spec["rec"] = rglru.rglru_spec(d, cfg.d_rnn or d, cfg.conv_width)
    elif kind == "ssd":
        spec["ssd"] = ssd.ssd_spec(d, cfg.d_inner, cfg.ssm_heads,
                                   cfg.ssm_headdim, cfg.ssm_state,
                                   cfg.conv_width)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if cfg.post_norm:
        spec["post_mix_norm"] = norm_spec(cfg.norm, d)
    if cfg.mlp != "none":
        spec["mlp_norm"] = norm_spec(cfg.norm, d)
        if cfg.moe_experts > 0:
            spec["moe"] = moe_lib.moe_spec(d, cfg.d_ff, cfg.moe_experts,
                                           kind=cfg.mlp)
        else:
            spec["mlp"] = mlp_lib.mlp_spec(cfg.mlp, d, cfg.d_ff,
                                           bias=cfg.mlp_bias)
        if cfg.post_norm:
            spec["post_mlp_norm"] = norm_spec(cfg.norm, d)
    return spec


def _layout(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    """(n_scan_groups, remainder_kinds)."""
    lp = len(cfg.pattern)
    if not cfg.scan_layers:
        return 0, cfg.layer_kinds()
    n_groups = cfg.n_layers // lp
    rem = cfg.layer_kinds()[n_groups * lp:]
    return n_groups, rem


def abstract_params(cfg: ModelConfig) -> PyTree:
    n_groups, rem = _layout(cfg)
    spec: dict = {"embed": embedding.embedding_spec(cfg)}
    if n_groups > 0:
        group = {f"b{i}": _block_spec(cfg, kind)
                 for i, kind in enumerate(cfg.pattern)}
        spec["scan"] = P.stack_specs(group, n_groups, "layers")
    for j, kind in enumerate(rem):
        spec[f"tail{j}"] = _block_spec(cfg, kind)
    spec["final_norm"] = norm_spec(cfg.norm, cfg.d_model)
    un = embedding.unembed_spec(cfg)
    if un:
        spec["unembed"] = un
    return spec


def init_params(key: jax.Array, cfg: ModelConfig) -> PyTree:
    return P.initialize(key, abstract_params(cfg))


def param_shapes(cfg: ModelConfig) -> PyTree:
    return P.abstract(abstract_params(cfg))


def count_params(cfg: ModelConfig) -> int:
    return P.count_params(abstract_params(cfg))


# ==========================================================================
# forward (training / prefill)
# ==========================================================================

def _block_apply(cfg: ModelConfig, kind: str, p: PyTree, x: jnp.ndarray,
                 aux: jnp.ndarray, positions=None):
    h = shard(apply_norm(cfg.norm, p["pre_norm"], x),
              ("batch", "seq", "embed"))
    if kind in ("attn", "local_attn"):
        y = attention.attention_apply(
            p["attn"], h, n_heads=cfg.n_heads, causal=cfg.causal,
            window=cfg.window if kind == "local_attn" else 0,
            cap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            positions=positions)
    elif kind == "rglru":
        y = rglru.rglru_block_apply(p["rec"], h, conv_width=cfg.conv_width)
    else:  # ssd
        y = ssd.ssd_block_apply(p["ssd"], h, n_heads=cfg.ssm_heads,
                                headdim=cfg.ssm_headdim,
                                d_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
                                conv_width=cfg.conv_width)
    if cfg.post_norm:
        y = apply_norm(cfg.norm, p["post_mix_norm"], y)
    x = x + y
    if cfg.mlp != "none":
        h = shard(apply_norm(cfg.norm, p["mlp_norm"], x),
                  ("batch", "seq", "embed"))
        if cfg.moe_experts > 0:
            y, moe_aux = moe_lib.moe_apply(
                p["moe"], h, n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                kind=cfg.mlp, capacity_factor=cfg.capacity_factor)
            aux = aux + moe_aux
        else:
            y = mlp_lib.mlp_apply(cfg.mlp, p["mlp"], h)
        if cfg.post_norm:
            y = apply_norm(cfg.norm, p["post_mlp_norm"], y)
        x = x + y
    return shard(x, ("batch", "seq", "embed")), aux


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _embed_inputs(params, cfg: ModelConfig, batch: dict, dtype):
    """Build the (B,S,d) input sequence from the batch dict."""
    if cfg.frontend == "frames":
        return embedding.embed_frontend(params["embed"], cfg,
                                        batch["features"], dtype)
    x = embedding.embed_tokens(params["embed"], cfg, batch["inputs"], dtype)
    if cfg.frontend == "patches" and "patches" in batch:
        img = embedding.embed_frontend(params["embed"], cfg,
                                       batch["patches"], dtype)
        x = jnp.concatenate([img, x], axis=1)
    return x


def forward(params: PyTree, cfg: ModelConfig, batch: dict,
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """-> (logits (B,S,V) compute dtype, aux_loss fp32 scalar).

    For the VLM the returned logits cover only the text positions (the
    patch prefix is stripped before the head).
    """
    # compute dtype is whatever the (possibly mpx-cast) params arrived in
    dtype = params["embed"][next(iter(params["embed"]))].dtype
    x = _embed_inputs(params, cfg, batch, dtype)
    aux = jnp.zeros((), jnp.float32)
    n_groups, rem = _layout(cfg)

    if n_groups > 0:
        def group_body(carry, gparams):
            x, aux = carry
            for i, kind in enumerate(cfg.pattern):
                x, aux = _block_apply(cfg, kind, gparams[f"b{i}"], x, aux)
            return (x, aux), None

        body = _remat_wrap(cfg, group_body)
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["scan"])
    for j, kind in enumerate(rem):
        fn = _remat_wrap(cfg, functools.partial(_block_apply, cfg, kind))
        x, aux = fn(params[f"tail{j}"], x, aux)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    if cfg.frontend == "patches":
        n_patch = batch["patches"].shape[1] if "patches" in batch else 0
        if n_patch:
            x = x[:, n_patch:]
    logits = embedding.logits_fn(params["embed"], params.get("unembed", {}),
                                 cfg, x)
    return logits, aux


# ==========================================================================
# loss (MPX-ready: signature loss(model, batch))
# ==========================================================================

def _ce(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Cross-entropy with fp32 log-sum-exp (fused upcast, no fp32 (B,S,V))."""
    l32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(l32, axis=-1)
    ll = jnp.take_along_axis(l32, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def make_loss_fn(cfg: ModelConfig, moe_aux_weight: float = 0.01):
    """Returns ``loss(params, batch) -> (loss, metrics)`` for mpx.filter_*."""

    def loss_fn(params, batch):
        logits, aux = forward(params, cfg, batch)
        ce = _ce(logits, batch["targets"])
        loss = ce + moe_aux_weight * aux
        return loss, {"ce": ce, "moe_aux": aux}

    return loss_fn


# ==========================================================================
# decode (single-token serve step)
# ==========================================================================

def _block_state_spec(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                      dtype):
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else 0
        return attention.init_cache_spec(batch, max_seq, cfg.n_kv_heads,
                                         cfg.resolved_head_dim, window, dtype)
    if kind == "rglru":
        return rglru.rglru_state_spec(batch, cfg.d_rnn or cfg.d_model,
                                      cfg.conv_width, dtype)
    return ssd.ssd_state_spec(batch, cfg.d_inner, cfg.ssm_state,
                              cfg.ssm_heads, cfg.ssm_headdim,
                              cfg.conv_width, dtype)


def _stack_sds(tree: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree,
        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> PyTree:
    """Decode-state stand-ins mirroring the scan/tail parameter layout."""
    n_groups, rem = _layout(cfg)
    cache: dict = {}
    if n_groups > 0:
        group = {f"b{i}": _block_state_spec(cfg, kind, batch, max_seq, dtype)
                 for i, kind in enumerate(cfg.pattern)}
        cache["scan"] = _stack_sds(group, n_groups)
    for j, kind in enumerate(rem):
        cache[f"tail{j}"] = _block_state_spec(cfg, kind, batch, max_seq, dtype)
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> PyTree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_cache(cfg, batch, max_seq, dtype),
                        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))


def _block_decode(cfg: ModelConfig, kind: str, p: PyTree, st: PyTree,
                  x: jnp.ndarray, pos):
    h = apply_norm(cfg.norm, p["pre_norm"], x)
    if kind in ("attn", "local_attn"):
        y, st = attention.decode_step(
            p["attn"], st, h, pos, n_heads=cfg.n_heads,
            window=cfg.window if kind == "local_attn" else 0,
            cap=cfg.attn_softcap, rope_theta=cfg.rope_theta)
    elif kind == "rglru":
        y, st = rglru.rglru_block_apply(p["rec"], h,
                                        conv_width=cfg.conv_width, state=st)
    else:
        y, st = ssd.ssd_block_apply(p["ssd"], h, n_heads=cfg.ssm_heads,
                                    headdim=cfg.ssm_headdim,
                                    d_state=cfg.ssm_state,
                                    chunk=cfg.ssm_chunk,
                                    conv_width=cfg.conv_width, state=st)
    if cfg.post_norm:
        y = apply_norm(cfg.norm, p["post_mix_norm"], y)
    x = x + y
    if cfg.mlp != "none":
        h = apply_norm(cfg.norm, p["mlp_norm"], x)
        if cfg.moe_experts > 0:
            y, _ = moe_lib.moe_decode_apply(
                p["moe"], h, n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                kind=cfg.mlp)
        else:
            y = mlp_lib.mlp_apply(cfg.mlp, p["mlp"], h)
        if cfg.post_norm:
            y = apply_norm(cfg.norm, p["post_mlp_norm"], y)
        x = x + y
    return x, st


# ==========================================================================
# paged serving path (chunked prefill + ragged decode, repro.serve)
# ==========================================================================

_SERVABLE_KINDS = ("attn", "local_attn", "rglru", "ssd")
_RECURRENT_KINDS = ("rglru", "ssd")


def _require_paged_support(cfg: ModelConfig) -> None:
    bad = [k for k in cfg.layer_kinds() if k not in _SERVABLE_KINDS]
    if bad:
        raise ValueError(
            f"{cfg.name}: layer kind {bad[0]!r} has no serving state-pool "
            f"implementation; the paged state pool serves attention "
            f"(paged KV: 'attn', 'local_attn') and recurrent "
            f"(O(1) per-slot state: 'rglru', 'ssd') layer families")


def _pool_leaf_spec(cfg: ModelConfig, kind: str, n_pages: int,
                    page_size: int, n_slots: int, dtype,
                    kv_format: str) -> PyTree:
    """Per-layer-kind state-pool leaf: paged KV or per-slot decode state."""
    if kind in ("attn", "local_attn"):
        return attention.paged_cache_spec(
            n_pages, page_size, cfg.n_kv_heads, cfg.resolved_head_dim,
            dtype, kv_format=kv_format)
    return _block_state_spec(cfg, kind, n_slots, 0, dtype)


def abstract_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                         dtype=jnp.bfloat16, kv_format: str = "bf16",
                         n_slots: int = 1) -> PyTree:
    """Per-layer-kind state-pool stand-ins mirroring the scan/tail layout.

    Attention layers get one (n_pages, page_size, K, D) pool pair each;
    all of them share one page table (each has its own pool array), so the
    serve scheduler allocates pages once per sequence.  A quantized
    ``kv_format`` ("i8", "f8_e4m3", "f8_e3m4" — see :mod:`repro.quant`)
    stores those pools in the format's storage dtype and adds a
    (n_pages, K) fp32 amax-scale sidecar pair per layer; ``dtype`` then
    only names the bf16 passthrough layout.

    Recurrent layers ('rglru', 'ssd') instead carry O(1) per-slot decode
    state — batch dim ``n_slots``, no pages, no page-table entries: the
    RG-LRU hidden state and the SSD state accumulator stay fp32 (the MPX
    fragile-spot policy), conv buffers ride ``dtype``.  Scan groups carry
    the usual stacked leading dim over both kinds of leaves.
    """
    _require_paged_support(cfg)
    n_groups, rem = _layout(cfg)
    leaf = lambda kind: _pool_leaf_spec(  # noqa: E731
        cfg, kind, n_pages, page_size, n_slots, dtype, kv_format)
    cache: dict = {}
    if n_groups > 0:
        group = {f"b{i}": leaf(kind)
                 for i, kind in enumerate(cfg.pattern)}
        cache["scan"] = _stack_sds(group, n_groups)
    for j, kind in enumerate(rem):
        cache[f"tail{j}"] = leaf(kind)
    return cache


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16, kv_format: str = "bf16",
                     n_slots: int = 1) -> PyTree:
    # scale sidecars init to the quant SCALE_FLOOR via zeros -> floor is
    # irrelevant: zero pages dequantize to zero under any scale, and the
    # first write to a page installs a fresh amax scale
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_paged_cache(cfg, n_pages, page_size, dtype,
                                             kv_format=kv_format,
                                             n_slots=n_slots),
                        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))


def slot_state_mask(cfg: ModelConfig, kv_format: str = "bf16") -> PyTree:
    """Bool tree matching :func:`abstract_paged_cache`'s structure: True on
    per-slot recurrent state leaves (slot-indexed, reset on admit), False
    on paged KV pool leaves (page-indexed, recycled by the allocator)."""
    _require_paged_support(cfg)
    n_groups, rem = _layout(cfg)
    is_sds = lambda s: isinstance(s, jax.ShapeDtypeStruct)  # noqa: E731
    leaf = lambda kind: jax.tree.map(  # noqa: E731
        lambda _: kind in _RECURRENT_KINDS,
        _pool_leaf_spec(cfg, kind, 1, 1, 1, jnp.bfloat16, kv_format),
        is_leaf=is_sds)
    mask: dict = {}
    if n_groups > 0:
        mask["scan"] = {f"b{i}": leaf(kind)
                        for i, kind in enumerate(cfg.pattern)}
    for j, kind in enumerate(rem):
        mask[f"tail{j}"] = leaf(kind)
    return mask


def _block_serve(cfg: ModelConfig, kind: str, p: PyTree, pages: dict,
                 page_table, x: jnp.ndarray, positions, valid, *,
                 page_size: int, use_kernel: bool, pages_per_block: int = 1,
                 kv_format: str = "bf16"):
    h = apply_norm(cfg.norm, p["pre_norm"], x)
    if kind in ("attn", "local_attn"):
        y, pages = attention.paged_attend(
            p["attn"], pages, page_table, h, positions, valid,
            page_size=page_size, n_heads=cfg.n_heads,
            window=cfg.window if kind == "local_attn" else 0,
            cap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            use_kernel=use_kernel, pages_per_block=pages_per_block,
            kv_format=kv_format)
    elif kind == "rglru":
        y, pages = rglru.rglru_serve_chunk(p["rec"], h, pages, valid,
                                           conv_width=cfg.conv_width)
    else:  # ssd
        y, pages = ssd.ssd_serve_chunk(p["ssd"], h, pages, valid,
                                       n_heads=cfg.ssm_heads,
                                       headdim=cfg.ssm_headdim,
                                       d_state=cfg.ssm_state,
                                       conv_width=cfg.conv_width)
    if cfg.post_norm:
        y = apply_norm(cfg.norm, p["post_mix_norm"], y)
    x = x + y
    if cfg.mlp != "none":
        h = apply_norm(cfg.norm, p["mlp_norm"], x)
        if cfg.moe_experts > 0:
            y, _ = moe_lib.moe_decode_apply(
                p["moe"], h, n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                kind=cfg.mlp)
        else:
            y = mlp_lib.mlp_apply(cfg.mlp, p["mlp"], h)
        if cfg.post_norm:
            y = apply_norm(cfg.norm, p["post_mlp_norm"], y)
        x = x + y
    return x, pages


def serve_forward(params: PyTree, cfg: ModelConfig, pages: PyTree,
                  page_table: jnp.ndarray, tokens: jnp.ndarray,
                  start: jnp.ndarray, valid: jnp.ndarray, *,
                  page_size: int, logit_idx: Optional[jnp.ndarray] = None,
                  use_kernel: bool = False, pages_per_block: int = 1,
                  kv_format: str = "bf16") -> tuple[jnp.ndarray, PyTree]:
    """Unified serving step over the per-layer-kind state pool.

    tokens (B, C) with per-slot chunk ``start`` positions (B,) and ``valid``
    (B,) real-token counts (0 disables a slot).  Each slot is independent:
    one (B, C) step can mix prefill chunks (valid up to C), single decode
    tokens (valid = 1, start = current length) and speculative decode
    windows (valid = 1 + k: the committed token plus k proposed drafts) —
    the mixed-chunk plans :mod:`repro.serve.scheduler` emits.

    Attention layers scatter K/V into their paged pools and attend through
    the shared ``page_table``; recurrent layers ('rglru', 'ssd') ignore the
    table entirely and advance their O(1) per-slot state (batch row b IS
    slot b) via the ``*_serve_chunk`` entry points, whose masked
    per-position scans make padded chunk columns exact state no-ops — so
    greedy serving stays token-identical to per-token :func:`decode`
    across attn / ssm / rglru / hybrid stacks.

    Returns (logits (B, W, V), new pages): per-slot logits for the W chunk
    positions named by ``logit_idx`` (B, W) int32 — the slot's live window
    for speculative verification, or (the default when ``logit_idx`` is
    None) just each slot's last valid position with W = 1.  Gathering the
    window *before* the unembed keeps the (d, V) projection at W columns
    per slot instead of once per padded chunk position — the C-fold
    vocab-matmul saving survives speculation because W (typically <= 5) is
    far below C.

    ``use_kernel=True`` runs every full-attention layer through the Pallas
    paged-attention kernel (:mod:`repro.kernels.paged_attention`) —
    prefill, decode, mixed and speculative-window plans alike, one
    compiled program, no gathered dense copy of the cache;
    ``pages_per_block`` widens the kernel's K-blocks to span that many
    logical pages per grid step.

    ``kv_format`` ("bf16" | "i8" | "f8_e4m3" | "f8_e3m4", see
    :mod:`repro.quant`) must match the layout ``pages`` was built with
    (:func:`init_paged_cache`): quantized formats write-quantize each
    chunk's K/V into the pools (per-page/per-head amax scales in the
    fp32 sidecars) and dequantize on read — inside the kernel's VMEM
    blocks on the ``use_kernel`` path, so the sub-bf16 pool is the ONLY
    HBM-resident image of the cache.
    """
    _require_paged_support(cfg)
    dtype = params["embed"][next(iter(params["embed"]))].dtype
    x = embedding.embed_tokens(params["embed"], cfg, tokens, dtype)
    positions = start[:, None] + jnp.arange(tokens.shape[1])[None, :]
    n_groups, rem = _layout(cfg)
    new_pages: dict = {}

    if n_groups > 0:
        def group_body(x, scanned):
            gparams, gpages = scanned
            new_gpages = {}
            for i, kind in enumerate(cfg.pattern):
                x, new_gpages[f"b{i}"] = _block_serve(
                    cfg, kind, gparams[f"b{i}"], gpages[f"b{i}"],
                    page_table, x, positions, valid,
                    page_size=page_size, use_kernel=use_kernel,
                    pages_per_block=pages_per_block, kv_format=kv_format)
            return x, new_gpages

        x, new_pages["scan"] = jax.lax.scan(
            group_body, x, (params["scan"], pages["scan"]))
    for j, kind in enumerate(rem):
        x, new_pages[f"tail{j}"] = _block_serve(
            cfg, kind, params[f"tail{j}"], pages[f"tail{j}"],
            page_table, x, positions, valid,
            page_size=page_size, use_kernel=use_kernel,
            pages_per_block=pages_per_block, kv_format=kv_format)

    # gather the sampled window positions before the unembed so the (d, V)
    # projection runs over W positions per slot, not per padded chunk
    # position (C-fold less vocab-matmul work per step)
    if logit_idx is None:
        logit_idx = jnp.clip(valid - 1, 0)[:, None]          # (B, 1)
    x = x[jnp.arange(x.shape[0])[:, None], logit_idx]        # (B, W, d)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = embedding.logits_fn(params["embed"], params.get("unembed", {}),
                                 cfg, x)
    return logits, new_pages


def decode(params: PyTree, cfg: ModelConfig, cache: PyTree,
           tokens: jnp.ndarray, pos) -> tuple[jnp.ndarray, PyTree]:
    """One token for every sequence: tokens (B,1) -> logits (B,1,V)."""
    dtype = params["embed"][next(iter(params["embed"]))].dtype
    x = embedding.embed_tokens(params["embed"], cfg, tokens, dtype)
    n_groups, rem = _layout(cfg)
    new_cache: dict = {}

    if n_groups > 0:
        def group_body(x, scanned):
            gparams, gcache = scanned
            new_gcache = {}
            for i, kind in enumerate(cfg.pattern):
                x, st = _block_decode(cfg, kind, gparams[f"b{i}"],
                                      gcache[f"b{i}"], x, pos)
                new_gcache[f"b{i}"] = st
            return x, new_gcache

        x, new_cache["scan"] = jax.lax.scan(
            group_body, x, (params["scan"], cache["scan"]))
    for j, kind in enumerate(rem):
        x, new_cache[f"tail{j}"] = _block_decode(
            cfg, kind, params[f"tail{j}"], cache[f"tail{j}"], x, pos)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = embedding.logits_fn(params["embed"], params.get("unembed", {}),
                                 cfg, x)
    return logits, new_cache
