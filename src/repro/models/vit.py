"""Vision Transformer — the paper's own evaluation model (Section 5).

The MPX paper trains (a) a small ViT (feature size 256, one 800-wide hidden
layer per block) on CIFAR-100 on a desktop GPU, and (b) a ViT-Base
(768/3072) on ImageNet1k on 4×H100.  This module reproduces that model
functionally on top of the same nn substrate as the LM architectures, and
is what `examples/train_vit.py` + the paper-figure benchmarks drive.

Classification head over the CLS token; learned positional embeddings;
LayerNorm (fp32 statistics via the MPX rule) — matching the paper's
Example 1 structure (pre-LN blocks, fp32 softmax/norm, half-precision
matmuls).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import attention, mlp as mlp_lib
from repro.nn import param as P
from repro.nn.norms import apply_norm, norm_spec
from repro.nn.param import ParamSpec


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str = "vit-paper-desktop"
    image_size: int = 32
    patch_size: int = 4
    channels: int = 3
    d_model: int = 256
    n_layers: int = 6
    n_heads: int = 8
    d_ff: int = 800            # the paper's "one hidden layer of 800 neurons"
    n_classes: int = 100


#: the paper's two evaluation configs
PAPER_DESKTOP = ViTConfig()
VIT_BASE = ViTConfig(name="vit-base", image_size=224, patch_size=16,
                     d_model=768, n_layers=12, n_heads=12, d_ff=3072,
                     n_classes=1000)


def num_patches(cfg: ViTConfig) -> int:
    return (cfg.image_size // cfg.patch_size) ** 2


def abstract_params(cfg: ViTConfig):
    patch_dim = cfg.patch_size ** 2 * cfg.channels
    head_dim = cfg.d_model // cfg.n_heads
    block = {
        "norm1": norm_spec("layernorm", cfg.d_model),
        "attn": attention.attention_spec(cfg.d_model, cfg.n_heads,
                                         cfg.n_heads, head_dim,
                                         qkv_bias=True, out_bias=True),
        "norm2": norm_spec("layernorm", cfg.d_model),
        "mlp": mlp_lib.mlp_spec("gelu", cfg.d_model, cfg.d_ff, bias=True),
    }
    return {
        "patch_embed": ParamSpec((patch_dim, cfg.d_model),
                                 ("img_embed", "embed")),
        "cls": ParamSpec((1, 1, cfg.d_model), (None, None, "embed"),
                         init="zeros"),
        "pos": ParamSpec((1, num_patches(cfg) + 1, cfg.d_model),
                         (None, "patch", "embed"), init="embed", scale=0.02),
        "blocks": P.stack_specs(block, cfg.n_layers, "layers"),
        "final_norm": norm_spec("layernorm", cfg.d_model),
        "head": ParamSpec((cfg.d_model, cfg.n_classes), ("embed", "vocab")),
        "head_b": ParamSpec((cfg.n_classes,), ("vocab",), init="zeros"),
    }


def init_params(key, cfg: ViTConfig):
    return P.initialize(key, abstract_params(cfg))


def patchify(cfg: ViTConfig, images: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W, C) -> (B, N, patch_dim)."""
    b, h, w, c = images.shape
    p = cfg.patch_size
    x = images.reshape(b, h // p, p, w // p, p, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, (h // p) * (w // p), p * p * c)


def forward(params, cfg: ViTConfig, images: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W, C) -> (B, n_classes) logits.  Runs in the params' dtype."""
    dtype = params["patch_embed"].dtype
    x = patchify(cfg, images).astype(dtype) @ params["patch_embed"]
    cls = jnp.broadcast_to(params["cls"].astype(dtype),
                           (x.shape[0], 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1)
    x = x + params["pos"].astype(dtype)

    def block(x, p):
        h = apply_norm("layernorm", p["norm1"], x)
        x = x + attention.attention_apply(
            p["attn"], h, n_heads=cfg.n_heads, causal=False, window=0,
            cap=0.0, rope_theta=0.0, use_blocked=False)
        h = apply_norm("layernorm", p["norm2"], x)
        x = x + mlp_lib.mlp_apply("gelu", p["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(block, x, params["blocks"])
    x = apply_norm("layernorm", params["final_norm"], x)
    logits = x[:, 0] @ params["head"] + params["head_b"]
    return logits


def make_loss_fn(cfg: ViTConfig):
    """loss(params, batch={'images','labels'}) — fp32 lse (MPX-ready)."""

    def loss_fn(params, batch):
        logits = forward(params, cfg, batch["images"]).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, batch["labels"][:, None],
                                 axis=-1)[:, 0]
        loss = jnp.mean(lse - ll)
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                       .astype(jnp.float32))
        return loss, {"acc": acc}

    return loss_fn
