"""Span/event tracer exporting Chrome trace-event JSON (Perfetto-loadable).

Dependency-free, host-side, bounded.  The engine and trainer record spans
*around* their device steps — the tracer never touches a device array, so
enabling it adds zero device syncs to the hot path (pinned by a test).

Model:

- a :class:`Tracer` holds a ring buffer (``collections.deque(maxlen=...)``)
  of trace events — a runaway serve session overwrites its oldest events
  instead of growing without bound;
- the clock is injectable (``Tracer(clock=...)``) so tests can drive
  deterministic timelines; timestamps are microseconds relative to tracer
  construction (Chrome trace ``ts``);
- **spans** are "X" (complete) events with ``ts`` + ``dur`` — emitted on
  exit, so they nest exactly (a child's end is measured before its
  parent's);  **instants** are "i" events; ``thread_name`` metadata ("M")
  labels the per-``tid`` tracks.

Track convention used by :class:`repro.serve.engine.ServeEngine`:

- ``tid 0`` ("engine") carries the per-tick phases — ``tick`` wrapping
  ``admit`` / ``plan`` / ``device step`` / ``host sync`` / ``commit``;
- ``tid 1 + slot`` ("slot N") carries that slot's request lifecycle:
  ``submit``/``admit`` instants, one ``prefill`` span per chunk, one
  ``decode`` span per (speculative) window with
  ``{rid, tokens, drafts, accepted}`` args, ``truncate`` instants when a
  window's tail is rejected, and a ``retire`` instant.

Load the exported file in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``: a serve session renders as one timeline per slot
over the engine-phase track.

:func:`validate_chrome_trace` checks the schema the CI artifact relies on
(every event has ``ph``/``ts``/``pid``/``tid``; spans nest within a
track) without needing a browser; :func:`profiler_trace` is the optional
``jax.profiler`` hook — a context manager that brackets a run with
``start_trace``/``stop_trace`` when given a directory and is a no-op
otherwise.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional, Union


class Tracer:
    """Bounded ring-buffer trace recorder with an injectable clock."""

    def __init__(self, clock=time.perf_counter, max_events: int = 65536,
                 pid: int = 0, process_name: str = "repro"):
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1: {max_events}")
        self._clock = clock
        self._t0 = clock()
        self.pid = pid
        self.events: deque = deque(maxlen=max_events)
        # metadata events live outside the ring buffer: a long session
        # must not evict its track names
        self._meta: List[dict] = [{
            "ph": "M", "ts": 0.0, "pid": pid, "tid": 0,
            "name": "process_name", "args": {"name": process_name}}]
        self._named_tids: set = set()

    # -- recording ----------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since tracer construction (trace ``ts`` units)."""
        return (self._clock() - self._t0) * 1e6

    def thread_name(self, tid: int, name: str) -> None:
        """Label a track (idempotent per tid)."""
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self._meta.append({"ph": "M", "ts": 0.0, "pid": self.pid,
                           "tid": tid, "name": "thread_name",
                           "args": {"name": name}})

    def instant(self, name: str, tid: int = 0, **args) -> None:
        """A zero-duration marker ("i" event, thread scope)."""
        self.events.append({"ph": "i", "ts": self.now_us(), "pid": self.pid,
                            "tid": tid, "name": name, "s": "t",
                            "args": args})

    def complete(self, name: str, ts_us: float, dur_us: float,
                 tid: int = 0, args: Optional[dict] = None) -> None:
        """An explicit "X" span — for spans whose interval is known after
        the fact (e.g. per-slot windows sharing the device-step interval).
        """
        self.events.append({"ph": "X", "ts": ts_us, "pid": self.pid,
                            "tid": tid, "name": name,
                            "dur": max(dur_us, 0.0), "args": args or {}})

    @contextmanager
    def span(self, name: str, tid: int = 0, **args):
        """Context-managed "X" span; emitted on exit so children are
        recorded (and end) before their parent."""
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(name, t0, self.now_us() - t0, tid=tid, args=args)

    def counter(self, name: str, values: Dict[str, float],
                tid: int = 0) -> None:
        """A "C" counter sample (renders as a stacked area track)."""
        self.events.append({"ph": "C", "ts": self.now_us(), "pid": self.pid,
                            "tid": tid, "name": name, "args": dict(values)})

    # -- export -------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object (``traceEvents`` form)."""
        return {"traceEvents": self._meta + list(self.events),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        """Write the trace JSON (open in Perfetto / chrome://tracing)."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


def validate_chrome_trace(
        trace: Union[dict, Iterable[dict]]) -> List[dict]:
    """Validate trace-event schema; returns the event list.

    Checks the invariants the CI artifact consumers rely on:

    - every event is a JSON object carrying ``ph``, ``ts``, ``pid``,
      ``tid`` and ``name``, with a numeric ``ts``;
    - "X" events carry a numeric, non-negative ``dur``;
    - "C" (counter) events carry a non-empty ``args`` dict of numeric
      series — Perfetto silently drops malformed counters, so a schema
      bug here would otherwise pass validation and render as nothing;
    - within each ``(pid, tid)`` track, "X" spans strictly nest — no
      partial overlap (guaranteed by construction: a ``span()`` is
      emitted on exit, after every child has ended).  Instants and
      counters never participate in nesting, and a ring buffer that
      evicted a span's *parent* still validates: children are emitted
      (and evicted) before their parents, so any suffix of the event
      stream keeps the nesting invariant.

    Raises ``ValueError`` naming the first offending event.
    """
    if isinstance(trace, dict):
        events = trace.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("trace object has no traceEvents list")
    else:
        events = list(trace)

    def _numeric(v) -> bool:
        return isinstance(v, (int, float)) and not isinstance(v, bool)

    tracks: Dict[tuple, List[dict]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(
                f"event {i} is {type(ev).__name__}, not a trace-event "
                f"object")
        for field in ("ph", "ts", "pid", "tid", "name"):
            if field not in ev:
                raise ValueError(f"event {i} ({ev.get('name')!r}) missing "
                                 f"required field {field!r}")
        if not _numeric(ev["ts"]):
            raise ValueError(
                f"event {i} ({ev['name']!r}): ts must be a number, got "
                f"{ev['ts']!r}")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not _numeric(dur) or dur < 0:
                raise ValueError(
                    f"event {i} ({ev['name']!r}): X event needs dur >= 0")
            tracks.setdefault((ev["pid"], ev["tid"]), []).append(ev)
        elif ev["ph"] == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                raise ValueError(
                    f"event {i} ({ev['name']!r}): C (counter) event needs "
                    f"a non-empty args dict of numeric series, got "
                    f"{args!r}")
            for series, v in args.items():
                if not _numeric(v):
                    raise ValueError(
                        f"event {i} ({ev['name']!r}): counter series "
                        f"{series!r} must be numeric, got {v!r}")
    for (pid, tid), spans in tracks.items():
        # sort children-inside-parents: by start, widest first on ties
        spans.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: List[dict] = []
        for ev in spans:
            end = ev["ts"] + ev["dur"]
            while stack and ev["ts"] >= stack[-1]["ts"] + stack[-1]["dur"]:
                stack.pop()
            if stack and end > stack[-1]["ts"] + stack[-1]["dur"]:
                raise ValueError(
                    f"track (pid={pid}, tid={tid}): span "
                    f"{ev['name']!r} [{ev['ts']}, {end}] partially "
                    f"overlaps {stack[-1]['name']!r} — spans must nest")
            stack.append(ev)
    return events


@contextmanager
def profiler_trace(trace_dir: Optional[str] = None):
    """Optional ``jax.profiler`` hook: bracket a run with a device-level
    trace when ``trace_dir`` is set; exact no-op when ``None``.

    The resulting TensorBoard/Perfetto trace carries the *device* view
    (kernel launches, transfers) that complements the host-side
    :class:`Tracer` timeline.
    """
    if not trace_dir:
        yield
        return
    import jax
    jax.profiler.start_trace(trace_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
