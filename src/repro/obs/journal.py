"""Flight recorder: event-sourced ServeEngine journal + deterministic replay.

Turns "a request timed out in CI at 02:00" into a checked-in repro
artifact: a :class:`JournalRecorder` attached via
``ServeEngine(journal=...)`` event-sources **every external input** to a
serve drive, and :func:`replay_journal` reconstructs the engine from the
journal alone and re-drives it, asserting token identity and per-tick
digest equality — the serving analogue of MPX §3.3's discipline of making
invisible events (overflow → halve → recover) first-class inspectable
records instead of silent retries.

Journal schema (versioned, append-only JSONL — one JSON object per line)::

    {"ev":"header","schema":1,"config":{...},"engine":{...},
     "faults":{...}|null,"param_seed":N|null}
    {"ev":"clocks","v":[t0,t1,...]}          # batched clock samples
    {"ev":"submit","rid":R,"prompt":[...],"max_new":N,"deadline_ms":D}
    {"ev":"cancel","rid":R}
    {"ev":"tick","i":N,"d":{...}}            # per-tick digest (below)
    {"ev":"result","rid":R,"status":S,"tokens":[...],"m":{...}}
    {"ev":"truncated"}                       # max_events bound was hit

The header carries the **config fingerprint**: the full
:class:`~repro.configs.base.ModelConfig`, every engine constructor knob
(slots, pool geometry, chunking, kv format, sampling, seeds, admission
and preemption policy), and the :class:`~repro.serve.faults.FaultInjector`
schedule captured *before* any tick fires.  ``clocks`` records every
sample the engine drew from its clock, in order — deadlines, metrics and
admission estimates are all functions of these samples, so replay feeds
them back verbatim instead of re-reading a wall clock.

The per-tick digest ``d`` is built from host-side plan state the engine
already holds (recording adds **zero device syncs**; the
two-transfers-per-step pin in tests/test_obs.py holds with the journal
enabled): plan kind and token/draft counts, admitted/preempted request
ids, this tick's accepted-token count, finished ``[rid, status]`` pairs,
a pool digest ``[free, used, cached, shared, held]`` pages, cumulative
prefix/COW counters, and ``tok`` — a rolling blake2b chain over each
valid slot's ``(slot, rid, token, accept)`` — so a single flipped sampled
token at tick N changes every digest from N on.

Replay guarantees and limits:

- :func:`replay_journal` rebuilds the engine **from the header** (params
  re-initialized from ``param_seed``, or passed in), re-drives the
  recorded submit/cancel/step sequence, and compares digests tick by
  tick; the first mismatch raises :class:`JournalDivergence` naming the
  **first divergent tick** with both digests.
- Replay requires the same config fingerprint: the replayed engine's
  fingerprint is checked against the header at attach time
  (:class:`JournalMismatch` on drift), so a journal cannot silently
  replay against different weights geometry, pool sizing or policy.
- Custom :class:`~repro.serve.propose.Proposer` instances cannot be
  serialized — a journal recorded with one replays only when an
  equivalent instance is passed to ``replay_journal(..., proposer=...)``.
- Determinism holds per backend: a journal recorded on CPU replays
  token-identically on CPU (CI records and replays in one job); across
  backends the digests are still the ground truth for triage.
- A journal that hit its ``max_events`` bound is marked ``truncated``
  and refuses to replay (the input stream is incomplete) — the bound
  exists so a runaway session cannot fill the disk.

CLI::

    python -m repro.obs.journal <journal.jsonl>    # replay; exit 1 on
                                                   # divergence

``python -m repro.obs.postmortem`` renders the same journal as a
per-request incident report (see :mod:`repro.obs.postmortem`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 1

#: seed of the rolling token-hash chain (versioned with the schema)
_TOK_SEED = b"repro.journal.v1"


class JournalError(RuntimeError):
    """Base class for journal recording/replay failures."""


class JournalMismatch(JournalError):
    """The replayed engine's config fingerprint differs from the header —
    replay requires the same config fingerprint."""


class JournalTruncated(JournalError):
    """The recording hit its ``max_events`` bound: the input event stream
    is incomplete, so the drive cannot be reconstructed."""


class JournalDivergence(JournalError):
    """Replay produced a different per-tick digest than the journal
    recorded.  Carries the first divergent tick and both digests."""

    def __init__(self, tick: int, recorded: dict, replayed: dict):
        self.tick = tick
        self.recorded = recorded
        self.replayed = replayed
        super().__init__(
            f"replay diverged at tick {tick}:\n"
            f"  recorded: {json.dumps(recorded, sort_keys=True)}\n"
            f"  replayed: {json.dumps(replayed, sort_keys=True)}")


def _chain(prev: bytes, tok_items: Sequence[Tuple[int, int, int, int]]
           ) -> bytes:
    """Advance the rolling token hash over one tick's (slot, rid, token,
    accept) tuples."""
    h = hashlib.blake2b(prev, digest_size=16)
    for slot, rid, token, accept in tok_items:
        h.update(f"{slot}:{rid}:{token}:{accept};".encode())
    return h.digest()


def _normalize(obj):
    """JSON round-trip: tuples become lists, keys become strings — so a
    freshly built digest compares equal to one read back from disk."""
    return json.loads(json.dumps(obj))


class _JournalHook:
    """Shared recorder/replayer state: tick numbering + the rolling
    token-hash chain (both sides must compute it identically)."""

    def __init__(self):
        self._tok = _TOK_SEED
        self._n_ticks = 0

    def _tick_digest(self, digest: dict, tok_items) -> dict:
        self._tok = _chain(self._tok, tok_items)
        d = dict(digest)
        d["tok"] = self._tok.hex()
        return d


class JournalRecorder(_JournalHook):
    """Append-only JSONL flight recorder for one ``ServeEngine`` drive.

    Attach at construction — ``ServeEngine(cfg, params, journal=rec)`` —
    and the engine records its config fingerprint, fault schedule, every
    clock sample, ``submit``/``cancel`` call, per-tick digest, and
    per-request result.  ``param_seed`` (optional) makes the journal
    self-contained: :func:`replay_journal` re-initializes params from it
    (``init_params(key(param_seed), cfg)`` cast to bf16 — the convention
    every bench/test drive uses); without it, replay needs ``params=``.

    Writes are flushed per event, so a crashed drive still leaves a
    usable journal (that is the point of a flight recorder).  The file is
    bounded by ``max_events``: past the bound the journal is marked
    truncated and further events are dropped — a truncated journal
    refuses to replay but still feeds the postmortem analyzer.
    """

    def __init__(self, path, *, param_seed: Optional[int] = None,
                 max_events: int = 1_000_000):
        super().__init__()
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1: {max_events}")
        self.path = str(path)
        self.param_seed = param_seed
        self.max_events = int(max_events)
        self._f = open(self.path, "w")
        self._clock_buf: List[float] = []
        self._n_events = 0
        self.truncated = False
        self.attached = False

    # -- engine-facing hooks (duck-typed: the engine never imports us) ------

    def wrap_clock(self, inner: Callable[[], float]) -> Callable[[], float]:
        def clock() -> float:
            v = inner()
            if not self.truncated:
                self._clock_buf.append(v)
            return v
        return clock

    def on_attach(self, fingerprint: dict, faults) -> None:
        if self.attached:
            raise JournalError(
                "a JournalRecorder records exactly one engine drive — "
                "attach a fresh recorder per ServeEngine")
        self.attached = True
        header = {"ev": "header", "schema": SCHEMA_VERSION,
                  "param_seed": self.param_seed,
                  "faults": (faults.schedule() if faults is not None
                             else None)}
        header.update(fingerprint)          # "config" + "engine"
        self._write(header, count=False)
        self._f.flush()

    def record_submit(self, rid: int, prompt: Sequence[int], max_new: int,
                      deadline_ms: Optional[float]) -> None:
        self._flush_clocks()
        self._write({"ev": "submit", "rid": rid, "prompt": list(prompt),
                     "max_new": max_new, "deadline_ms": deadline_ms})
        self._f.flush()

    def record_cancel(self, rid: int) -> None:
        self._flush_clocks()
        self._write({"ev": "cancel", "rid": rid})
        self._f.flush()

    def record_tick(self, digest: dict, tok_items) -> None:
        d = self._tick_digest(digest, tok_items)
        i = self._n_ticks
        self._n_ticks += 1
        self._flush_clocks()
        self._write({"ev": "tick", "i": i, "d": d})
        self._f.flush()

    def record_result(self, result) -> None:
        rm = result.metrics
        self._flush_clocks()
        self._write({"ev": "result", "rid": result.request_id,
                     "status": result.status,
                     "tokens": list(result.tokens),
                     "m": {"prompt_len": rm.prompt_len,
                           "ttft": rm.ttft,
                           "queue_wait": rm.queue_wait,
                           "prefill_s": rm.prefill_seconds,
                           "decode_s": rm.decode_seconds,
                           "preempted_s": rm.preempted_seconds,
                           "preemptions": rm.preemptions,
                           "cached_prefix": rm.cached_prefix_tokens,
                           "proposed": rm.proposed_tokens,
                           "accepted": rm.accepted_tokens,
                           "error": rm.error}})
        self._f.flush()

    # -- lifecycle ----------------------------------------------------------

    def close(self) -> None:
        if not self._f.closed:
            self._flush_clocks()
            self._f.flush()
            self._f.close()

    def __enter__(self) -> "JournalRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- internals ----------------------------------------------------------

    def _flush_clocks(self) -> None:
        if self._clock_buf and not self.truncated:
            buf, self._clock_buf = self._clock_buf, []
            self._write({"ev": "clocks", "v": buf})

    def _write(self, obj: dict, count: bool = True) -> None:
        if self.truncated:
            return
        if count:
            self._n_events += 1
            if self._n_events > self.max_events:
                self.truncated = True
                self._f.write(json.dumps({"ev": "truncated"}) + "\n")
                self._f.flush()
                return
        self._f.write(json.dumps(obj) + "\n")


def read_journal(path) -> Tuple[dict, List[dict]]:
    """Parse a journal file into ``(header, events)``.

    Raises :class:`JournalError` with the offending line number on
    malformed input, a missing header, or a schema-version mismatch.
    """
    header: Optional[dict] = None
    events: List[dict] = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as err:
                raise JournalError(
                    f"{path}:{lineno}: not valid JSON ({err}) — the "
                    f"journal is corrupt or not a journal at all")
            if not isinstance(obj, dict) or "ev" not in obj:
                raise JournalError(
                    f"{path}:{lineno}: journal records are objects with "
                    f"an 'ev' field, got {obj!r}")
            if obj["ev"] == "header":
                if header is not None:
                    raise JournalError(
                        f"{path}:{lineno}: second header record — one "
                        f"journal holds exactly one engine drive")
                header = obj
            else:
                events.append(obj)
    if header is None:
        raise JournalError(
            f"{path}: no header record — not a flight-recorder journal")
    if header.get("schema") != SCHEMA_VERSION:
        raise JournalError(
            f"{path}: journal schema v{header.get('schema')!r}, this "
            f"build reads v{SCHEMA_VERSION} — replay with a matching "
            f"checkout")
    return header, events


def _config_from_dict(d: dict):
    """Rebuild a ModelConfig from its JSON form (lists -> tuples)."""
    from repro.configs.base import ModelConfig
    kw = {}
    for k, v in d.items():
        if isinstance(v, list):
            v = tuple(tuple(x) if isinstance(x, list) else x for x in v)
        kw[k] = v
    return ModelConfig(**kw)


def _diff_paths(recorded, live, prefix: str = "") -> List[str]:
    out: List[str] = []
    if isinstance(recorded, dict) and isinstance(live, dict):
        for k in sorted(set(recorded) | set(live)):
            p = f"{prefix}.{k}" if prefix else str(k)
            out += _diff_paths(recorded.get(k), live.get(k), p)
    elif recorded != live:
        out.append(f"{prefix}: recorded {recorded!r} vs engine {live!r}")
    return out


class _Replayer(_JournalHook):
    """The replay-side journal hook: feeds recorded clock samples back to
    the engine and compares each per-tick digest against the recording,
    raising :class:`JournalDivergence` at the first mismatch."""

    def __init__(self, header: dict, events: List[dict]):
        super().__init__()
        self.header = header
        self._samples: deque = deque()
        for ev in events:
            if ev["ev"] == "clocks":
                self._samples.extend(ev["v"])
        self.ticks = [ev for ev in events if ev["ev"] == "tick"]
        # results written after the final tick belong to a tick that
        # aborted mid-flight (a real, non-injected exception): the tick
        # itself was never journaled, so replay cannot re-create them —
        # keep them out of the coverage check
        last_tick = max((i for i, ev in enumerate(events)
                         if ev["ev"] == "tick"), default=-1)
        self.results = {ev["rid"]: ev for i, ev in enumerate(events)
                        if ev["ev"] == "result" and i < last_tick}
        self.aborted_results = [ev for i, ev in enumerate(events)
                                if ev["ev"] == "result" and i > last_tick]
        self._last_sample = 0.0
        self._seen_rids: set = set()
        self._i = 0
        self.ticks_compared = 0
        self.result_mismatches: List[dict] = []
        self.clock_exhausted = False

    # -- engine-facing hooks ------------------------------------------------

    def wrap_clock(self, inner: Callable[[], float]) -> Callable[[], float]:
        def clock() -> float:
            if self._samples:
                self._last_sample = self._samples.popleft()
            else:
                # more clock reads than recorded: control flow already
                # diverged — keep time frozen so the digest comparison
                # (not an IndexError) names the divergent tick
                self.clock_exhausted = True
            return self._last_sample
        return clock

    def on_attach(self, fingerprint: dict, faults) -> None:
        recorded = _normalize({"config": self.header["config"],
                               "engine": self.header["engine"]})
        live = _normalize(fingerprint)
        if recorded != live:
            diffs = _diff_paths(recorded, live)
            raise JournalMismatch(
                "replay requires the same config fingerprint the journal "
                "was recorded with; the replayed engine differs at:\n  "
                + "\n  ".join(diffs))

    def record_submit(self, rid, prompt, max_new, deadline_ms) -> None:
        pass

    def record_cancel(self, rid) -> None:
        pass

    def record_tick(self, digest: dict, tok_items) -> None:
        d = _normalize(self._tick_digest(digest, tok_items))
        i = self._i
        self._i += 1
        if i >= len(self.ticks):
            raise JournalDivergence(
                i, {"missing": "journal recorded no tick at this index"},
                d)
        rec = _normalize(self.ticks[i]["d"])
        if rec != d:
            raise JournalDivergence(i, rec, d)
        self.ticks_compared += 1

    def record_result(self, result) -> None:
        rid = result.request_id
        self._seen_rids.add(rid)
        rec = self.results.get(rid)
        if rec is None:
            self.result_mismatches.append(
                {"rid": rid, "recorded": None,
                 "replayed": {"status": result.status,
                              "tokens": list(result.tokens)}})
            return
        if (rec["status"] != result.status
                or list(rec["tokens"]) != list(result.tokens)):
            self.result_mismatches.append(
                {"rid": rid,
                 "recorded": {"status": rec["status"],
                              "tokens": rec["tokens"]},
                 "replayed": {"status": result.status,
                              "tokens": list(result.tokens)}})

    def finish(self) -> None:
        """Flag recorded results the replay never produced."""
        for rid in sorted(set(self.results) - self._seen_rids):
            rec = self.results[rid]
            self.result_mismatches.append(
                {"rid": rid,
                 "recorded": {"status": rec["status"],
                              "tokens": rec["tokens"]},
                 "replayed": None})


@dataclasses.dataclass
class ReplayReport:
    """Outcome of :func:`replay_journal`."""
    ok: bool
    ticks: int                       # ticks replayed with equal digests
    results: int                     # recorded results checked
    divergence: Optional[JournalDivergence] = None
    result_mismatches: List[dict] = dataclasses.field(default_factory=list)
    aborted_results: int = 0         # results of a tick that never journaled
    clock_exhausted: bool = False

    def summary(self) -> str:
        if self.ok:
            extra = (f" ({self.aborted_results} result(s) from an aborted "
                     f"final tick skipped)" if self.aborted_results else "")
            return (f"replay OK: {self.ticks} ticks digest-identical, "
                    f"{self.results} request results token-identical"
                    f"{extra}")
        lines = [f"replay FAILED after {self.ticks} matching ticks"]
        if self.divergence is not None:
            lines.append(str(self.divergence))
        for mm in self.result_mismatches:
            lines.append(f"  result mismatch rid={mm['rid']}: "
                         f"recorded={mm['recorded']} "
                         f"replayed={mm['replayed']}")
        return "\n".join(lines)


def replay_journal(path, params=None, proposer=None,
                   raise_on_divergence: bool = True) -> ReplayReport:
    """Reconstruct the engine from a journal and re-drive it.

    Rebuilds the :class:`~repro.configs.base.ModelConfig`, engine knobs,
    sampling params and :class:`~repro.serve.faults.FaultInjector`
    schedule from the header; initializes params from the recorded
    ``param_seed`` (or uses ``params``); drives the engine's clock from
    the recorded samples; then replays the recorded submit/cancel/step
    sequence, comparing every per-tick digest and every request result.

    Returns a :class:`ReplayReport`.  With ``raise_on_divergence`` (the
    default) a digest mismatch raises :class:`JournalDivergence` naming
    the first divergent tick with both digests, and result mismatches
    raise :class:`JournalError`.
    """
    header, events = read_journal(path)
    if any(ev["ev"] == "truncated" for ev in events):
        raise JournalTruncated(
            f"{path}: the recording hit its max_events bound mid-drive, "
            f"so the input event stream is incomplete and the drive "
            f"cannot be reconstructed — re-record with "
            f"JournalRecorder(max_events=...) sized for the drive (the "
            f"postmortem analyzer still reads the truncated journal)")

    # replay needs the engine (and thus jax); keep `import
    # repro.obs.journal` stdlib-only for recording-side consumers
    import jax

    from repro import mpx
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine
    from repro.serve.faults import FaultInjector
    from repro.serve.sampling import SamplingParams

    cfg = _config_from_dict(header["config"])
    if params is None:
        seed = header.get("param_seed")
        if seed is None:
            raise JournalError(
                f"{path}: the journal carries no param_seed and no "
                f"params were passed — record with JournalRecorder(path, "
                f"param_seed=...) for a self-contained journal, or call "
                f"replay_journal(path, params=...)")
        params = mpx.cast_to_bfloat16(
            T.init_params(jax.random.key(int(seed)), cfg))

    ekw = dict(header["engine"])
    sampling = SamplingParams(**ekw.pop("sampling"))
    prop_name = ekw.pop("proposer")
    if proposer is None and prop_name not in (None, "NGramProposer"):
        raise JournalError(
            f"{path}: recorded with a custom proposer {prop_name!r}, "
            f"which cannot be serialized — pass an equivalent instance "
            f"via replay_journal(..., proposer=...)")
    fault_sched = header.get("faults")
    faults = (FaultInjector.from_schedule(fault_sched)
              if fault_sched else None)

    rep = _Replayer(header, events)
    engine = ServeEngine(cfg, params, sampling=sampling, proposer=proposer,
                         faults=faults, journal=rep, **ekw)
    divergence: Optional[JournalDivergence] = None
    try:
        for ev in events:
            kind = ev["ev"]
            if kind == "submit":
                engine.submit(ev["prompt"], max_new=ev["max_new"],
                              request_id=ev["rid"],
                              deadline_ms=ev["deadline_ms"])
            elif kind == "cancel":
                engine.cancel(ev["rid"])
            elif kind == "tick":
                engine.step()
    except JournalDivergence as err:
        divergence = err
    if divergence is None:
        rep.finish()
    report = ReplayReport(
        ok=divergence is None and not rep.result_mismatches,
        ticks=rep.ticks_compared, results=len(rep.results),
        divergence=divergence, result_mismatches=rep.result_mismatches,
        aborted_results=len(rep.aborted_results),
        clock_exhausted=rep.clock_exhausted)
    if raise_on_divergence and divergence is not None:
        raise divergence
    if raise_on_divergence and report.result_mismatches:
        raise JournalError(report.summary())
    return report


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.journal",
        description="Replay a ServeEngine flight-recorder journal and "
                    "verify token identity + per-tick digest equality.")
    ap.add_argument("journal", help="journal JSONL recorded via "
                                    "ServeEngine(journal=JournalRecorder(...))")
    args = ap.parse_args(argv)
    try:
        report = replay_journal(args.journal, raise_on_divergence=False)
    except JournalError as err:
        print(f"replay error: {err}")
        return 2
    print(report.summary())
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
