"""Postmortem analyzer: join a flight-recorder journal with the Chrome
trace, Prometheus snapshot and precision telemetry into an incident
report.

The journal (:mod:`repro.obs.journal`) is the event-sourced ground truth
of one ``ServeEngine`` drive; the other observability artifacts each see
a different projection of the same drive (spans, counters, loss-scale
trajectory).  ``analyze()`` reassembles them into a **per-request causal
story**: where each request's latency went (queue wait vs prefill vs
decode vs preempted-recompute), what happened to it (preemptions, COW
copies, prefix hits, speculative accept rate, deadline/cancel/nonfinite
outcome), and — when a training-side
:class:`~repro.obs.precision.PrecisionStats` export is supplied — the
loss-scale trajectory behind any nonfinite event.

CLI::

    python -m repro.obs.postmortem <journal.jsonl> \
        [--trace serving_trace.json] [--metrics serving_metrics.prom] \
        [--precision quickstart_precision.json] [--out report.md]

All joins are optional: the report renders from the journal alone and
grows sections as artifacts are supplied.  ``--trace`` accepts the
engine's ``Tracer`` export (validated via
:func:`~repro.obs.trace.validate_chrome_trace` first — a malformed
artifact fails loudly, not silently); ``--metrics`` a Prometheus text
snapshot (such as the bench's ``--metrics-out``); ``--precision`` either
the quickstart's JSON snapshot (with ``loss_scale_trajectory``) or a
Prometheus text export of the precision registry.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

from repro.obs.journal import read_journal

_SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)$')
_LABEL_RE = re.compile(r'(\w+)="((?:[^"\\]|\\.)*)"')


def _unescape(v: str) -> str:
    return re.sub(r"\\(.)",
                  lambda m: {"n": "\n"}.get(m.group(1), m.group(1)), v)


def parse_prometheus(text: str) -> Dict[str, float]:
    """Parse Prometheus text exposition into ``{series_name: value}`` —
    the inverse of ``Registry.snapshot()``'s naming (label values
    unescaped)."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            continue
        name, labels, value = m.groups()
        key = name
        if labels:
            inner = ",".join(
                f'{k}="{_unescape(v)}"'
                for k, v in _LABEL_RE.findall(labels))
            key = f"{name}{{{inner}}}"
        try:
            out[key] = float(value)
        except ValueError:
            continue
    return out


def _fmt_s(v: Optional[float]) -> str:
    if v is None:
        return "-"
    if v >= 1.0:
        return f"{v:.3f}s"
    return f"{v * 1e3:.2f}ms"


def _pct(a: Optional[float], b: Optional[float]) -> str:
    if a is None or b is None or b <= 0:
        return ""
    return f" ({100.0 * a / b:.0f}%)"


def _request_report(rid: int, sub: dict, res: Optional[dict],
                    cancelled: set, trace_spans: Optional[dict],
                    tick_events: Dict[int, List[str]]) -> List[str]:
    lines = [f"### request {rid}"]
    dl = sub.get("deadline_ms")
    lines.append(
        f"- submitted: prompt {len(sub['prompt'])} tokens, "
        f"max_new {sub['max_new']}"
        + (f", deadline {dl:g}ms" if dl is not None else ""))
    for ev in tick_events.get(rid, ()):
        lines.append(f"- {ev}")
    if res is None:
        verdict = ("cancel requested, never retired"
                   if rid in cancelled else "in flight")
        lines.append(f"- **no result in journal** ({verdict} when the "
                     f"recording stopped)")
        return lines
    m = res.get("m", {})
    total = None
    if m.get("queue_wait") is not None and m.get("prefill_s") is not None \
            and m.get("decode_s") is not None:
        total = m["queue_wait"] + m["prefill_s"] + m["decode_s"]
    lines.append(
        f"- outcome: **{res['status']}**, {len(res['tokens'])} tokens"
        + (f" — {m['error']}" if m.get("error") else ""))
    phases = [("queue wait", m.get("queue_wait")),
              ("prefill", m.get("prefill_s")),
              ("decode", m.get("decode_s"))]
    phase_txt = ", ".join(
        f"{name} {_fmt_s(v)}{_pct(v, total)}" for name, v in phases)
    lines.append(f"- phases: {phase_txt} "
                 f"(TTFT {_fmt_s(m.get('ttft'))})")
    attribution = []
    if m.get("preemptions"):
        attribution.append(
            f"preempted {m['preemptions']}x "
            f"({_fmt_s(m.get('preempted_s'))} evicted + recompute)")
    if m.get("cached_prefix"):
        attribution.append(
            f"prefix cache absorbed {m['cached_prefix']} prefill tokens")
    if m.get("proposed"):
        rate = m.get("accepted", 0) / max(m["proposed"], 1)
        attribution.append(
            f"speculation accepted {m.get('accepted', 0)}/{m['proposed']} "
            f"drafts ({rate:.0%})")
    if attribution:
        lines.append("- attribution: " + "; ".join(attribution))
    if trace_spans is not None and rid in trace_spans:
        spans = trace_spans[rid]
        parts = [f"{name} {n}x/{_fmt_s(dur / 1e6)}"
                 for name, (n, dur) in sorted(spans.items())]
        lines.append(f"- trace: {', '.join(parts)}")
    return lines


def analyze(journal_path, trace_path=None, metrics_path=None,
            precision_path=None) -> dict:
    """Join the artifacts into a structured report (see :func:`render`)."""
    header, events = read_journal(journal_path)
    truncated = any(ev["ev"] == "truncated" for ev in events)
    submits = {ev["rid"]: ev for ev in events if ev["ev"] == "submit"}
    results = {ev["rid"]: ev for ev in events if ev["ev"] == "result"}
    cancelled = {ev["rid"] for ev in events if ev["ev"] == "cancel"}
    ticks = [ev for ev in events if ev["ev"] == "tick"]

    # per-request lifecycle markers scanned out of the tick digests
    tick_events: Dict[int, List[str]] = {}
    for t in ticks:
        d = t["d"]
        for rid in d.get("admitted", ()):
            tick_events.setdefault(rid, []).append(
                f"admitted at tick {t['i']}")
        for rid in d.get("preempted", ()):
            tick_events.setdefault(rid, []).append(
                f"preempted at tick {t['i']}")
        for rid, status in d.get("finished", ()):
            tick_events.setdefault(rid, []).append(
                f"retired at tick {t['i']} ({status})")

    kinds: Dict[str, int] = {}
    for t in ticks:
        k = t["d"].get("kind", "?")
        kinds[k] = kinds.get(k, 0) + 1
    statuses: Dict[str, int] = {}
    for res in results.values():
        statuses[res["status"]] = statuses.get(res["status"], 0) + 1
    last = ticks[-1]["d"] if ticks else {}

    trace_spans: Optional[Dict[int, Dict[str, Tuple[int, float]]]] = None
    engine_phases: Optional[Dict[str, Tuple[int, float]]] = None
    if trace_path is not None:
        from repro.obs.trace import validate_chrome_trace
        with open(trace_path) as f:
            tev = validate_chrome_trace(json.load(f))
        trace_spans = {}
        engine_phases = {}
        for ev in tev:
            if ev["ph"] != "X":
                continue
            rid = ev.get("args", {}).get("rid")
            if rid is not None:
                n, dur = trace_spans.setdefault(rid, {}).get(
                    ev["name"], (0, 0.0))
                trace_spans[rid][ev["name"]] = (n + 1, dur + ev["dur"])
            elif ev["tid"] == 0:
                n, dur = engine_phases.get(ev["name"], (0, 0.0))
                engine_phases[ev["name"]] = (n + 1, dur + ev["dur"])

    metrics: Optional[Dict[str, float]] = None
    if metrics_path is not None:
        with open(metrics_path) as f:
            metrics = parse_prometheus(f.read())

    precision: Optional[dict] = None
    if precision_path is not None:
        with open(precision_path) as f:
            text = f.read()
        try:
            precision = {"kind": "json", "data": json.loads(text)}
        except json.JSONDecodeError:
            precision = {"kind": "prom", "data": parse_prometheus(text)}

    return {"journal": str(journal_path), "header": header,
            "truncated": truncated, "n_ticks": len(ticks),
            "kinds": kinds, "statuses": statuses, "last_tick": last,
            "submits": submits, "results": results,
            "cancelled": cancelled, "tick_events": tick_events,
            "trace_spans": trace_spans, "engine_phases": engine_phases,
            "metrics": metrics, "precision": precision}


def render(report: dict) -> str:
    """Render :func:`analyze`'s output as a markdown incident report."""
    h = report["header"]
    eng = h.get("engine", {})
    lines = ["# Serve postmortem", "",
             f"journal: `{report['journal']}` "
             f"(schema v{h.get('schema')})", ""]
    lines.append(
        f"- engine: {h.get('config', {}).get('name', '?')} — "
        f"{eng.get('n_slots')} slots, kv={eng.get('kv_dtype')}, "
        f"prefix_cache={eng.get('prefix_cache')}, "
        f"spec_tokens={eng.get('spec_tokens')}, "
        f"preempt={eng.get('preempt')}")
    if h.get("faults"):
        f = h["faults"]
        parts = []
        if f.get("poison"):
            parts.append(f"poison {sorted(f['poison'])}")
        if f.get("fail_steps"):
            parts.append(f"fail_steps {f['fail_steps']}")
        if f.get("exhaust"):
            parts.append(f"{len(f['exhaust'])} exhaust window(s)")
        if f.get("advances"):
            parts.append(f"clock advances at ticks "
                         f"{sorted(f['advances'])}")
        lines.append(f"- fault schedule: {', '.join(parts) or 'none'}")
    kinds = ", ".join(f"{k}:{n}" for k, n in sorted(report["kinds"].items()))
    lines.append(f"- drive: {report['n_ticks']} ticks ({kinds or 'none'}), "
                 f"{len(report['submits'])} requests submitted")
    statuses = ", ".join(f"{k}:{n}"
                         for k, n in sorted(report["statuses"].items()))
    lines.append(f"- outcomes: {statuses or 'none recorded'}")
    last = report["last_tick"]
    if last:
        pool = last.get("pool", [0] * 5)
        pre = last.get("prefix", [0] * 3)
        lines.append(
            f"- final pool: {pool[0]} free / {pool[1]} used / "
            f"{pool[2]} cached / {pool[3]} shared / {pool[4]} held pages")
        lines.append(
            f"- prefix cache lifetime: {pre[0]} hits, {pre[1]} misses, "
            f"{pre[2]} COW copies")
    if report["truncated"]:
        lines.append("- **journal truncated** (hit its max_events bound; "
                     "replay is unavailable, the story below covers the "
                     "recorded prefix)")
    lines.append("")

    lines.append("## Requests")
    lines.append("")
    for rid in sorted(report["submits"]):
        lines.extend(_request_report(
            rid, report["submits"][rid], report["results"].get(rid),
            report["cancelled"], report["trace_spans"],
            report["tick_events"]))
        lines.append("")

    if report["engine_phases"]:
        lines.append("## Engine phase time (trace)")
        lines.append("")
        total = report["engine_phases"].get("tick", (0, 0.0))[1]
        for name, (n, dur) in sorted(report["engine_phases"].items(),
                                     key=lambda kv: -kv[1][1]):
            share = (f" ({100.0 * dur / total:.0f}% of tick time)"
                     if total > 0 and name != "tick" else "")
            lines.append(f"- {name}: {n} spans, "
                         f"{_fmt_s(dur / 1e6)}{share}")
        lines.append("")

    if report["metrics"] is not None:
        m = report["metrics"]
        lines.append("## Engine metrics (Prometheus snapshot)")
        lines.append("")

        def _mean(stem: str) -> Optional[float]:
            c = m.get(f"{stem}_count")
            return (m.get(f"{stem}_sum", 0.0) / c) if c else None

        for stem, label in (("serve_queue_wait_seconds", "queue wait"),
                            ("serve_prefill_seconds", "prefill"),
                            ("serve_decode_seconds", "decode"),
                            ("serve_ttft_seconds", "TTFT"),
                            ("serve_itl_seconds", "ITL")):
            mean = _mean(stem)
            if mean is not None:
                lines.append(
                    f"- mean {label}: {_fmt_s(mean)} "
                    f"(n={int(m.get(stem + '_count', 0))})")
        for name, label in (("serve_preemptions_total", "preemptions"),
                            ("serve_cow_copies_total", "COW copies"),
                            ("serve_prefix_hits_total", "prefix hits"),
                            ("serve_timeouts_total", "timeouts"),
                            ("serve_cancelled_total", "cancellations"),
                            ("serve_nonfinite_total", "nonfinite kills"),
                            ("serve_failed_total", "failures")):
            if name in m:
                lines.append(f"- {label}: {int(m[name])}")
        lines.append("")

    if report["precision"] is not None:
        lines.append("## Precision telemetry")
        lines.append("")
        p = report["precision"]
        if p["kind"] == "json":
            data = p["data"]
            traj = data.get("loss_scale_trajectory")
            if traj:
                lines.append(
                    f"- loss scale trajectory: start {traj[0]:g}, "
                    f"end {traj[-1]:g}, min {min(traj):g}, "
                    f"max {max(traj):g} over {len(traj)} steps")
            for k in ("overflow_steps", "skipped_steps", "growths",
                      "backoffs", "steps"):
                if k in data:
                    lines.append(f"- {k}: {data[k]}")
        else:
            for key, v in sorted(p["data"].items()):
                if key.startswith(("train_loss_scale",
                                   "train_overflow", "train_skipped",
                                   "train_steps")):
                    lines.append(f"- {key}: {v:g}")
        lines.append("")

    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.postmortem",
        description="Render a flight-recorder journal (plus optional "
                    "trace/metrics/precision artifacts) as a per-request "
                    "incident report.")
    ap.add_argument("journal", help="flight-recorder journal JSONL")
    ap.add_argument("--trace", default=None,
                    help="Chrome trace JSON from the same drive "
                         "(Tracer.export)")
    ap.add_argument("--metrics", default=None,
                    help="Prometheus text snapshot "
                         "(engine.prometheus() / --metrics-out)")
    ap.add_argument("--precision", default=None,
                    help="PrecisionStats export: quickstart JSON or "
                         "Prometheus text (quickstart.py --metrics-out)")
    ap.add_argument("--out", default=None,
                    help="write the markdown report here instead of stdout")
    args = ap.parse_args(argv)
    report = analyze(args.journal, trace_path=args.trace,
                     metrics_path=args.metrics,
                     precision_path=args.precision)
    text = render(report)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"postmortem report -> {args.out}")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
