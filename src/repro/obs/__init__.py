"""repro.obs — unified telemetry: metrics registry, request tracing, and
precision observability.

Three dependency-free layers instrumenting both halves of the stack:

- :mod:`~repro.obs.registry` — labeled counters / gauges /
  log2-bucketed histograms with ``snapshot()`` dicts, Prometheus text
  exposition, and JSON dumps.  :class:`repro.serve.EngineStats` is built
  on it (its ``summary()`` schema unchanged), and the serving engine,
  scheduler and paged cache report queue depth, admissions, page-pool
  occupancy/high-watermark and speculative truncations into it.
- :mod:`~repro.obs.trace` — a span/event :class:`Tracer` (injectable
  clock, bounded ring buffer) exporting Chrome trace-event JSON: a serve
  session renders in Perfetto as per-slot request timelines (submit →
  admit → prefill chunks → decode/spec windows with accept counts →
  truncate → retire) over an engine-phase track (plan / device step /
  host sync / commit).  Plus :func:`~repro.obs.trace.profiler_trace`,
  the optional ``jax.profiler`` trace-dir hook.
- :mod:`~repro.obs.precision` — the MPX §3.3 signals:
  :class:`PrecisionStats` (loss-scale trajectory, overflow/skip-step
  counters, halving/doubling events) and
  :func:`~repro.obs.precision.per_layer_grad_summary`, per-layer grad
  amax / nonfinite / underflow fractions computed *inside* the jitted
  train step as fixed-shape arrays — no host callbacks.

Everything here is host-side bookkeeping recorded around the jitted
steps; tracing a serve session adds zero device syncs to
``ServeEngine.step()`` (pinned by tests) and <3% tok/s on the bench
workload (the ``serving_obs_overhead_pct`` CI row).
"""
from repro.obs.registry import (Counter, Gauge, Histogram, Registry,
                                merged_prometheus, merged_snapshot)
from repro.obs.trace import Tracer, profiler_trace, validate_chrome_trace

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "PrecisionStats",
    "Registry",
    "Tracer",
    "grad_layer_names",
    "merged_prometheus",
    "merged_snapshot",
    "per_layer_grad_summary",
    "profiler_trace",
    "validate_chrome_trace",
]


def __getattr__(name):
    # precision imports jax; keep `import repro.obs` free of that cost
    # for stdlib-only consumers (registry/trace never touch jax)
    if name in ("PrecisionStats", "per_layer_grad_summary",
                "grad_layer_names"):
        from repro.obs import precision
        return getattr(precision, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
