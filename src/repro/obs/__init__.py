"""repro.obs — unified telemetry: metrics registry, request tracing,
precision observability, and the serving flight recorder.

Four dependency-free layers instrumenting both halves of the stack:

- :mod:`~repro.obs.registry` — labeled counters / gauges /
  log2-bucketed histograms with ``snapshot()`` dicts, Prometheus text
  exposition, and JSON dumps.  :class:`repro.serve.EngineStats` is built
  on it (its ``summary()`` schema unchanged), and the serving engine,
  scheduler and paged cache report queue depth, admissions, page-pool
  occupancy/high-watermark and speculative truncations into it.
- :mod:`~repro.obs.trace` — a span/event :class:`Tracer` (injectable
  clock, bounded ring buffer) exporting Chrome trace-event JSON: a serve
  session renders in Perfetto as per-slot request timelines (submit →
  admit → prefill chunks → decode/spec windows with accept counts →
  truncate → retire) over an engine-phase track (plan / device step /
  host sync / commit).  Plus :func:`~repro.obs.trace.profiler_trace`,
  the optional ``jax.profiler`` trace-dir hook.
- :mod:`~repro.obs.precision` — the MPX §3.3 signals:
  :class:`PrecisionStats` (loss-scale trajectory, overflow/skip-step
  counters, halving/doubling events) and
  :func:`~repro.obs.precision.per_layer_grad_summary`, per-layer grad
  amax / nonfinite / underflow fractions computed *inside* the jitted
  train step as fixed-shape arrays — no host callbacks.
- :mod:`~repro.obs.journal` — the **flight recorder**:
  :class:`JournalRecorder` event-sources every external input to a
  ``ServeEngine`` drive (config fingerprint, fault schedule, clock
  samples, submits/cancels, per-tick digests with a rolling token hash)
  into bounded append-only JSONL; :func:`replay_journal` reconstructs
  the engine and re-drives it deterministically, naming the first
  divergent tick on mismatch.  :mod:`~repro.obs.postmortem` joins the
  journal with the other three layers' artifacts into a per-request
  incident report (``python -m repro.obs.postmortem``).

Everything here is host-side bookkeeping recorded around the jitted
steps; tracing or journaling a serve session adds zero device syncs to
``ServeEngine.step()`` (pinned by tests) and <3% tok/s on the bench
workload (the ``serving_obs_overhead_pct`` and
``serving_journal_overhead_pct`` CI rows).
"""
from repro.obs.journal import (JournalDivergence, JournalError,
                               JournalMismatch, JournalRecorder,
                               JournalTruncated, ReplayReport,
                               read_journal, replay_journal)
from repro.obs.registry import (Counter, Gauge, Histogram, Registry,
                                merged_prometheus, merged_snapshot)
from repro.obs.trace import Tracer, profiler_trace, validate_chrome_trace

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "JournalDivergence",
    "JournalError",
    "JournalMismatch",
    "JournalRecorder",
    "JournalTruncated",
    "PrecisionStats",
    "Registry",
    "ReplayReport",
    "Tracer",
    "grad_layer_names",
    "merged_prometheus",
    "merged_snapshot",
    "per_layer_grad_summary",
    "profiler_trace",
    "read_journal",
    "replay_journal",
    "validate_chrome_trace",
]


def __getattr__(name):
    # precision imports jax; keep `import repro.obs` free of that cost
    # for stdlib-only consumers (registry/trace never touch jax)
    if name in ("PrecisionStats", "per_layer_grad_summary",
                "grad_layer_names"):
        from repro.obs import precision
        return getattr(precision, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
