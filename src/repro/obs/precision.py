"""Precision observability — the MPX §3.3 control loop as exportable
signals.

Dynamic loss scaling *is* a feedback controller: the scale rises until a
gradient overflows, the overflow halves it, and the optimizer skips the
step.  Micikevicius et al. (1710.03740) motivated the heuristic with
gradient-magnitude histograms; Zhao et al. (1910.12385) showed the
statistics that decide whether a layer trains are *per-layer*.  This
module makes both observable:

- :class:`PrecisionStats` — host-side recorder for the loss-scale
  trajectory, overflow/skip-step counters, and halving/doubling events,
  backed by a :class:`~repro.obs.registry.Registry` so the same data
  exports as Prometheus text or a JSON snapshot.  Feed it from the
  trainer loop (:meth:`record_scaling` takes the loss-scaling object's
  ``telemetry()`` dict, or :meth:`record_step` takes raw floats).
- :func:`per_layer_grad_summary` — the **in-jit** half: per-layer grad
  amax / nonfinite fraction / underflow fraction computed inside the
  jitted train step as fixed-shape ``(L,)`` fp32 arrays.  No host
  callbacks, no shape dependence on values — it rides the metrics dict
  the step already returns, so reading it costs nothing beyond the
  transfer the trainer's logging cadence already pays.
  :func:`grad_layer_names` gives the matching static layer names.

"Underflow fraction" is the fraction of *nonzero* gradient elements whose
magnitude falls below fp16's smallest normal (``2**-14``) — the mass
dynamic loss scaling exists to save.  A rising underflow fraction with a
capped scale is the §3.3 failure mode; a per-layer view shows *which*
layer hits it first (Zhao et al.'s argument for per-layer scales, the
ROADMAP's fp8-training prerequisite).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.obs.registry import Registry

PyTree = Any

#: smallest normal float16 — below this, fp16 gradients go subnormal/zero
FP16_TINY = 2.0 ** -14


# -- in-jit per-layer summaries (fixed shapes, no host callbacks) -----------

def _inexact_leaves_with_path(tree: PyTree) -> List[Tuple[str, Any]]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype,
                                                     jnp.inexact):
            name = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            out.append((name, leaf))
    return out


def grad_layer_names(tree: PyTree) -> List[str]:
    """Static layer names matching :func:`per_layer_grad_summary` order.

    Call once on the host with any tree of the gradients' structure
    (e.g. the params); the jitted summary arrays index by this list.
    """
    return [name for name, _ in _inexact_leaves_with_path(tree)]


def per_layer_grad_summary(grads: PyTree,
                           tiny: float = FP16_TINY) -> Dict[str, jax.Array]:
    """Per-layer gradient statistics, computed inside jit.

    Returns three ``(L,)`` fp32 arrays (L = number of inexact leaves,
    order = :func:`grad_layer_names`):

    - ``grad_amax_per_layer``      — ``max(|g|)`` per leaf (0 for empty);
    - ``grad_nonfinite_frac_per_layer`` — fraction of non-finite elements;
    - ``grad_underflow_frac_per_layer`` — fraction of *nonzero* elements
      with ``|g| < tiny`` (underflow candidates at fp16 precision).

    Everything is fixed-shape and data-independent, so the summary adds
    no recompilation, no host callback, and no extra device sync — it
    travels in the metrics dict the train step already returns.
    """
    leaves = [leaf for _, leaf in _inexact_leaves_with_path(grads)]
    if not leaves:
        z = jnp.zeros((0,), jnp.float32)
        return {"grad_amax_per_layer": z,
                "grad_nonfinite_frac_per_layer": z,
                "grad_underflow_frac_per_layer": z}
    amax, nonfinite, underflow = [], [], []
    for g in leaves:
        a = jnp.abs(g.astype(jnp.float32))
        finite = jnp.isfinite(a)
        nz = a > 0
        amax.append(jnp.max(a) if a.size else jnp.float32(0))
        nonfinite.append(jnp.mean((~finite).astype(jnp.float32)))
        # underflow counts only finite, nonzero magnitudes below tiny;
        # guard the mean against all-zero leaves (0/0 -> 0, not NaN)
        n_nz = jnp.sum(nz.astype(jnp.float32))
        n_uf = jnp.sum((nz & finite & (a < tiny)).astype(jnp.float32))
        underflow.append(n_uf / jnp.maximum(n_nz, 1.0))
    return {"grad_amax_per_layer": jnp.stack(amax),
            "grad_nonfinite_frac_per_layer": jnp.stack(nonfinite),
            "grad_underflow_frac_per_layer": jnp.stack(underflow)}


# -- host-side trajectory recorder ------------------------------------------

class PrecisionStats:
    """Loss-scale trajectory + overflow accounting, registry-backed.

    Record once per (logged) step; the trajectory keeps ``(step, scale)``
    pairs so a run's §3.3 control-loop behaviour — ramp, overflow
    halvings, recovery doublings — is replayable from the snapshot.
    """

    def __init__(self, registry: Optional[Registry] = None):
        self.registry = registry if registry is not None else Registry()
        r = self.registry
        self._steps = r.counter(
            "train_steps_total", "train steps recorded")
        self._overflows = r.counter(
            "train_overflow_steps_total",
            "steps with non-finite grads (optimizer update skipped)")
        self._scale_events = r.counter(
            "train_loss_scale_events_total",
            "loss-scale transitions by direction", labels=("event",))
        self._scale_gauge = r.gauge(
            "train_loss_scale", "current dynamic loss scale")
        self._counter_gauge = r.gauge(
            "train_loss_scale_counter",
            "consecutive finite steps toward the next scale doubling")
        self._layer_gauges: Dict[str, Any] = {}
        self.scale_trajectory: List[Tuple[int, float]] = []
        self._prev_scale: Optional[float] = None
        self.layer_names: List[str] = []
        self._layer_latest: Dict[str, List[float]] = {}

    # -- per-step scaling state ---------------------------------------------

    def record_step(self, step: int, scale: float, grads_finite: bool,
                    counter: Optional[int] = None) -> None:
        """One training step's scaling outcome (host floats/bools)."""
        scale = float(scale)
        self._steps.inc()
        if not grads_finite:
            self._overflows.inc()
        if self._prev_scale is not None:
            if scale < self._prev_scale:
                self._scale_events.inc(event="halved")
            elif scale > self._prev_scale:
                self._scale_events.inc(event="doubled")
        self._prev_scale = scale
        self.scale_trajectory.append((int(step), scale))
        self._scale_gauge.set(scale)
        if counter is not None:
            self._counter_gauge.set(int(counter))

    def record_scaling(self, step: int, scaling: Any,
                       grads_finite: bool = True) -> None:
        """Record from a loss-scaling object exposing ``telemetry()``
        (:class:`repro.core.loss_scaling.DynamicLossScaling`).  Forces a
        host transfer of two scalars — call at logging cadence, not
        inside the step."""
        t = scaling.telemetry()
        self.record_step(step, t["loss_scale"], grads_finite,
                         counter=t.get("counter"))

    # -- per-layer summaries -------------------------------------------------

    def record_layer_summary(self, layer_names: List[str],
                             summary: Dict[str, Any]) -> None:
        """Latest per-layer arrays from :func:`per_layer_grad_summary`
        (already transferred to host, e.g. via ``np.asarray``)."""
        self.layer_names = list(layer_names)
        for key, arr in summary.items():
            vals = [float(v) for v in arr]
            if len(vals) != len(layer_names):
                raise ValueError(
                    f"{key}: {len(vals)} values for "
                    f"{len(layer_names)} layer names")
            self._layer_latest[key] = vals
            g = self._layer_gauges.get(key)
            if g is None:
                g = self.registry.gauge(
                    key.replace("_per_layer", ""),
                    "per-layer gradient statistic", labels=("layer",))
                self._layer_gauges[key] = g
            for name, v in zip(layer_names, vals):
                g.set(v, layer=name)

    # -- views ---------------------------------------------------------------

    @property
    def steps(self) -> int:
        return int(self._steps.total)

    @property
    def overflow_steps(self) -> int:
        """Steps whose optimizer update was skipped (non-finite grads)."""
        return int(self._overflows.total)

    @property
    def scale_halvings(self) -> int:
        return int(self._scale_events.value(event="halved"))

    @property
    def scale_doublings(self) -> int:
        return int(self._scale_events.value(event="doubled"))

    def snapshot(self) -> Dict[str, Any]:
        """Registry snapshot + the raw trajectory and per-layer arrays."""
        out: Dict[str, Any] = dict(self.registry.snapshot())
        out["loss_scale_trajectory"] = list(self.scale_trajectory)
        if self.layer_names:
            out["grad_layer_names"] = list(self.layer_names)
            out.update(self._layer_latest)
        return out
