"""Metrics registry: labeled counters / gauges / log2-bucketed histograms.

Dependency-free (stdlib only) so every layer of the stack — the serving
hot path, the trainer, the benchmarks — can instrument itself without
pulling a metrics client into the import graph.  All instruments are
host-side plain Python: they are updated *around* the jitted steps, never
inside them (in-jit telemetry lives in :mod:`repro.obs.precision` as
fixed-shape arrays), so registering a metric can never add a device sync.

Model (a deliberately small subset of the Prometheus data model):

- a :class:`Registry` owns named instruments; ``counter()`` / ``gauge()``
  / ``histogram()`` are get-or-create, so independent call sites can
  share one series by name;
- instruments carry a fixed tuple of **label names**; each distinct
  label-value combination is an independent series
  (``steps.inc(kind="mixed")``);
- :class:`Counter` only goes up; :class:`Gauge` is set (or ratcheted via
  ``set_max`` — high-watermarks); :class:`Histogram` buckets observations
  at powers of two (``le = 2**e``) — the right shape for latencies and
  gradient magnitudes, where decades matter and linear buckets alias;
- exports: ``snapshot()`` (flat ``{series_name: value}`` dict — the thing
  tests assert on), ``prometheus()`` (text exposition format, the
  ``metrics.prom`` artifact), and ``json_dump()``.

Thread-safety is *not* provided: the engine and trainer are
single-threaded hosts, and a lock per ``inc()`` on the serving hot path
would be pure overhead.
"""
from __future__ import annotations

import json
import math
import re
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_INF = float("inf")


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(f"invalid metric name {name!r}")
    return name


def _escape_label_value(v: str) -> str:
    """Prometheus exposition escaping for label values: backslash first
    (so the other escapes aren't double-escaped), then newline and
    quote.  Without this, a label value containing ``"`` or a newline
    corrupts every sample after it in the scrape."""
    return (v.replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _escape_help(h: str) -> str:
    """HELP text escaping: backslash and newline (quotes are legal)."""
    return h.replace("\\", "\\\\").replace("\n", "\\n")


def _fmt_labels(label_names: Sequence[str], key: Tuple[str, ...]) -> str:
    if not label_names:
        return ""
    inner = ",".join(f'{k}="{_escape_label_value(v)}"'
                     for k, v in zip(label_names, key))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == _INF:
        return "+Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


class _Metric:
    """Base: one named instrument holding one series per label-value set."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = ()):
        self.name = _check_name(name)
        self.help = help
        self.label_names = tuple(labels)
        for ln in self.label_names:
            _check_name(ln)
        self._series: Dict[Tuple[str, ...], float] = {}

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, declared "
                f"{sorted(self.label_names)}")
        return tuple(str(labels[k]) for k in self.label_names)

    def value(self, **labels) -> float:
        """Current value of one series (0.0 if never touched)."""
        return self._series.get(self._key(labels), 0.0)

    @property
    def total(self) -> float:
        """Sum over every series of this instrument."""
        return sum(self._series.values())

    def series(self) -> Iterator[Tuple[str, float]]:
        """Yields ``(suffix, value)`` — suffix is ``{k="v",...}`` or ''."""
        for key in sorted(self._series):
            yield _fmt_labels(self.label_names, key), self._series[key]


class Counter(_Metric):
    """Monotonically increasing count (events, tokens, seconds of work)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(
                f"{self.name}: counters only go up (inc {amount})")
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount


class Gauge(_Metric):
    """Point-in-time value (queue depth, free pages, current loss scale)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._key(labels)] = float(value)

    def set_max(self, value: float, **labels) -> None:
        """Ratchet upward only — high-watermark gauges."""
        key = self._key(labels)
        self._series[key] = max(self._series.get(key, float(value)),
                                float(value))


class Histogram(_Metric):
    """Log2-bucketed histogram: bucket upper edges are ``2**e`` for
    ``e`` in ``[lo_exp, hi_exp]`` plus a final ``+Inf`` bucket.

    An observation ``v`` lands in the first bucket whose edge satisfies
    ``v <= edge`` (Prometheus ``le`` semantics); ``v <= 0`` lands in the
    lowest bucket (log2 of a non-positive latency is meaningless — they
    are clamped, not dropped, so ``count``/``sum`` stay exact).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (),
                 lo_exp: int = -20, hi_exp: int = 4):
        super().__init__(name, help, labels)
        if hi_exp < lo_exp:
            raise ValueError(f"hi_exp {hi_exp} < lo_exp {lo_exp}")
        self.edges: Tuple[float, ...] = tuple(
            2.0 ** e for e in range(lo_exp, hi_exp + 1)) + (_INF,)
        self._lo_exp = lo_exp
        self._buckets: Dict[Tuple[str, ...], List[int]] = {}
        self._sums: Dict[Tuple[str, ...], float] = {}

    def bucket_index(self, value: float) -> int:
        """Index of the bucket ``value`` falls into (``v <= edge``)."""
        if value <= self.edges[0]:
            return 0
        if value > self.edges[-2]:
            return len(self.edges) - 1
        # ceil(log2(v)) relative to the lowest edge, exact on powers of 2
        idx = int(math.ceil(math.log2(value))) - self._lo_exp
        # float log2 can land one off at the boundary — nudge to the
        # first edge actually covering the value
        while idx > 0 and value <= self.edges[idx - 1]:
            idx -= 1
        while value > self.edges[idx]:
            idx += 1
        return idx

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        if key not in self._buckets:
            self._buckets[key] = [0] * len(self.edges)
            self._sums[key] = 0.0
            self._series[key] = 0.0
        self._buckets[key][self.bucket_index(value)] += 1
        self._sums[key] += value
        self._series[key] += 1          # _series holds the count

    def count(self, **labels) -> int:
        return int(self._series.get(self._key(labels), 0))

    def sum(self, **labels) -> float:
        return self._sums.get(self._key(labels), 0.0)

    def buckets(self, **labels) -> List[Tuple[float, int]]:
        """``(le_edge, cumulative_count)`` pairs for one series."""
        raw = self._buckets.get(self._key(labels))
        if raw is None:
            return [(e, 0) for e in self.edges]
        out, cum = [], 0
        for edge, n in zip(self.edges, raw):
            cum += n
            out.append((edge, cum))
        return out


class Registry:
    """A named set of instruments with dict / Prometheus / JSON exports."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_create(self, cls, name, help, labels, **kw) -> _Metric:
        existing = self._metrics.get(name)
        if existing is not None:
            if (type(existing) is not cls
                    or existing.label_names != tuple(labels)):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{existing.kind} with labels {existing.label_names}")
            return existing
        m = cls(name, help, labels, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), lo_exp: int = -20,
                  hi_exp: int = 4) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   lo_exp=lo_exp, hi_exp=hi_exp)

    def metrics(self) -> List[_Metric]:
        return list(self._metrics.values())

    # -- exports ------------------------------------------------------------

    def snapshot(self) -> Dict[str, float]:
        """Flat ``{series_name: value}`` — histograms expand to
        ``name_count`` / ``name_sum`` / ``name_bucket{le="..."}``."""
        out: Dict[str, float] = {}
        for m in self._metrics.values():
            if isinstance(m, Histogram):
                for key in sorted(m._series):
                    suffix = _fmt_labels(m.label_names, key)
                    out[f"{m.name}_count{suffix}"] = float(m._series[key])
                    out[f"{m.name}_sum{suffix}"] = m._sums[key]
                    cum = 0
                    for edge, n in zip(m.edges, m._buckets[key]):
                        cum += n
                        names = m.label_names + ("le",)
                        sfx = _fmt_labels(names, key + (_fmt_value(edge),))
                        out[f"{m.name}_bucket{sfx}"] = float(cum)
            else:
                for suffix, value in m.series():
                    out[f"{m.name}{suffix}"] = value
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition format (the ``.prom`` artifact)."""
        lines: List[str] = []
        for m in self._metrics.values():
            lines.extend(_family_header_lines(m))
            lines.extend(_family_sample_lines(m))
        return "\n".join(lines) + ("\n" if lines else "")

    def json_dump(self, path: Optional[str] = None) -> str:
        """JSON of :meth:`snapshot` (written to ``path`` when given)."""
        text = json.dumps(self.snapshot(), indent=2, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


def _family_header_lines(m: _Metric) -> List[str]:
    """The one-per-family ``# HELP`` / ``# TYPE`` comment lines."""
    lines: List[str] = []
    if m.help:
        lines.append(f"# HELP {m.name} {_escape_help(m.help)}")
    lines.append(f"# TYPE {m.name} {m.kind}")
    return lines


def _family_sample_lines(m: _Metric) -> List[str]:
    """One metric's sample lines, no headers (shared by
    :meth:`Registry.prometheus` and :func:`merged_prometheus`)."""
    lines: List[str] = []
    if isinstance(m, Histogram):
        for key in sorted(m._series):
            cum = 0
            for edge, n in zip(m.edges, m._buckets[key]):
                cum += n
                names = m.label_names + ("le",)
                sfx = _fmt_labels(names, key + (_fmt_value(edge),))
                lines.append(f"{m.name}_bucket{sfx} {cum}")
            sfx = _fmt_labels(m.label_names, key)
            lines.append(f"{m.name}_sum{sfx} {_fmt_value(m._sums[key])}")
            lines.append(f"{m.name}_count{sfx} {cum}")
    else:
        for suffix, value in m.series():
            lines.append(f"{m.name}{suffix} {_fmt_value(value)}")
    return lines


def merged_snapshot(*registries: Registry) -> Dict[str, float]:
    """Union of several registries' snapshots (engine + stats exports)."""
    out: Dict[str, float] = {}
    for r in registries:
        out.update(r.snapshot())
    return out


def merged_prometheus(*registries: Registry) -> str:
    """Text exposition of several registries as one scrape document.

    Registries sharing a metric family (same name) contribute their
    series under a **single** ``# HELP``/``# TYPE`` header — the
    exposition format allows each family's headers at most once per
    scrape, and Prometheus rejects documents that repeat them.  A name
    registered with different *kinds* across registries is a schema bug
    and raises ``ValueError``.
    """
    order: List[str] = []
    first: Dict[str, _Metric] = {}
    samples: Dict[str, List[str]] = {}
    for r in registries:
        for m in r.metrics():
            seen = first.get(m.name)
            if seen is None:
                first[m.name] = m
                order.append(m.name)
                samples[m.name] = []
            elif seen.kind != m.kind:
                raise ValueError(
                    f"merged_prometheus: metric {m.name!r} is a "
                    f"{seen.kind} in one registry and a {m.kind} in "
                    f"another — one family name, one type")
            samples[m.name].extend(_family_sample_lines(m))
    lines: List[str] = []
    for name in order:
        lines.extend(_family_header_lines(first[name]))
        lines.extend(samples[name])
    return "\n".join(lines) + ("\n" if lines else "")
