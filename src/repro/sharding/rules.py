"""Logical-axis sharding rules (MaxText-style) → PartitionSpecs.

Every tensor in the framework names its dims with *logical* axes
("batch", "embed", "heads", "mlp", "experts", ...).  A rule table maps each
logical axis to zero or more *mesh* axes; :func:`resolve_spec` turns
(logical axes, shape) into a ``PartitionSpec``, silently dropping any mesh
axis that does not divide the dim or is absent from the mesh — the
divisibility fallback that lets one rule table serve llama3 (8 KV heads on a
16-way model axis ⇒ fall back) and qwen (40 heads ⇒ shard 16-way? no ⇒
fall back to replicated + the "q_per_kv" trick) alike.

A context manager installs (mesh, rules) process-wide so model code can call
:func:`shard` on activations without threading mesh plumbing through every
layer; with no context installed it is a no-op (single-CPU tests).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

#: Default rule table for the ("pod", "data", "model") production mesh.
#: Order matters for multi-axis entries: first listed axis is the major one.
DEFAULT_RULES: tuple[tuple[str, Any], ...] = (
    ("batch", ("pod", "data")),
    ("seq", None),                 # overridden to "model" for SP decode
    ("embed", None),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("head_dim", None),
    ("mlp", "model"),
    ("moe_mlp", "model"),          # TP inside experts (mixtral fallback)
    ("experts", "model"),          # EP when expert count divides
    ("moe_group", ("pod", "data")),  # GShard group dim == DP shards
    ("vocab", "model"),
    ("rnn", "model"),              # RG-LRU width
    ("ssm_inner", "model"),        # mamba2 d_inner
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("layers", None),              # scan-stacking dim
    ("kv_seq", None),              # KV-cache seq dim (SP rules flip this)
    ("patch", None),
    ("img_embed", None),
)

_ctx = threading.local()


def _get_ctx() -> tuple[Optional[Mesh], tuple]:
    return getattr(_ctx, "mesh", None), getattr(_ctx, "rules", DEFAULT_RULES)


def data_parallel_groups() -> int:
    """Size of the data-parallel section of the installed mesh (pod×data).

    MoE uses this as the GShard group count so token dispatch stays local
    to each DP shard (no cross-data collectives).  1 when no mesh is
    installed (single-device tests keep global-capacity semantics).
    """
    mesh, _ = _get_ctx()
    if mesh is None:
        return 1
    return mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Sequence[tuple[str, Any]] = DEFAULT_RULES):
    """Install (mesh, rules) for :func:`shard` / :func:`resolve_spec`."""
    old = (getattr(_ctx, "mesh", None), getattr(_ctx, "rules", DEFAULT_RULES))
    _ctx.mesh, _ctx.rules = mesh, tuple(rules)
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = old


def rules_with(overrides: dict[str, Any],
               base: Sequence[tuple[str, Any]] = DEFAULT_RULES,
               ) -> tuple[tuple[str, Any], ...]:
    """Return a rule table with some logical axes remapped."""
    out, seen = [], set()
    for name, tgt in base:
        if name in overrides:
            out.append((name, overrides[name]))
        else:
            out.append((name, tgt))
        seen.add(name)
    for name, tgt in overrides.items():
        if name not in seen:
            out.append((name, tgt))
    return tuple(out)


def _mesh_axes_for(logical: Optional[str], rules) -> tuple[str, ...]:
    if logical is None:
        return ()
    for name, tgt in rules:
        if name == logical:
            if tgt is None:
                return ()
            return tgt if isinstance(tgt, tuple) else (tgt,)
    return ()


def resolve_spec(logical: Sequence[Optional[str]],
                 shape: Sequence[int],
                 mesh: Optional[Mesh] = None,
                 rules=None) -> P:
    """Logical axes + concrete shape → PartitionSpec with fallbacks.

    A mesh axis is used only if (a) it exists in the mesh, (b) it is not
    already consumed by an earlier dim of this tensor, and (c) the product
    of chosen axis sizes divides the dim.
    """
    if mesh is None or rules is None:
        cmesh, crules = _get_ctx()
        mesh = mesh if mesh is not None else cmesh
        rules = rules if rules is not None else crules
    if mesh is None:
        return P(*([None] * len(shape)))

    used: set[str] = set()
    parts = []
    for dim, logical_name in zip(shape, logical):
        chosen: list[str] = []
        size = 1
        for ax in _mesh_axes_for(logical_name, rules):
            if ax in used or ax not in mesh.shape:
                continue
            if dim % (size * mesh.shape[ax]) != 0:
                continue
            chosen.append(ax)
            size *= mesh.shape[ax]
        for ax in chosen:
            used.add(ax)
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(tuple(chosen))
    return P(*parts)


def named_sharding(logical: Sequence[Optional[str]], shape: Sequence[int],
                   mesh: Optional[Mesh] = None, rules=None,
                   ) -> Optional[NamedSharding]:
    if mesh is None:
        mesh = _get_ctx()[0]
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(logical, shape, mesh, rules))


def shard(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Apply ``with_sharding_constraint`` from the installed context.

    No-op when no mesh is installed (pure-CPU unit tests) or when tracing
    shapes disagree with the logical rank (defensive: never crash a model
    on a sharding annotation).
    """
    mesh, rules = _get_ctx()
    if mesh is None or len(logical) != x.ndim:
        return x
    spec = resolve_spec(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_pspecs(logical_tree: PyTree, shape_tree: PyTree, mesh: Mesh,
                rules=DEFAULT_RULES) -> PyTree:
    """Map matching (logical-axes tree, ShapeDtypeStruct tree) → NamedShardings."""
    return jax.tree.map(
        lambda lg, sd: NamedSharding(
            mesh, resolve_spec(lg, sd.shape, mesh, rules)),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
