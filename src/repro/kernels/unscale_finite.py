"""Fused gradient unscale + isfinite check (the MPX loss-scaling hot path).

Steps 4–6 of the paper's recipe — convert to fp32, divide by the scaling,
test finiteness — touch every gradient element.  Done naively that is three
HBM passes; this kernel does one: each block is read once, multiplied by
``1/scale`` in fp32, written once, while a scalar finite-flag accumulates in
SMEM across the grid (initialized at step 0, AND-reduced, readable as the
second output).

The wrapper handles arbitrary 1-D-flattenable arrays with tail padding
(pad values are 0, which is finite and cannot flip the flag).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unscale_kernel(inv_ref, g_ref, o_ref, flag_ref, ok_smem):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        ok_smem[0] = jnp.int32(1)

    g32 = g_ref[...].astype(jnp.float32) * inv_ref[0]
    o_ref[...] = g32
    blk_ok = jnp.all(jnp.isfinite(g32))
    ok_smem[0] = ok_smem[0] * blk_ok.astype(jnp.int32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _write():
        flag_ref[0] = ok_smem[0]


def unscale_and_check(g, inv_scale, *, block: int = 65536,
                      interpret: bool = False):
    """g (any shape), inv_scale scalar fp32 -> (g*inv fp32, all_finite bool)."""
    orig_shape = g.shape
    flat = g.reshape(-1)
    n = flat.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    grid = (flat.shape[0] // block,)
    inv = jnp.asarray(inv_scale, jnp.float32).reshape(1)

    out, flag = pl.pallas_call(
        _unscale_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(flat.shape, jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(inv, flat)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape), flag[0].astype(jnp.bool_)
