"""Flash attention for TPU (Pallas): bf16 streaming, fp32 softmax state.

This kernel is the MPX `force_full_precision`-softmax rule implemented where
it is free: Q/K/V stream through VMEM in bf16 feeding the MXU, while the
running max / sum-of-exp / output accumulator live in fp32 VMEM scratch.

TPU adaptation (DESIGN.md §3): block shapes are multiples of the 128-wide
MXU systolic dimension; the grid walks (batch·heads, q_blocks, k_blocks)
with the K loop innermost so the fp32 state for one (bh, q_block) stays
resident in scratch across K steps; causal/window key blocks that are fully
masked are skipped via `pl.when` on the grid indices (halving causal FLOPs —
something the pure-XLA path cannot do dynamically).

Supports: causal or bidirectional, sliding window, logit softcap (gemma2),
GQA via pre-expanded heads (`ops.py` handles the expand).  Forward-only:
training uses the blocked-XLA attention (autodiffable); this kernel is the
serving/prefill hot path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scratch, l_scratch,
                 acc_scratch, *, scale: float, causal: bool, window: int,
                 softcap: float, block_q: int, block_k: int):
    """Grid: (BH, n_q, n_k); K innermost.  Block refs are (block, dim)."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip key blocks entirely outside the causal/window band (grid-dynamic)
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window > 0:
        run &= k_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[...]                                     # (bq, d) bf16
        k = k_ref[...]                                     # (bk, d) bf16
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (bq, bk) fp32
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]                            # (bq, 1) fp32
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (bq, bk) fp32
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (bq, d) fp32
        acc_scratch[...] = acc_scratch[...] * alpha + pv
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_scratch[...]
        o_ref[...] = (acc_scratch[...] /
                      jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False):
    """q/k/v: (B, S, H, D), same H (GQA pre-expanded).  Returns (B, S, H, D).

    VMEM working set per grid cell ≈ (block_q + 2·block_k)·D·2B bf16 tiles
    + block_q·(D+2)·4B fp32 state + block_q·block_k·4B scores ≈ 1.4 MB at
    the 256/256 defaults with D=128 — comfortable inside ~16 MB VMEM with
    double buffering.  (m/l scratch is (block_q, 1); on real hardware the
    compiler pads the lane dim to 128 — still < 0.2 MB.)
    """
    b, s, h, d = q.shape
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    grid = (b * h, s // block_q, s // block_k)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running sum-of-exp
            pltpu.VMEM((block_q, d), jnp.float32),    # fp32 output accum
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
