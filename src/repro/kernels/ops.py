"""Jitted public wrappers for the Pallas kernels.

Each op auto-selects: real Pallas lowering on TPU backends, interpret mode
on CPU (used by the test suite to validate kernel bodies against the
``ref.py`` oracles).  GQA head expansion for flash attention happens here so
the kernel itself stays single-layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.unscale_finite import unscale_and_check as _unscale


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 256,
                    block_k: int = 256, interpret: bool | None = None):
    """q (B,S,H,D); k/v (B,S,K,D) with K dividing H (GQA auto-expand)."""
    interpret = _interpret_default() if interpret is None else interpret
    h, kv = q.shape[2], k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def unscale_and_check(g, inv_scale, *, block: int = 65536,
                      interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _unscale(g, inv_scale, block=block, interpret=interpret)
