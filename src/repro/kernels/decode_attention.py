"""Decode attention Pallas kernel: one query token vs a *contiguous* cache.

q (B, H, D) against a monolithic K/V slab (B, S, K, D) — the decode_32k /
long_500k cells and any caller holding per-slot contiguous caches.  (The
serving engine's paged pools are served by the page-table-walking kernel
in ``repro.kernels.paged_attention`` instead — this one would need the
gathered dense copy.)  Unlike prefill flash attention the arithmetic intensity
is O(1) FLOPs/byte — the kernel is purely HBM-bandwidth-bound streaming the
cache — so the design goal is: touch every cache byte exactly once, in
bf16, with fp32 softmax state in scratch, masked by the *current length*
(an SMEM operand, so one compiled kernel serves every position).  Length is
either a scalar (uniform batch) or a (B,) vector — the ragged case that
continuous batching produces: every slot of the serving batch sits at its
own position, and each (batch, kv-head) grid row masks by its own slot's
length.

Grid: (B·K, S/block_k) — K-block innermost, fp32 (m, l, acc) carried in
VMEM scratch across K steps; GQA handled by keeping the q-group dim G=H/K
resident (block (G, D), MXU-aligned for G·D ≥ 128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(length_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, block_k: int, scale: float,
                   n_kv: int):
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # grid axis 0 is b * n_kv + kv_head: recover this row's batch slot
    length = length_ref[pl.program_id(0) // n_kv]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _body():
        q = q_ref[...]                                    # (G, D) bf16
        k = k_ref[...]                                    # (bk, D) bf16
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bk) fp32
        pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        # zero V rows at/beyond length: the final (ragged) block reads
        # past the array edge, and OOB/undefined values must not reach
        # the accumulator even via p == 0 (0 * NaN = NaN)
        row = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (v_ref.shape[0], 1), 0)
        vb = jnp.where(row < length, v_ref[...], 0)
        pv = jax.lax.dot_general(
            p.astype(vb.dtype), vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, D)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _fin():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k, v, length, *, block_k: int = 512,
                     interpret: bool = False):
    """q (B,H,D) vs cache k/v (B,S,K,D), valid prefix ``length``.

    ``length`` is a scalar (uniform batch) or a (B,) vector (ragged
    continuous batch — each slot masked by its own prefix; a slot with
    length 0 outputs zeros).  Returns (B,H,D).  K divides H; the
    rolling-buffer window layout of the framework's local-attention caches
    is handled by the caller (positions beyond ``length`` are masked here;
    wrap-around caches pass length=S).
    """
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    # arbitrary cache lengths must not crash OR degrade the block size:
    # a cdiv grid keeps block_k intact and lets the final ragged block
    # read past the array edge (Pallas pads OOB; the in-kernel masks keep
    # those values out of the softmax AND the accumulator).  The old
    # gcd-divisor fallback collapsed to size-1 blocks for lengths like
    # 3*512+1; padding K/V with jnp.pad instead would rewrite the whole
    # multi-GB cache every step on the exact path this kernel exists for.
    block_k = min(block_k, s)

    qf = q.reshape(b, kv, g, d).transpose(0, 1, 2, 3).reshape(b * kv, g, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    length_arr = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1), (b,))

    grid = (b * kv, -(-s // block_k))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k, scale=scale,
                          n_kv=kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, g, d), lambda bk, ki: (bk, 0, 0)),
            pl.BlockSpec((None, block_k, d), lambda bk, ki: (bk, ki, 0)),
            pl.BlockSpec((None, block_k, d), lambda bk, ki: (bk, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, g, d), lambda bk, ki: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(length_arr, qf, kf, vf)
    return out.reshape(b, kv, g, d).reshape(b, h, d)


def decode_attention_ref(q, k, v, length):
    """Pure-jnp oracle (scalar or (B,) ragged ``length``): see kernels/ref."""
    from repro.kernels import ref
    return ref.decode_attention_ref(q, k, v, length)
