"""Pallas TPU kernels for the hot paths the paper's discipline exposes.

- ``flash_attention``   — blocked prefill attention (fp32 streaming state)
- ``decode_attention``  — one query vs a *contiguous* (B, S, K, D) cache
- ``paged_attention``   — page-table-indexed serving attention over the
  shared (P, page_size, K, D) pools: scalar-prefetch page tables, no
  gathered dense copy, covers decode AND chunked-prefill queries
- ``rmsnorm`` / ``unscale_finite`` — fused MPX precision primitives
- ``ref``               — pure-jnp oracles every kernel is tested against

Every kernel runs under ``interpret=True`` on CPU (that is what tier-1 CI
exercises) and compiles natively on TPU.
"""
