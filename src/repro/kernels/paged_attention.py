"""Native paged-attention Pallas kernel: page-table-indexed KV streaming,
with in-kernel dequantization of sub-bf16 (int8 / fp8) page pools.

The serving hot path is HBM-bandwidth-bound, and the paged KV layout
(``repro.serve.cache``) stores every slot's cache as fixed-size pages of a
shared ``(P, page_size, K, D)`` pool addressed through a per-slot page
table.  The gather-based path first materializes each slot's *entire
padded* prefix — ``(B, Pmax*page_size, K, D)`` — as a dense copy, per
layer, per tick, garbage sentinel pages included.  This kernel instead
walks each slot's page table directly: the page table and per-slot chunk
``start``/``valid`` counts are scalar-prefetch (SMEM) operands, and the
K/V block index maps resolve logical page ``i`` -> physical page
``table[b, i]`` in the pool, so the DMA engine streams exactly the pages
the scheduler allocated, exactly once.  Pages past a slot's length
re-issue the previous block index (the pipeline elides the refetch) and
their compute is predicated off — unallocated pages are never read.

Quantized pools (``repro.quant``) add two more *blocked* operands: the
``(P, K)`` fp32 amax-scale sidecars for K and V.  Each sub-page's scale
is a ``(1, 1)`` block whose index map resolves the SAME logical page ->
physical page mapping as that sub-page's value block (one shared
``_phys_page`` helper, so the value and its scale can never point at
different pages), and each K/V block is dequantized *in VMEM* —
``block.astype(f32) * scale``, cast to the query dtype — before the
score/output matmuls.  The pool is streamed at 1 byte/element and the
dense bf16 view of the cache never exists anywhere: not in HBM (the
gather copy PR 3 removed) and not as a pool-shaped intermediate
(dequant happens block-by-block in registers).  The sidecars ride
blocked VMEM rather than scalar-prefetch SMEM deliberately: SMEM is a
few KB per core and the sidecar grows with the *pool* (``P * K`` fp32
each), so a production-sized pool would blow the scalar-prefetch budget
— only the O(B * Pmax) page table and the (B,) start/valid vectors
belong there.  Sidecar HBM cost stays ~``page_size * head_dim / 2``
times below the pools it describes.

Queries cover every ``serve_forward`` step shape, not just single-token
decode: q is ``(B, C, H, D)`` where ``C = 1`` is decode and ``C > 1`` a
chunked-prefill, speculative-window, or mixed step, causal by absolute
position (``start[b] + ci``).  GQA keeps the whole query group resident:
the kernel block is ``(C*G, D)`` with ``G = H / K``, one grid row per
(slot, kv-head).  Softmax runs as the usual streaming (m, l, acc)
recurrence in fp32 VMEM scratch; padding chunk positions
(``ci >= valid[b]``) and idle slots (``valid = 0``) output exact zeros.

``pages_per_block`` widens the K-block: each grid step concatenates that
many *logical* pages (each resolved to its own physical page by its own
index map — pages are not physically contiguous, so one block per page is
DMA'd and they meet in VMEM) into a single ``(ppb * page_size, D)``
operand for the score matmul.  With page_size 16 a single page underfills
the MXU's 128-lane contraction dim; ``pages_per_block = 8`` fills it.
Each sub-page block is dequantized with its *own* page's scale before the
concatenation.

Grid: ``(B*K, ceil(Pmax / pages_per_block))`` — logical page blocks
innermost so the fp32 state is carried across one slot's pages, then
reset (``i == 0``) for the next row.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _phys_page(table_ref, start_ref, valid_ref, b, logical, *,
               page_size: int, n_pages: int):
    """Logical page of slot ``b`` -> clamped physical pool page.

    THE logical->physical rule, shared by the K/V value block index maps
    and the scale block index maps (a value and its scale must always
    resolve to the same page): pages past the slot's last used page
    re-issue the last used index, the sentinel is clamped into range —
    compute for either case is predicated off by the kernel body.
    """
    n_pg = pl.cdiv(start_ref[b] + valid_ref[b], page_size)
    i_eff = jnp.minimum(logical, jnp.maximum(n_pg - 1, 0))
    return jnp.minimum(table_ref[b, i_eff], n_pages - 1)


def _paged_kernel(table_ref, start_ref, valid_ref, *refs,
                  page_size: int, scale: float, n_kv: int, group: int,
                  ppb: int, quantized: bool):
    q_ref = refs[0]
    k_refs = refs[1:1 + ppb]
    v_refs = refs[1 + ppb:1 + 2 * ppb]
    refs = refs[1 + 2 * ppb:]
    if quantized:
        ks_refs = refs[:ppb]                  # (1, 1) scale per sub-page
        vs_refs = refs[ppb:2 * ppb]
        refs = refs[2 * ppb:]
    o_ref = refs[0]
    m_scr, l_scr, acc_scr = refs[1:]
    i = pl.program_id(1)
    n_i = pl.num_programs(1)
    b = pl.program_id(0) // n_kv

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    start = start_ref[b]
    length = start + valid_ref[b]        # cached tokens incl. this chunk
    block_lo = i * ppb * page_size

    def _block(refs_j, scale_ref_j):
        """One sub-page's (page_size, D) block, dequantized in VMEM with
        its own page's (1, 1) sidecar scale block (same index map)."""
        blk = refs_j[...]
        if not quantized:
            return blk
        return (blk.astype(jnp.float32) *
                scale_ref_j[0, 0]).astype(q_ref.dtype)

    @pl.when(block_lo < length)
    def _body():
        q = q_ref[...]                                    # (C*G, D) bf16
        if ppb == 1:
            k = _block(k_refs[0], ks_refs[0] if quantized else None)
            v = _block(v_refs[0], vs_refs[0] if quantized else None)
        else:
            # ppb logical pages, each DMA'd from its own physical page,
            # dequantized with its own scale, concatenated in VMEM into
            # one (ppb*ps, D) matmul operand
            k = jnp.concatenate(
                [_block(r, ks_refs[j] if quantized else None)
                 for j, r in enumerate(k_refs)], axis=0)
            v = jnp.concatenate(
                [_block(r, vs_refs[j] if quantized else None)
                 for j, r in enumerate(v_refs)], axis=0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (C*G, ppb*ps) f32
        # key absolute position, query chunk index: causal by position,
        # padding queries (ci >= valid) fully masked -> exact-zero rows.
        # Sub-pages past the slot's length (their index map re-issued an
        # allocated page) land at kpos >= length > start + ci: masked.
        kpos = block_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ci = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        ok = (kpos <= start + ci) & (ci < valid_ref[b])
        s = jnp.where(ok, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # masked entries contribute exactly 0 (not exp(NEG_INF - NEG_INF)
        # = 1 on all-masked padding rows), so l stays 0 there and the
        # final divide yields zeros instead of garbage-page averages
        p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new), 0.0)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (C*G, D)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(i == n_i - 1)
    def _fin():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, page_table, start, valid, *,
                    k_scales=None, v_scales=None,
                    pages_per_block: int = 1, interpret: bool = False):
    """Paged attention over a shared KV page pool, no gathered copy.

    q (B, C, H, D) — one serving chunk per slot (C = 1 decode, C > 1
    prefill / speculative window / mixed); k_pages / v_pages
    (P, page_size, K, D) — the shared pools, chunk K/V already scattered
    in (``paged_write`` / ``quantized_pool_write`` runs first);
    page_table (B, Pmax) int32 logical->physical map whose unallocated
    entries hold the sentinel ``P``; start (B,) absolute position of
    each slot's chunk; valid (B,) real tokens in the chunk (0 = idle
    slot).

    ``k_scales`` / ``v_scales`` (P, K) fp32 enable the quantized path:
    the pools hold int8 or fp8 (``repro.quant`` formats) and every K/V
    block is dequantized in VMEM — ``block * scales[phys, kv_head]`` —
    before its matmul.  Both must be given together; without them the
    pools are attended to as-is (the bf16 baseline).  Each sub-page's
    scale arrives as its own (1, 1) block through the same
    logical->physical index map as the sub-page's values (blocked VMEM,
    not scalar-prefetch SMEM — the sidecar scales with the pool and
    would not fit the SMEM budget at production pool sizes).

    Query ``ci`` of slot ``b`` attends causally to cache positions
    ``<= start[b] + ci``; padding positions (``ci >= valid[b]``) and idle
    slots output zeros.  ``pages_per_block`` logical pages are fused into
    each K-block (score-matmul contraction dim ``pages_per_block *
    page_size`` — fill it to ~128 lanes on the MXU).  Returns
    (B, C, H, D) in q.dtype.  K divides H; sliding windows and logit
    softcaps are the caller's fallback path.
    """
    b, c, h, d = q.shape
    n_pages, page_size, kv, _ = k_pages.shape
    if h % kv:
        raise ValueError(f"n_kv_heads {kv} must divide n_heads {h}")
    if pages_per_block < 1:
        raise ValueError(f"pages_per_block must be >= 1: {pages_per_block}")
    if (k_scales is None) != (v_scales is None):
        raise ValueError("k_scales and v_scales must be given together")
    quantized = k_scales is not None
    group = h // kv
    cg = c * group
    scale = 1.0 / math.sqrt(d)
    pmax = page_table.shape[1]
    ppb = min(pages_per_block, pmax)

    # (B, C, H, D) -> one (C*G, D) query block per (slot, kv-head) row
    qf = (q.reshape(b, c, kv, group, d).transpose(0, 2, 1, 3, 4)
          .reshape(b * kv, cg, d))
    table = jnp.asarray(page_table, jnp.int32)
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (b,))
    valid = jnp.broadcast_to(jnp.asarray(valid, jnp.int32).reshape(-1), (b,))

    def sub_page_phys(bk, i, j, table_ref, start_ref, valid_ref):
        # logical page i*ppb + j of slot bk//kv -> physical pool page,
        # via the ONE shared rule (_phys_page).  Blocks past the slot's
        # last used page re-issue the last used index (no refetch,
        # compute predicated off); the sentinel (= n_pages) only
        # survives for idle slots, clamped into range with compute
        # predicated off.
        return _phys_page(table_ref, start_ref, valid_ref, bk // kv,
                          i * ppb + j, page_size=page_size,
                          n_pages=n_pages)

    def page_index(j):
        def index_map(bk, i, *scalar_refs):
            phys = sub_page_phys(bk, i, j, *scalar_refs)
            return (phys, 0, bk % kv, 0)
        return index_map

    def scale_index(j):
        def index_map(bk, i, *scalar_refs):
            phys = sub_page_phys(bk, i, j, *scalar_refs)
            return (phys, bk % kv)
        return index_map

    kv_specs = [pl.BlockSpec((None, page_size, None, d), page_index(j))
                for j in range(ppb)]
    sc_specs = [pl.BlockSpec((1, 1), scale_index(j)) for j in range(ppb)]
    inputs = [qf] + [k_pages] * ppb + [v_pages] * ppb
    in_specs = ([pl.BlockSpec((None, cg, d), lambda bk, i, *_: (bk, 0, 0))]
                + kv_specs + kv_specs)
    if quantized:
        inputs += ([jnp.asarray(k_scales, jnp.float32)] * ppb
                   + [jnp.asarray(v_scales, jnp.float32)] * ppb)
        in_specs += sc_specs + sc_specs
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * kv, -(-pmax // ppb)),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, cg, d), lambda bk, i, *_: (bk, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((cg, 1), jnp.float32),
            pltpu.VMEM((cg, 1), jnp.float32),
            pltpu.VMEM((cg, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, page_size=page_size, scale=scale,
                          n_kv=kv, group=group, ppb=ppb,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kv, cg, d), q.dtype),
        interpret=interpret,
    )(table, start, valid, *inputs)
    return (out.reshape(b, kv, c, group, d).transpose(0, 2, 1, 3, 4)
            .reshape(b, c, h, d))


def paged_attention_ref(q, k_pages, v_pages, page_table, start, valid):
    """Ragged pure-jnp paged oracle: see :func:`repro.kernels.ref`."""
    from repro.kernels import ref
    return ref.paged_attention_ref(q, k_pages, v_pages, page_table,
                                   start, valid)
