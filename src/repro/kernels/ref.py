"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernel tests `assert_allclose` against
(shape/dtype sweeps, interpret=True execution of the kernels on CPU).
Everything here is deliberately simple — no blocking, no streaming — and
follows the MPX precision discipline: fp32 softmax/statistics, compute-dtype
matmuls.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jnp.ndarray:
    """q/k/v: (B, S, H, D) (same H — expand GQA before calling).

    fp32 scores/softmax, output cast back to q.dtype.
    """
    b, s, h, d = q.shape
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    scores = jnp.where(ok[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, lengths) -> jnp.ndarray:
    """One query token per slot vs a ragged KV cache (continuous batching).

    q (B, H, D), k/v (B, S, K, D) with K dividing H (GQA expanded here),
    ``lengths`` a scalar or (B,) vector of valid prefix lengths.  fp32
    scores/softmax, compute-dtype matmuls — the oracle for the ragged
    serving hot path in ``repro.kernels.decode_attention``.
    """
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    ke = jnp.repeat(k, h // kv, axis=2)
    ve = jnp.repeat(v, h // kv, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q, ke).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    lengths_b = jnp.asarray(lengths, jnp.int32).reshape(-1, 1, 1)
    scores = jnp.where(jnp.arange(s)[None, None, :] < lengths_b,
                       scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhs,bshd->bhd", probs, ve)


def paged_attention_ref(q, k_pages, v_pages, page_table, start, valid
                        ) -> jnp.ndarray:
    """Ragged paged-attention oracle (gathers the pool; the kernel doesn't).

    q (B, C, H, D) — one serving chunk per slot; k_pages / v_pages
    (P, page_size, K, D) shared pools; page_table (B, Pmax) int32 with the
    sentinel ``P`` in unallocated entries; start / valid (B,) chunk
    position and real-token count.  Query ``ci`` attends causally to
    cache positions ``<= start + ci``; padding positions (``ci >= valid``)
    and idle slots (``valid = 0``) return exact zeros — matching
    ``repro.kernels.paged_attention``.  fp32 scores/softmax, compute-dtype
    matmuls.  This is deliberately the dense gather-based layout: the
    ground truth the page-table-walking kernel is tested against.
    """
    b, c, h, d = q.shape
    n_pages, ps, kv, _ = k_pages.shape
    pmax = page_table.shape[1]
    s_max = pmax * ps
    tbl = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, n_pages - 1)
    k = k_pages[tbl].reshape(b, s_max, kv, d)
    v = v_pages[tbl].reshape(b, s_max, kv, d)
    ke = jnp.repeat(k, h // kv, axis=2)
    ve = jnp.repeat(v, h // kv, axis=2)
    start = jnp.asarray(start, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)
    kpos = jnp.arange(s_max)
    # zero V beyond each slot's length so fully-masked rows (uniform
    # softmax over NEG_INF scores) cannot pick up garbage-page values
    length = (start + valid)[:, None, None, None]
    ve = jnp.where(kpos[None, :, None, None] < length, ve, 0)
    scores = jnp.einsum("bchd,bshd->bhcs", q, ke).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    qpos = start[:, None] + jnp.arange(c)[None, :]
    ok = (kpos[None, None, :] <= qpos[:, :, None]) & \
         (jnp.arange(c)[None, :, None] < valid[:, None, None])
    scores = jnp.where(ok[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhcs,bshd->bchd", probs, ve)
    row_ok = jnp.arange(c)[None, :] < valid[:, None]
    return jnp.where(row_ok[:, :, None, None], out, 0).astype(q.dtype)


def quantized_paged_attention_ref(q, k_pages, v_pages, k_scales, v_scales,
                                  page_table, start, valid) -> jnp.ndarray:
    """Quantized ragged paged-attention oracle (``repro.quant`` pools).

    ``k_pages`` / ``v_pages`` hold int8 or fp8 (bf16-emulated off-TPU)
    values with ``(P, K)`` fp32 amax-scale sidecars ``k_scales`` /
    ``v_scales`` — the write-quantize/read-dequantize serving layout.
    Dequantizes each pool with the SAME per-element rule the kernel
    applies per block in VMEM (``repro.quant.ops.dequantize``: fp32
    multiply, cast to q.dtype) and defers to :func:`paged_attention_ref`,
    so any kernel/oracle disagreement is attention math, never a dequant
    discrepancy.  This is deliberately the dense gather-based layout the
    kernel exists to avoid — ground truth only.
    """
    from repro.quant.ops import dequantize
    k = dequantize(k_pages, k_scales[:, None, :, None], q.dtype)
    v = dequantize(v_pages, v_scales[:, None, :, None], q.dtype)
    return paged_attention_ref(q, k, v, page_table, start, valid)


def rmsnorm_ref(x, scale, eps: float = 1e-6) -> jnp.ndarray:
    """(..., D) RMSNorm with fp32 statistics, output in x.dtype."""
    x32 = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 / rms) * scale.astype(jnp.float32)).astype(x.dtype)


def unscale_finite_ref(g, inv_scale) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused gradient unscale + isfinite reduction (one array).

    Returns (g * inv_scale as fp32, all_finite bool) — the per-leaf body of
    the MPX loss-scaling hot path.
    """
    g32 = g.astype(jnp.float32) * inv_scale
    return g32, jnp.all(jnp.isfinite(g32))
