"""Fused RMSNorm Pallas kernel: bf16 in/out, fp32 statistics.

The MPX paper's Example 1 wraps layernorm in ``force_full_precision`` — at
the XLA level that costs an fp32 upcast round-trip through HBM.  This kernel
fuses the upcast, the mean-of-squares reduction, the normalization and the
scale into one VMEM pass: one bf16 read + one bf16 write per element, with
the statistics accumulated in fp32 registers.  Rows are tiled (block_rows ×
d_model) so the working set stays in VMEM for any d_model ≤ ~64k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)          # (rows, d) fp32 in VMEM
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * inv * w[None, :]).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x (..., D), scale (D,) -> same shape/dtype as x; fp32 stats."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    rows = xf.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n_blocks = xf.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
