"""Parameter metadata: shapes + logical sharding axes + initializers.

Models in this framework describe their parameters as trees of
:class:`ParamSpec` (shape, logical axis names, init law).  From one spec
tree we derive, without ever tracing the model:

- ``abstract(spec_tree)``      -> ShapeDtypeStruct tree (dry-run stand-ins)
- ``initialize(key, spec_tree)``-> materialized fp32 parameters
- ``pspecs(spec_tree, mesh, rules)`` -> NamedSharding tree (via
  :mod:`repro.sharding.rules`)

This is the MaxText/Flax "logical axis" pattern reduced to its essentials,
and it is what lets the 512-device dry-run lower full-size models on a CPU
without allocating a byte of parameter memory.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor."""
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]   # logical axis name per dim
    init: str = "normal"                 # normal|zeros|ones|embed|trunc_fan_in
    scale: float = 1.0                   # multiplier on the init law
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(
                f"shape {self.shape} and logical {self.logical} rank mismatch")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract(spec_tree: PyTree) -> PyTree:
    """ShapeDtypeStruct stand-ins (no allocation) for a spec tree."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree,
        is_leaf=is_spec)


def _init_one(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        # LeCun-style fan-in scaling on the first dim (input features).
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        std = spec.scale / math.sqrt(fan_in)
        return (jax.random.normal(key, spec.shape, spec.dtype) * std)
    if spec.init == "embed":
        std = spec.scale
        return jax.random.normal(key, spec.shape, spec.dtype) * std
    if spec.init == "trunc_fan_in":
        fan_in = spec.shape[0]
        std = spec.scale / math.sqrt(fan_in)
        return (jax.random.truncated_normal(key, -2.0, 2.0, spec.shape,
                                            spec.dtype) * std)
    raise ValueError(f"unknown init {spec.init!r}")


def initialize(key: jax.Array, spec_tree: PyTree) -> PyTree:
    """Materialize fp32 parameters; one fold of the key per leaf."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves)) if leaves else []
    params = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, params)


def logical_axes(spec_tree: PyTree) -> PyTree:
    """Tree of logical-axis tuples matching the spec tree's structure."""
    return jax.tree.map(lambda s: s.logical, spec_tree, is_leaf=is_spec)


def count_params(spec_tree: PyTree) -> int:
    return sum(math.prod(s.shape)
               for s in jax.tree.leaves(spec_tree, is_leaf=is_spec))


def stack_specs(spec_tree: PyTree, n: int, axis_name: Optional[str] = "layers",
                ) -> PyTree:
    """Prepend a stacking dim of size ``n`` to every spec (scan-over-layers)."""

    def _stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, (axis_name,) + s.logical,
                         init=s.init, scale=s.scale, dtype=s.dtype)

    return jax.tree.map(_stack, spec_tree, is_leaf=is_spec)
