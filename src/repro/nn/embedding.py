"""Token embeddings, stub modality frontends, and the output head.

The unembed projection produces logits in the compute dtype; the loss is
responsible for fp32 log-sum-exp (the astype is fused by XLA into the
reduction, so no fp32 (B,S,V) tensor is ever materialized).  Final logit
softcap (gemma-2) runs in fp32 per the paper's force-full-precision rule.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn.norms import softcap as apply_softcap
from repro.nn.param import ParamSpec
from repro.sharding.rules import shard


def embedding_spec(cfg):
    spec = {}
    if cfg.frontend != "frames":          # audio stub consumes features only
        spec["tok"] = ParamSpec((cfg.vocab_size, cfg.d_model),
                                ("vocab", "embed"), init="embed", scale=0.02)
    if cfg.frontend in ("frames", "patches"):
        dim = cfg.frontend_dim or cfg.d_model
        spec["frontend_proj"] = ParamSpec((dim, cfg.d_model),
                                          ("img_embed", "embed"))
    return spec


def unembed_spec(cfg):
    if cfg.tie_embeddings or cfg.frontend == "frames":
        # frames (hubert): classification head over vocab_size units
        if cfg.frontend == "frames":
            return {"w": ParamSpec((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"))}
        return {}
    return {"w": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))}


def embed_tokens(params, cfg, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    """tokens (B,S) int32 -> (B,S,d) in compute dtype."""
    x = params["tok"].astype(dtype)[tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return shard(x, ("batch", "seq", "embed"))


def embed_frontend(params, cfg, features: jnp.ndarray, dtype) -> jnp.ndarray:
    """Stub frontend: precomputed frame/patch embeddings -> model width."""
    x = features.astype(dtype) @ params["frontend_proj"].astype(dtype)
    return shard(x, ("batch", "seq", "embed"))


def logits_fn(embed_params, unembed_params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """x (B,S,d) -> logits (B,S,V) in compute dtype (+ fp32 softcap)."""
    dtype = x.dtype
    if cfg.tie_embeddings and "tok" in embed_params and not unembed_params:
        logits = jnp.einsum("bsd,vd->bsv", x, embed_params["tok"].astype(dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, unembed_params["w"].astype(dtype))
    if cfg.final_softcap > 0:
        logits = apply_softcap(logits, cfg.final_softcap)
    return shard(logits, ("batch", "seq", "vocab"))
