"""Multi-head attention: GQA, RoPE, sliding windows, logit softcap, caches.

Three execution paths share one set of parameters:

- ``attend_plain``   — masked einsum softmax; small sequences (<= 4k).
- ``attend_blocked`` — query-block × key-block streaming softmax with fp32
  running (max, sum, acc) state.  This is the flash-attention recurrence
  expressed in pure jnp so it lowers/partitions under GSPMD for the 512-chip
  dry-run; causal/window key blocks that are fully masked are *statically
  skipped* (the query loop is a Python loop over static slices), so long
  prefills don't pay the 2× dense-causal FLOP tax and never materialize an
  (S, S) score tensor.  The Pallas kernel in ``repro/kernels/flash_attention``
  is the TPU-native version of exactly this loop.
- ``decode_step``    — single-token query against a (possibly rolling) KV
  cache.

Precision follows the paper: QK^T and PV matmuls run in the compute dtype
(bf16/fp16 on the MXU), softmax statistics and accumulators are fp32
(``force_full_precision`` / explicit fp32 state).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import mpx
from repro.nn.norms import softcap as apply_softcap
from repro.nn.param import ParamSpec
from repro.nn.rope import apply_rope
from repro.sharding.rules import shard

NEG_INF = -1e30  # fp32 additive mask value (not -inf: avoids NaN on all-masked rows)


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def attention_spec(d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
                   qkv_bias: bool = False, out_bias: bool = False):
    spec = {
        "wq": ParamSpec((d_model, n_heads, head_dim),
                        ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d_model, n_kv_heads, head_dim),
                        ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d_model, n_kv_heads, head_dim),
                        ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((n_heads, head_dim, d_model),
                        ("heads", "head_dim", "embed")),
    }
    if qkv_bias:
        spec["bq"] = ParamSpec((n_heads, head_dim), ("heads", "head_dim"),
                               init="zeros")
        spec["bk"] = ParamSpec((n_kv_heads, head_dim),
                               ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamSpec((n_kv_heads, head_dim),
                               ("kv_heads", "head_dim"), init="zeros")
    if out_bias:
        spec["bo"] = ParamSpec((d_model,), ("embed",), init="zeros")
    return spec


def _project_qkv(params, x, positions, theta):
    """x (B,S,d) -> q (B,S,H,D), k/v (B,S,K,D); RoPE applied if theta > 0."""
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if "bq" in params:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if theta > 0:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    q = shard(q, ("batch", "seq", "heads", "head_dim"))
    k = shard(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B,S,K,D) -> (B,S,H,D) by repeating each KV head H/K times."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


# --------------------------------------------------------------------------
# plain path (short sequences)
# --------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int) -> jnp.ndarray:
    """(Sq, Sk) fp32 additive mask from position vectors (fused by XLA)."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attend_plain(q, k, v, *, causal: bool, window: int, cap: float,
                 q_positions=None, k_positions=None) -> jnp.ndarray:
    """q (B,Sq,H,D), k/v (B,Sk,K,D), K divides H -> (B,Sq,H,D).

    GQA runs as grouped einsums WITHOUT materializing H-expanded K/V —
    expanding first costs an H/K-times-inflated KV gather (and a matching
    fp32 dK reduction in backward) on meshes where heads don't shard
    (§Perf iteration B-3).
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    sk = k.shape[1]
    qg = q.reshape(b, sq, kv, h // kv, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) * scale
    if cap > 0:
        scores = apply_softcap(scores, cap)
    q_pos = q_positions if q_positions is not None else jnp.arange(sq)
    k_pos = k_positions if k_positions is not None else jnp.arange(sk)
    bias = _mask_bias(q_pos, k_pos, causal, window)
    probs = mpx.force_full_precision(jax.nn.softmax, q.dtype)(
        scores.astype(jnp.float32) + bias, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, d)


# --------------------------------------------------------------------------
# blocked path (long sequences): streaming softmax, static block skipping
# --------------------------------------------------------------------------

def attend_blocked(q, k, v, *, causal: bool, window: int, cap: float,
                   q_block: int = 2048, k_block: int = 2048) -> jnp.ndarray:
    """Flash-style blocked attention in pure jnp (self-attention, aligned
    positions).  fp32 running max/sum/accumulator; bf16 matmuls."""
    b, s, h, d = q.shape
    assert k.shape[1] == s, "blocked path is for self-attention"
    scale = 1.0 / math.sqrt(d)
    q_block = min(q_block, s)
    k_block = min(k_block, s)
    n_q = (s + q_block - 1) // q_block
    outs = []
    for qi in range(n_q):
        q_lo, q_hi = qi * q_block, min((qi + 1) * q_block, s)
        qb = q[:, q_lo:q_hi]                                   # (B,Qb,H,D)
        # static key range for this query block
        k_hi = q_hi if causal else s
        k_lo = max(0, q_lo - window + 1) if window > 0 else 0
        k_lo = (k_lo // k_block) * k_block                     # align
        acc = jnp.zeros((b, q_hi - q_lo, h, d), jnp.float32)
        m = jnp.full((b, h, q_hi - q_lo), NEG_INF, jnp.float32)
        l = jnp.zeros((b, h, q_hi - q_lo), jnp.float32)
        q_pos = jnp.arange(q_lo, q_hi)
        for kj_lo in range(k_lo, k_hi, k_block):
            kj_hi = min(kj_lo + k_block, k_hi)
            kb = k[:, kj_lo:kj_hi]
            vb = v[:, kj_lo:kj_hi]
            scores = jnp.einsum("bqhd,bkhd->bhqk", qb, kb) * scale
            scores = scores.astype(jnp.float32)
            if cap > 0:
                scores = cap * jnp.tanh(scores / cap)
            k_pos = jnp.arange(kj_lo, kj_hi)
            need_mask = (causal and kj_hi > q_lo) or window > 0
            if need_mask:
                scores = scores + _mask_bias(q_pos, k_pos, causal, window)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            correction = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new[..., None])             # (B,H,Qb,Kb)
            l = l * correction + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), vb)
            acc = acc * correction.transpose(0, 2, 1)[..., None] + pv
            m = m_new
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        outs.append(out.astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

#: sequences above this use the blocked path (never materialize S×S scores)
BLOCKED_THRESHOLD = 8192


def attention_apply(params, x, *, n_heads: int, causal: bool, window: int,
                    cap: float, rope_theta: float,
                    positions: Optional[jnp.ndarray] = None,
                    use_blocked: Optional[bool] = None) -> jnp.ndarray:
    """Self-attention over x (B,S,d) -> (B,S,d)."""
    s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(params, x, positions, rope_theta)
    blocked = use_blocked if use_blocked is not None else s > BLOCKED_THRESHOLD
    # expanded-KV path only where heads shard cleanly over the model axis
    # (the reshape in the grouped path would cross shard boundaries there);
    # grouped path everywhere else — it avoids the H/K-inflated KV gather.
    from repro.sharding import rules as _R
    mesh, _ = _R._get_ctx()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    heads_shard = msize > 1 and n_heads % msize == 0
    if blocked:
        out = attend_blocked(q, _expand_kv(k, n_heads),
                             _expand_kv(v, n_heads),
                             causal=causal, window=window, cap=cap)
    elif heads_shard:
        out = attend_plain(q, _expand_kv(k, n_heads), _expand_kv(v, n_heads),
                           causal=causal, window=window, cap=cap)
    else:
        out = attend_plain(q, k, v, causal=causal, window=window, cap=cap)
    out = shard(out, ("batch", "seq", "heads", "head_dim"))
    y = jnp.einsum("bqhd,hdm->bqm", out, params["wo"].astype(x.dtype))
    if "bo" in params:
        y = y + params["bo"].astype(x.dtype)
    return shard(y, ("batch", "seq", "embed"))


# --------------------------------------------------------------------------
# decode (KV cache)
# --------------------------------------------------------------------------

def init_cache_spec(batch: int, max_seq: int, n_kv_heads: int, head_dim: int,
                    window: int, dtype) -> dict:
    """Abstract cache layout for one attention layer.

    Local-attention layers store a rolling buffer of ``window`` positions —
    this is what makes mixtral/gemma2/recurrentgemma long-context decode
    sub-quadratic in memory.
    """
    length = min(max_seq, window) if window > 0 else max_seq
    return {
        "k": jax.ShapeDtypeStruct((batch, length, n_kv_heads, head_dim), dtype),
        "v": jax.ShapeDtypeStruct((batch, length, n_kv_heads, head_dim), dtype),
    }


def init_cache(batch, max_seq, n_kv_heads, head_dim, window, dtype):
    spec = init_cache_spec(batch, max_seq, n_kv_heads, head_dim, window, dtype)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec,
                        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))


def decode_step(params, cache, x, pos, *, n_heads: int, window: int,
                cap: float, rope_theta: float):
    """One decode step.  x (B,1,d), pos scalar int32 -> (y (B,1,d), cache').

    The cache seq dim is a rolling buffer for windowed layers
    (slot = pos mod window); full-attention layers write at ``pos``.
    Positions beyond ``pos`` are masked via a stored-position comparison,
    which also handles the rolling wrap-around correctly.
    """
    dtype = x.dtype
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, positions, rope_theta)
    length = cache["k"].shape[1]
    slot = pos % length if window > 0 else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(dtype), slot, axis=1)
    new_cache = {"k": k, "v": v}

    kx = _expand_kv(k, n_heads)
    vx = _expand_kv(v, n_heads)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kx) * scale
    scores = scores.astype(jnp.float32)
    if cap > 0:
        scores = cap * jnp.tanh(scores / cap)
    # stored position of each slot (rolling-buffer aware)
    idx = jnp.arange(length)
    if window > 0:
        # slot i currently holds position: the latest p <= pos with p % length == i
        stored = pos - ((pos - idx) % length)
        valid = (stored >= 0) & (stored > pos - window) & (stored <= pos)
    else:
        stored = idx
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vx)
    y = jnp.einsum("bqhd,hdm->bqm", out, params["wo"].astype(dtype))
    if "bo" in params:
        y = y + params["bo"].astype(dtype)
    return y, new_cache


# --------------------------------------------------------------------------
# paged KV cache (serving): fixed-size pages + per-slot page tables
# --------------------------------------------------------------------------
#
# The serving engine (``repro.serve``) replaces the monolithic per-slot
# (B, max_seq, K, D) cache slab with a shared pool of fixed-size pages:
# each slot owns a row of a page table mapping logical page -> physical
# page, so HBM is committed per admitted request, not per slot capacity.
# Token position p of slot b lives at pages[table[b, p // page_size],
# p % page_size].  Unallocated table entries hold the sentinel ``n_pages``
# (writes there are dropped).
#
# Attention reads the pool two ways.  The native path
# (``use_kernel=True``) is the Pallas paged-attention kernel
# (``repro.kernels.paged_attention``): its block index maps walk each
# slot's page table directly, so only allocated pages are streamed and no
# contiguous copy of the cache ever exists.  The fallback
# (``paged_gather`` + masked softmax) materializes each slot's padded
# prefix as a dense (B, Pmax*page_size, K, D) view — sentinel entries
# read clamped garbage that is masked by position.  The fallback is the
# numerics oracle and the non-TPU / windowed / softcapped path, not the
# serving layout.
#
# Pools may store sub-bf16 (``kv_format`` in {"i8", "f8_e4m3",
# "f8_e3m4"}, see ``repro.quant``): values live on the format's grid
# with a (P, K) fp32 amax-scale sidecar per pool.  Writes quantize
# (``quant.ops.quantized_pool_write`` requantizes exactly the touched
# pages), the kernel dequantizes block-by-block in VMEM, and the gather
# fallback dequantizes its dense view right after gathering.

def paged_cache_spec(n_pages: int, page_size: int, n_kv_heads: int,
                     head_dim: int, dtype, kv_format: str = "bf16") -> dict:
    """Abstract paged K/V pool layout for one attention layer.

    ``kv_format`` "bf16" is the passthrough {"k", "v"} pair in ``dtype``;
    quantized formats add the {"k_scale", "v_scale"} fp32 sidecars and
    store the pools in the format's storage dtype (``repro.quant``)."""
    from repro.quant import formats as qfmt
    return qfmt.pool_spec(n_pages, page_size, n_kv_heads, head_dim,
                          kv_format, dtype=dtype)


def paged_write(pages: jnp.ndarray, vals: jnp.ndarray,
                page_table: jnp.ndarray, positions: jnp.ndarray,
                valid: jnp.ndarray, *, page_size: int) -> jnp.ndarray:
    """Scatter ``vals`` (B, C, K, D) into ``pages`` (P, ps, K, D).

    ``positions`` (B, C) are absolute token positions, ``valid`` (B,) the
    number of real tokens per slot (suffix is padding).  Padding tokens and
    slots whose table entry is the sentinel scatter out of bounds and are
    dropped.

    Ownership contract: the caller must hold every targeted physical page
    *exclusively* — this scatter mutates rows in place and knows nothing
    about sharing.  Under prefix caching (``PagedStatePool`` with
    ``prefix_cache=True``) pages can be referenced by several slots'
    tables at once; the pool's ``note_write``/COW machinery copies any
    shared page and repoints the writing slot *before* the write is
    flushed, so by the time this function runs every targeted page has
    refcount 1 again.  Bypassing the pool's write path breaks that
    guarantee silently.
    """
    n_pages = pages.shape[0]
    b, c = positions.shape
    phys = jnp.take_along_axis(page_table, positions // page_size, axis=1)
    off = positions % page_size
    ok = jnp.arange(c)[None, :] < valid[:, None]
    phys = jnp.where(ok, phys, n_pages)                      # OOB -> dropped
    return pages.at[phys.reshape(-1), off.reshape(-1)].set(
        vals.reshape((b * c,) + vals.shape[2:]), mode="drop")


def paged_gather(pages: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """(P, ps, K, D), (B, Pmax) -> contiguous view (B, Pmax*ps, K, D).

    The gather-based *fallback* layout for attention: a dense padded copy
    of every slot's prefix, sentinel entries reading clamped garbage that
    the caller masks by length.  The serving hot path never calls this —
    ``paged_attend(use_kernel=True)`` streams pages through the page
    table inside the Pallas kernel instead.
    """
    g = pages[page_table]
    b, pmax, ps = g.shape[:3]
    return g.reshape((b, pmax * ps) + g.shape[3:])


def paged_gather_scales(scales: jnp.ndarray, page_table: jnp.ndarray,
                        page_size: int) -> jnp.ndarray:
    """(P, K) sidecar, (B, Pmax) -> per-position scales (B, Pmax*ps, K).

    Companion to :func:`paged_gather` for quantized pools: every token of
    a page shares its page's per-head scale, so the gathered scale is
    broadcast over the ``page_size`` rows.  Fallback/oracle path only —
    the kernel reads the (P, K) sidecar directly from SMEM.
    """
    g = scales[page_table]                                # (B, Pmax, K)
    b, pmax, kv = g.shape
    return jnp.broadcast_to(g[:, :, None, :],
                            (b, pmax, page_size, kv)).reshape(
                                b, pmax * page_size, kv)


def paged_attend(params, pages: dict, page_table: jnp.ndarray,
                 x: jnp.ndarray, positions: jnp.ndarray, valid: jnp.ndarray,
                 *, page_size: int, n_heads: int, window: int, cap: float,
                 rope_theta: float, use_kernel: bool = False,
                 pages_per_block: int = 1, kv_format: str = "bf16"):
    """Chunked-prefill / decode attention against a paged KV cache.

    x (B, C, d) with per-token absolute ``positions`` (B, C) and ``valid``
    (B,) real-token counts.  Writes the chunk's K/V into the pages, then
    attends every query to its slot's full cached prefix, causal by
    absolute position.  C=1 with valid=1 is exactly single-token decode;
    C>1 is a prefill chunk, a speculative decode window (valid = 1 + k
    proposed tokens, verified causally in one pass — the same C>1 program
    as prefill), or a mixed-chunk serving step in which decode slots
    carry small valid and idle slots valid=0.  Returns
    (y (B, C, d), new ``pages`` dict).

    ``kv_format`` selects the pool storage precision (``repro.quant``):
    "bf16" writes/reads the pools as-is; "i8" / "f8_e4m3" / "f8_e3m4"
    quantize the chunk's K/V on write (per-page/per-head amax scales in
    the pool dict's ``k_scale`` / ``v_scale`` fp32 sidecars) and
    dequantize on read — in VMEM inside the kernel, or on the gathered
    view in the fallback.

    ``use_kernel=True`` runs the Pallas paged-attention kernel
    (:mod:`repro.kernels.paged_attention`) for full-attention layers: the
    page table is a scalar-prefetch operand (quantized scale sidecars
    ride blocked VMEM through the same page index maps — they scale with
    the pool, so SMEM is the wrong home) and the kernel's block index
    maps stream each slot's allocated pages directly from the shared
    pool — the gathered
    contiguous (B, Pmax*page_size, K, D) copy is never formed, for
    decode AND prefill chunks alike, and quantized pools are multiplied
    back to the compute dtype block-by-block so no dense bf16 image of
    the cache exists either.  ``pages_per_block`` widens each kernel
    K-block to span that many logical pages (page_size 16 alone
    underfills the 128-lane MXU dim).  Sliding-window (``window > 0``)
    and softcapped (``cap > 0``) layers, and ``use_kernel=False``, take
    the pure-jnp gather fallback — the numerics oracle, which runs
    everywhere.
    """
    from repro.quant import formats as qfmt, ops as qops
    fmt = qfmt.resolve(kv_format)
    dtype = x.dtype
    q, k_new, v_new = _project_qkv(params, x, positions, rope_theta)
    if fmt.quantized:
        new_pages = qops.quantized_pool_write(
            pages, k_new, v_new, page_table, positions, valid,
            page_size=page_size, fmt=fmt)
    else:
        new_pages = {
            "k": paged_write(pages["k"], k_new.astype(dtype), page_table,
                             positions, valid, page_size=page_size),
            "v": paged_write(pages["v"], v_new.astype(dtype), page_table,
                             positions, valid, page_size=page_size),
        }
    if use_kernel and window == 0 and cap <= 0:
        from repro.kernels.paged_attention import paged_attention
        out = paged_attention(q, new_pages["k"], new_pages["v"], page_table,
                              positions[:, 0], valid,
                              k_scales=new_pages.get("k_scale"),
                              v_scales=new_pages.get("v_scale"),
                              pages_per_block=pages_per_block,
                              interpret=jax.default_backend() != "tpu")
    else:
        k = paged_gather(new_pages["k"], page_table)         # (B, S, K, D)
        v = paged_gather(new_pages["v"], page_table)
        if fmt.quantized:
            # scale sidecar gathered per page, broadcast over page rows —
            # the dense dequantized view exists ONLY on this oracle path
            ks = paged_gather_scales(new_pages["k_scale"], page_table,
                                     page_size)
            vs = paged_gather_scales(new_pages["v_scale"], page_table,
                                     page_size)
            k = qops.dequantize(k, ks[..., None], dtype)
            v = qops.dequantize(v, vs[..., None], dtype)
        kx = _expand_kv(k, n_heads)
        vx = _expand_kv(v, n_heads)
        scale = 1.0 / math.sqrt(q.shape[-1])
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kx) * scale
        scores = scores.astype(jnp.float32)
        if cap > 0:
            scores = cap * jnp.tanh(scores / cap)
        idx = jnp.arange(k.shape[1])
        ok = idx[None, None, :] <= positions[:, :, None]     # (B, C, S)
        if window > 0:
            ok &= idx[None, None, :] > positions[:, :, None] - window
        scores = jnp.where(ok[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vx)
    y = jnp.einsum("bqhd,hdm->bqm", out, params["wo"].astype(dtype))
    if "bo" in params:
        y = y + params["bo"].astype(dtype)
    return y, new_pages
