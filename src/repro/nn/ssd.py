"""Mamba-2 SSD (state-space duality) block.

Implements the chunked SSD algorithm from Dao & Gu (arXiv:2405.21060):
within a chunk of Q tokens the computation is an attention-like quadratic
form (maps onto the MXU); across chunks a compact (H, P, N) state is carried
through a `lax.scan` — O(S·Q) work, O(S) memory, constant-size decode state.

Precision (DESIGN.md §4): `softplus(dt)`, the `dt*A` cumulative sums, all
`exp` decays and the carried state are fp32 — these are long products of
near-one factors, exactly the compounding-rounding shape MPX's
`force_full_precision` exists for.  The large einsums (CB^T, score·x,
state outer products) run in the compute dtype.

Projections are kept separate per component (z, x, B, C, dt) instead of one
fused in_proj: identical FLOPs, but each output gets its own logical
sharding axis, which is what lets `ssm_inner` TP-shard while B/C/dt stay
replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec
from repro.sharding.rules import shard


def ssd_spec(d_model: int, d_inner: int, n_heads: int, headdim: int,
             d_state: int, conv_width: int = 4):
    assert d_inner == n_heads * headdim
    return {
        "w_z": ParamSpec((d_model, d_inner), ("embed", "ssm_inner")),
        "w_x": ParamSpec((d_model, d_inner), ("embed", "ssm_inner")),
        "w_B": ParamSpec((d_model, d_state), ("embed", "ssm_state")),
        "w_C": ParamSpec((d_model, d_state), ("embed", "ssm_state")),
        "w_dt": ParamSpec((d_model, n_heads), ("embed", "ssm_heads")),
        "conv_x": ParamSpec((conv_width, d_inner), (None, "ssm_inner"),
                            init="normal", scale=0.5),
        "conv_B": ParamSpec((conv_width, d_state), (None, "ssm_state"),
                            init="normal", scale=0.5),
        "conv_C": ParamSpec((conv_width, d_state), (None, "ssm_state"),
                            init="normal", scale=0.5),
        "A_log": ParamSpec((n_heads,), ("ssm_heads",), init="ones",
                           scale=1.386),     # A = -exp(A_log) ≈ -4
        "D": ParamSpec((n_heads,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((n_heads,), ("ssm_heads",), init="ones",
                             scale=-4.6),    # softplus ≈ 0.01
        "norm_w": ParamSpec((d_inner,), ("ssm_inner",), init="ones"),
        "w_out": ParamSpec((d_inner, d_model), ("ssm_inner", "embed")),
    }


def _conv1d(x, w, state=None):
    """Depthwise causal conv along seq; x (B,S,C), w (W,C)."""
    width = w.shape[0]
    if state is not None:
        hist = jnp.concatenate([state, x], axis=1)
        new_state = hist[:, -(width - 1):]
    else:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
        hist = jnp.concatenate([pad, x], axis=1)
        new_state = None
    y = sum(hist[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(width))
    return jax.nn.silu(y), new_state


def _gated_rmsnorm(w, y, z):
    """Mamba-2's RMSNorm(y * silu(z)) with fp32 statistics."""
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    rms = jnp.sqrt(jnp.mean(y32 * y32, axis=-1, keepdims=True) + 1e-6)
    return ((y32 / rms) * w.astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(x, dt, a, bmat, cmat, d_skip, chunk: int):
    """Chunked SSD scan.

    x (B,S,H,P) compute dtype; dt (B,S,H) fp32 (post-softplus);
    a (H,) fp32 negative; bmat/cmat (B,S,N) compute dtype; d_skip (H,) fp32.
    Returns y (B,S,H,P) in x.dtype.
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # zero-pad the tail: dt=0 makes padded steps exact no-ops for the
        # state (decay exp(0·a)=1, input dt·x=0); padded y rows are sliced.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // q
    dtype = x.dtype

    # chunked views, scan axis first
    xc = x.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    bc = bmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    cc = cmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((q, q), jnp.bool_))

    def chunk_step(state, inp):
        """state (B,H,P,N) fp32."""
        x_c, dt_c, b_c, c_c = inp                    # (B,Q,H,P),(B,Q,H),(B,Q,N)
        da = dt_c * a                                 # (B,Q,H) fp32, negative
        da_cs = jnp.cumsum(da, axis=1)                # (B,Q,H)
        # --- intra-chunk (attention-like, causal) ---
        cb = jnp.einsum("bln,bsn->bls", c_c, b_c).astype(jnp.float32)
        # mask INSIDE the exponent: the upper triangle would be exp(+large)
        # = inf, and the later 0-masking would turn its cotangent into
        # 0 * inf = NaN.  exp(-1e30) = 0 kills value and gradient cleanly.
        ldiff = da_cs[:, :, None, :] - da_cs[:, None, :, :]   # (B,l,s,H)
        ldiff = jnp.where(tri[None, :, :, None], ldiff, -1e30)
        scores = cb[..., None] * jnp.exp(ldiff)
        y_diag = jnp.einsum("blsh,bsh,bshp->blhp",
                            scores.astype(dtype), dt_c.astype(dtype), x_c)
        # --- contribution of incoming state ---
        state_decay = jnp.exp(da_cs)                  # (B,Q,H)
        y_off = jnp.einsum("bln,bhpn->blhp", c_c,
                           state.astype(dtype)) * state_decay[..., None].astype(dtype)
        # --- state update ---
        total = da_cs[:, -1, :]                       # (B,H)
        decay_out = jnp.exp(total[:, None, :] - da_cs)  # (B,Q,H)
        dx = (dt_c * decay_out)[..., None].astype(dtype) * x_c  # (B,Q,H,P)
        state_new = state * jnp.exp(total)[:, :, None, None] \
            + jnp.einsum("bqhp,bqn->bhpn", dx, b_c).astype(jnp.float32)
        return state_new, (y_diag + y_off).astype(dtype)

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, state0, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    y = y + d_skip.astype(dtype)[None, None, :, None] * x
    return y[:, :s_orig] if pad else y


def ssd_block_apply(params, xin, *, n_heads: int, headdim: int, d_state: int,
                    chunk: int = 256, conv_width: int = 4,
                    state: dict | None = None):
    """Full Mamba-2 block.  xin (B,S,d_model) -> same shape.

    ``state`` None for training; dict(conv_x/conv_B/conv_C, ssm) for decode
    — returns (y, new_state) then.
    """
    dtype = xin.dtype
    b, s, _ = xin.shape
    z = xin @ params["w_z"].astype(dtype)
    x = xin @ params["w_x"].astype(dtype)
    bmat = xin @ params["w_B"].astype(dtype)
    cmat = xin @ params["w_C"].astype(dtype)
    dt_raw = (xin @ params["w_dt"].astype(dtype)).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    x = shard(x, ("batch", "seq", "ssm_inner"))

    if state is None:
        x, _ = _conv1d(x, params["conv_x"])
        bmat, _ = _conv1d(bmat, params["conv_B"])
        cmat, _ = _conv1d(cmat, params["conv_C"])
        xh = x.reshape(b, s, n_heads, headdim)
        y = ssd_chunked(xh, dt, a, bmat, cmat, params["D"], chunk)
        y = y.reshape(b, s, n_heads * headdim)
        y = _gated_rmsnorm(params["norm_w"], y, z)
        out = y @ params["w_out"].astype(dtype)
        return shard(out, ("batch", "seq", "embed"))

    # ---- decode: O(1) state update ----
    x, cs_x = _conv1d(x, params["conv_x"], state["conv_x"])
    bmat, cs_b = _conv1d(bmat, params["conv_B"], state["conv_B"])
    cmat, cs_c = _conv1d(cmat, params["conv_C"], state["conv_C"])
    xh = x.reshape(b, 1, n_heads, headdim)[:, 0]        # (B,H,P)
    da = jnp.exp(dt[:, 0] * a)                          # (B,H) fp32
    # state' = exp(dt*A) state + dt * x ⊗ B
    ssm = state["ssm"] * da[:, :, None, None] \
        + jnp.einsum("bhp,bn->bhpn",
                     (dt[:, 0][..., None].astype(dtype) * xh),
                     bmat[:, 0]).astype(jnp.float32)
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], ssm.astype(dtype))
    y = y + params["D"].astype(dtype)[None, :, None] * xh
    y = y.reshape(b, 1, n_heads * headdim)
    y = _gated_rmsnorm(params["norm_w"], y, z)
    out = y @ params["w_out"].astype(dtype)
    return out, {"conv_x": cs_x, "conv_B": cs_b, "conv_C": cs_c, "ssm": ssm}


def ssd_serve_chunk(params, xin, state, valid, *, n_heads: int, headdim: int,
                    d_state: int, conv_width: int = 4):
    """Chunked-prefill / ragged-decode serve entry point.

    xin (B,C,d_model); state dict(conv_x/conv_B/conv_C, ssm) per slot;
    valid (B,) int32 — how many leading positions of each row are real.
    Returns (y (B,C,d_model), new_state).

    Positions are advanced by a sequential per-position ``lax.scan`` that
    executes exactly the decode-branch ops (projections batched — row-wise
    identical matmuls), NOT the chunked quadratic form: the quadratic path
    has a different bf16 summation order, and serving pins greedy token
    identity against per-token ``decode()``.  Padded positions (>= valid)
    produce garbage outputs (never gathered) and are exact state no-ops.
    """
    dtype = xin.dtype
    b, c, _ = xin.shape
    z = xin @ params["w_z"].astype(dtype)
    x = xin @ params["w_x"].astype(dtype)
    bmat = xin @ params["w_B"].astype(dtype)
    cmat = xin @ params["w_C"].astype(dtype)
    dt_raw = (xin @ params["w_dt"].astype(dtype)).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    x = shard(x, ("batch", "seq", "ssm_inner"))

    def conv_step(w, buf, xt):
        hist = jnp.concatenate([buf, xt[:, None]], axis=1)
        y = sum(hist[:, i] * w[i].astype(xt.dtype) for i in range(w.shape[0]))
        return jax.nn.silu(y), hist[:, 1:]

    def keep(ok, new, old):
        m = ok.reshape((b,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)

    def step(carry, inp):
        cx, cb, cc, ssm = carry
        xt, bt, ct, dtt, ok = inp
        xs, cx_new = conv_step(params["conv_x"], cx, xt)
        bs, cb_new = conv_step(params["conv_B"], cb, bt)
        cs, cc_new = conv_step(params["conv_C"], cc, ct)
        xh = xs.reshape(b, n_heads, headdim)
        da = jnp.exp(dtt * a)
        ssm_new = ssm * da[:, :, None, None] + jnp.einsum(
            "bhp,bn->bhpn", dtt[..., None].astype(dtype) * xh,
            bs).astype(jnp.float32)
        y = jnp.einsum("bn,bhpn->bhp", cs, ssm_new.astype(dtype))
        y = y + params["D"].astype(dtype)[None, :, None] * xh
        carry = (keep(ok, cx_new, cx), keep(ok, cb_new, cb),
                 keep(ok, cc_new, cc), keep(ok, ssm_new, ssm))
        return carry, y

    ok = jnp.arange(c)[:, None] < valid[None, :]             # (C, B)
    init = (state["conv_x"], state["conv_B"], state["conv_C"], state["ssm"])
    (cx, cb, cc, ssm), ys = jax.lax.scan(
        step, init,
        (x.transpose(1, 0, 2), bmat.transpose(1, 0, 2),
         cmat.transpose(1, 0, 2), dt.transpose(1, 0, 2), ok))
    y = ys.transpose(1, 0, 2, 3).reshape(b, c, n_heads * headdim)
    y = _gated_rmsnorm(params["norm_w"], y, z)
    out = y @ params["w_out"].astype(dtype)
    return (shard(out, ("batch", "seq", "embed")),
            {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssm": ssm})


def ssd_state_spec(batch: int, d_inner: int, d_state: int, n_heads: int,
                   headdim: int, conv_width: int, dtype):
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, conv_width - 1, d_inner), dtype),
        "conv_B": jax.ShapeDtypeStruct((batch, conv_width - 1, d_state), dtype),
        "conv_C": jax.ShapeDtypeStruct((batch, conv_width - 1, d_state), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, n_heads, headdim, d_state),
                                    jnp.float32),
    }
