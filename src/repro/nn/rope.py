"""Rotary position embeddings (RoPE), llama-style interleaved-half variant.

Frequencies are computed in fp32 (tiny tables, huge dynamic range for
theta=500k at 500k positions) and applied in the activation dtype.
Supports absolute position offsets for decode (query at position ``pos``
against a cache of earlier keys).
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies, fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               ) -> jnp.ndarray:
    """Rotate ``x`` of shape (..., seq, heads, head_dim) by ``positions``.

    ``positions`` has shape (..., seq) (broadcastable); angles are fp32,
    the rotation is applied in fp32 and cast back (sin/cos of large
    position×frequency products are precision-critical — bf16 angles at
    position 500k would alias).
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                     # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin,
                               x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)
