"""Normalization layers with fp32 statistics (the paper's Example 1 rule).

Sums/means are exactly the operations MPX forces to full precision.  Both
norms here compute their statistics under ``mpx.force_full_precision`` and
cast the result back to the activation dtype, so a bf16/fp16 forward pass
never accumulates a mean or variance in half precision.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import mpx
from repro.nn.param import ParamSpec


def rmsnorm_spec(dim: int, logical: str = "embed"):
    return {"scale": ParamSpec((dim,), (logical,), init="ones")}


def layernorm_spec(dim: int, logical: str = "embed"):
    return {"scale": ParamSpec((dim,), (logical,), init="ones"),
            "bias": ParamSpec((dim,), (logical,), init="zeros")}


def _rms_stats(x32: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + 1e-6)


def rmsnorm(params, x: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm; statistics in fp32, output in ``x.dtype``."""
    rms = mpx.force_full_precision(_rms_stats, None)(x)
    y = (x.astype(jnp.float32) / rms) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def _ln_stats(x32: jnp.ndarray):
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mean) ** 2, axis=-1, keepdims=True)
    return mean, var


def layernorm(params, x: jnp.ndarray) -> jnp.ndarray:
    """LayerNorm; statistics in fp32, output in ``x.dtype``."""
    mean, var = mpx.force_full_precision(_ln_stats, None)(x)
    inv = (var + 1e-5) ** -0.5  # fp32
    y = (x.astype(jnp.float32) - mean) * inv
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_spec(kind: str, dim: int):
    if kind == "rmsnorm":
        return rmsnorm_spec(dim)
    if kind == "layernorm":
        return layernorm_spec(dim)
    raise ValueError(kind)


def apply_norm(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 logit soft-capping ``cap·tanh(x/cap)`` in fp32.

    tanh saturates (and its gradient dies) quickly in bf16; running the cap
    in fp32 is the kernel-level analogue of the paper's
    ``force_full_precision``d softmax.
    """
    if cap <= 0.0:
        return x

    def _cap(x32):
        return cap * jnp.tanh(x32 / cap)

    return mpx.force_full_precision(_cap, x.dtype)(x)
