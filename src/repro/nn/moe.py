"""Top-k Mixture of Experts with static-capacity dispatch (GShard-style).

Routing runs **in fp32** (`mpx.force_full_precision`) — router logits and
top-k softmax are the most precision-sensitive computation in an MoE and a
canonical application of the paper's technique (DESIGN.md §4).

Dispatch avoids the O(T·E·C) one-hot dispatch tensor of the classic einsum
formulation: assignment ranks come from a cumsum over a (T·k, E) one-hot,
and tokens move through a scatter-add into an (E, C, d) buffer and a gather
back.  Memory is O(T·k·d + E·C·d), which is what makes the 32k-prefill MoE
cells lowerable.  Tokens beyond an expert's capacity are dropped (standard
top-k-with-capacity semantics); the residual connection carries them.

Sharding: the expert dim maps to the "model" mesh axis when divisible
(phi3.5: 16 experts on 16-way TP = pure expert parallelism); otherwise the
expert-internal hidden dim is TP-sharded (mixtral: 8 experts, d_ff 14336).
Both come from the same rule table — no code change.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro import mpx
from repro.nn.param import ParamSpec
from repro.sharding import rules as R
from repro.sharding.rules import shard


def moe_spec(d_model: int, d_ff: int, n_experts: int, kind: str = "swiglu"):
    spec = {
        "router": ParamSpec((d_model, n_experts), ("embed", "experts")),
        "w_up": ParamSpec((n_experts, d_model, d_ff),
                          ("experts", "embed", "moe_mlp")),
        "w_down": ParamSpec((n_experts, d_ff, d_model),
                            ("experts", "moe_mlp", "embed")),
    }
    if kind in ("swiglu", "geglu"):
        spec["w_gate"] = ParamSpec((n_experts, d_model, d_ff),
                                   ("experts", "embed", "moe_mlp"))
    return spec


def _route_and_rank(params, xf, *, n_experts: int, top_k: int,
                    capacity: int):
    """Per-group routing + assignment ranks.  xf (T_g, d)."""
    t = xf.shape[0]

    def _route(xin):
        return xin @ params["router"].astype(jnp.float32)

    logits = mpx.force_full_precision(_route, None)(xf)          # (T,E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, top_k)               # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renorm

    me = probs.mean(axis=0)                                      # (E,)
    ce_frac = jnp.zeros((n_experts,), jnp.float32).at[expert_idx.reshape(-1)] \
        .add(1.0) / (t * top_k)
    lb_loss = n_experts * jnp.sum(me * ce_frac)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = lb_loss + 1e-3 * z_loss

    flat_e = expert_idx.reshape(-1)                              # (T·k,)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    keep = pos < capacity
    pos_c = jnp.minimum(pos, capacity - 1)
    return flat_e, pos_c, keep, gate, aux


def _dispatch(xf, flat_e, pos_c, keep, *, n_experts: int, top_k: int,
              capacity: int):
    """Scatter one group's tokens into (E, C, d)."""
    t, d = xf.shape
    token_idx = jnp.repeat(jnp.arange(t), top_k)
    contrib = jnp.where(keep[:, None], xf[token_idx], 0).astype(xf.dtype)
    x_e = jnp.zeros((n_experts, capacity, d), xf.dtype)
    return x_e.at[flat_e, pos_c].add(contrib)


def _combine(y_e, flat_e, pos_c, keep, gate, *, top_k: int):
    """Gather one group's expert outputs back to (T_g, d)."""
    t = gate.shape[0]
    d = y_e.shape[-1]
    y_assign = y_e[flat_e, pos_c]                                # (T·k, d)
    y_assign = jnp.where(keep[:, None], y_assign, 0)
    weighted = y_assign.astype(jnp.float32) * gate.reshape(-1)[:, None]
    return weighted.reshape(t, top_k, d).sum(axis=1).astype(y_e.dtype)


def _expert_ffn(params, x_e, kind: str):
    """(..., E, C, d) -> (..., E, C, d); EP or TP per the rule table."""
    dtype = x_e.dtype
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("...ecd,edf->...ecf", x_e,
                       params["w_gate"].astype(dtype))
        u = jnp.einsum("...ecd,edf->...ecf", x_e,
                       params["w_up"].astype(dtype))
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(jnp.einsum("...ecd,edf->...ecf", x_e,
                                   params["w_up"].astype(dtype)))
    h = shard(h, ("moe_group", "experts", "exp_cap", "moe_mlp"))
    return jnp.einsum("...ecf,efd->...ecd", h, params["w_down"].astype(dtype))


def _moe_one_group(params, xf: jnp.ndarray, *, n_experts: int, top_k: int,
                   kind: str, capacity: int):
    """Unsharded single-group path (unit tests / no-mesh execution)."""
    flat_e, pos_c, keep, gate, aux = _route_and_rank(
        params, xf, n_experts=n_experts, top_k=top_k, capacity=capacity)
    x_e = _dispatch(xf, flat_e, pos_c, keep, n_experts=n_experts,
                    top_k=top_k, capacity=capacity)
    y_e = _expert_ffn(params, x_e, kind)
    out = _combine(y_e, flat_e, pos_c, keep, gate, top_k=top_k)
    return out, aux.astype(jnp.float32)


def moe_decode_apply(params, x: jnp.ndarray, *, n_experts: int, top_k: int,
                     kind: str = "swiglu") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Small-batch decode fast path: dense per-token expert gather.

    The capacity-buffer scatter is built for prefill-sized T: it zeros and
    scatters an (E, C, d) buffer whose cost is independent of how few
    tokens actually flow, so at decode sizes (T = B·window, tens of
    tokens) dispatch dominates the expert FLOPs.  It is also
    batch-coupled — capacity drops depend on which *other* requests share
    the step — which is wrong for serving determinism.  Here each token
    just gathers its top-k experts' weight matrices and runs them
    directly: exact (no drops, per-token independent), O(T·k·d·f) gathered
    weights, affordable precisely because T is decode-sized.  Routing and
    the combine weighting stay fp32 (same policy as ``moe_apply``); the
    aux loss is meaningless at inference and returns 0.
    """
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    dtype = x.dtype

    def _route(xin):
        return xin @ params["router"].astype(jnp.float32)

    logits = mpx.force_full_precision(_route, None)(xf)          # (T,E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, top_k)               # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    w_up = params["w_up"][expert_idx].astype(dtype)              # (T,k,d,f)
    w_down = params["w_down"][expert_idx].astype(dtype)          # (T,k,f,d)
    u = jnp.einsum("td,tkdf->tkf", xf, w_up)
    if kind in ("swiglu", "geglu"):
        gmat = jnp.einsum("td,tkdf->tkf", xf,
                          params["w_gate"][expert_idx].astype(dtype))
        act = jax.nn.silu(gmat) if kind == "swiglu" else jax.nn.gelu(gmat)
        h = act * u
    else:
        h = jax.nn.gelu(u)
    y = jnp.einsum("tkf,tkfd->tkd", h, w_down)
    out = (y.astype(jnp.float32) * gate[..., None]).sum(axis=1)
    return out.reshape(b, s, d).astype(dtype), jnp.zeros((), jnp.float32)


def moe_apply(params, x: jnp.ndarray, *, n_experts: int, top_k: int,
              kind: str = "swiglu", capacity_factor: float = 1.25,
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar fp32).

    Distribution (§Perf iteration A, see EXPERIMENTS.md): GSPMD partitions
    the dispatch *scatter* poorly — it replicates the (E, C, d) buffers,
    inserting ~38 GiB of per-layer all-gather/all-reduce on the production
    mesh.  So when a mesh is installed, the whole dispatch/compute/combine
    runs inside ``shard_map`` MANUAL over the data axes (each DP shard
    dispatches its own tokens into a local capacity buffer — GShard group
    semantics, zero cross-data collectives) while the model axis stays AUTO
    so the expert einsums keep their EP/TP GSPMD sharding.  Without a mesh
    (unit tests) the same body runs directly with one global group.
    """
    b, s, d = x.shape
    mesh, _ = R._get_ctx()
    dp_axes = tuple(ax for ax in ("pod", "data")
                    if mesh is not None and ax in mesh.shape
                    and mesh.shape[ax] > 1)
    groups = 1
    for ax in dp_axes:
        groups *= mesh.shape[ax]
    if b % groups:          # microbatch smaller than the DP section
        groups = 1
    t_g = (b // groups) * s
    capacity = int(math.ceil(t_g * top_k / n_experts * capacity_factor))

    if groups <= 1:
        out, aux = _moe_one_group(params, x.reshape(b * s, d),
                                  n_experts=n_experts, top_k=top_k,
                                  kind=kind, capacity=capacity)
        return out.reshape(b, s, d), aux

    # Staged, vmapped-over-groups pipeline with explicit sharding
    # constraints between stages.  The vmapped scatter/gather become
    # operand-batched ops whose batch (group) dim GSPMD keeps sharded on
    # the data axes — verified to eliminate the replicated-dispatch
    # collectives (EXPERIMENTS.md §Perf iteration A).
    xg = shard(x.reshape(groups, t_g, d), ("moe_group", None, "embed"))
    flat_e, pos_c, keep, gate, aux = jax.vmap(
        functools.partial(_route_and_rank, params, n_experts=n_experts,
                          top_k=top_k, capacity=capacity))(xg)
    x_e = jax.vmap(functools.partial(_dispatch, n_experts=n_experts,
                                     top_k=top_k, capacity=capacity)
                   )(xg, flat_e, pos_c, keep)
    x_e = shard(x_e, ("moe_group", "experts", "exp_cap", "embed"))
    y_e = _expert_ffn(params, x_e, kind)
    y_e = shard(y_e, ("moe_group", "experts", "exp_cap", "embed"))
    out = jax.vmap(functools.partial(_combine, top_k=top_k)
                   )(y_e, flat_e, pos_c, keep, gate)
    out = shard(out, ("moe_group", None, "embed"))
    return out.reshape(b, s, d), jnp.mean(aux)
