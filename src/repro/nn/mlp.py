"""Dense MLP blocks: SwiGLU (llama/qwen/phi), GeGLU (gemma), GELU (starcoder,
hubert, ViT).  Projections run in the compute dtype; the nonlinearity is
cheap enough that precision handling is unnecessary (silu/gelu are bounded
or near-linear — unlike softmax there is no large-sum overflow risk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec
from repro.sharding.rules import shard


def mlp_spec(kind: str, d_model: int, d_ff: int, bias: bool = False):
    if kind in ("swiglu", "geglu"):
        spec = {
            "w_gate": ParamSpec((d_model, d_ff), ("embed", "mlp")),
            "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
            "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
        }
    elif kind == "gelu":
        spec = {
            "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
            "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
        }
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    if bias:
        spec["b_up"] = ParamSpec((d_ff,), ("mlp",), init="zeros")
        spec["b_down"] = ParamSpec((d_model,), ("embed",), init="zeros")
    return spec


def mlp_apply(kind: str, params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d_model) -> (..., d_model), TP-sharded over the hidden dim."""
    dtype = x.dtype
    if kind in ("swiglu", "geglu"):
        gate = x @ params["w_gate"].astype(dtype)
        up = x @ params["w_up"].astype(dtype)
        if "b_up" in params:
            up = up + params["b_up"].astype(dtype)
        act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
        hidden = act * up
    else:  # gelu
        hidden = x @ params["w_up"].astype(dtype)
        if "b_up" in params:
            hidden = hidden + params["b_up"].astype(dtype)
        hidden = jax.nn.gelu(hidden)
    hidden = shard(hidden, ("batch", "seq", "mlp"))
    out = hidden @ params["w_down"].astype(dtype)
    if "b_down" in params:
        out = out + params["b_down"].astype(dtype)
    return shard(out, ("batch", "seq", "embed"))
