"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)  (per-channel decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Precision (DESIGN.md §4): the gate/decay math and the scan state are fp32 —
``a_t -> 1`` makes ``sqrt(1 - a_t^2)`` catastrophically cancel in bf16, and
the recurrence compounds rounding over thousands of steps.  Inputs/outputs
and the surrounding projections stay in the compute dtype.  Training uses
``jax.lax.associative_scan`` (parallel prefix, TPU-friendly); decode carries
(h, conv buffer) state per layer.

The full recurrent block (as in Griffin) is:
  norm -> [branch A: linear -> conv1d(4) -> RG-LRU] * [branch B: linear -> gelu]
       -> linear out
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec
from repro.sharding.rules import shard

_C = 8.0


def rglru_spec(d_model: int, d_rnn: int, conv_width: int = 4):
    return {
        "w_in_x": ParamSpec((d_model, d_rnn), ("embed", "rnn")),
        "w_in_gate": ParamSpec((d_model, d_rnn), ("embed", "rnn")),
        "conv_w": ParamSpec((conv_width, d_rnn), (None, "rnn"), init="normal",
                            scale=0.5),
        "conv_b": ParamSpec((d_rnn,), ("rnn",), init="zeros"),
        "w_a": ParamSpec((d_rnn, d_rnn), ("rnn", None)),
        "b_a": ParamSpec((d_rnn,), ("rnn",), init="zeros"),
        "w_x": ParamSpec((d_rnn, d_rnn), ("rnn", None)),
        "b_x": ParamSpec((d_rnn,), ("rnn",), init="zeros"),
        "lam": ParamSpec((d_rnn,), ("rnn",), init="normal", scale=1.0),
        "w_out": ParamSpec((d_rnn, d_model), ("rnn", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv along seq.  x (B,S,C), w (W,C) -> (B,S,C).

    With ``state`` (B,W-1,C): decode mode, returns (y, new_state).
    """
    width = w.shape[0]
    if state is not None:
        hist = jnp.concatenate([state, x], axis=1)          # (B, W-1+S, C)
        new_state = hist[:, -(width - 1):]
    else:
        pad = jnp.zeros(x.shape[:1] + (width - 1,) + x.shape[2:], x.dtype)
        hist = jnp.concatenate([pad, x], axis=1)
        new_state = None
    y = sum(hist[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(width))
    y = y + b.astype(x.dtype)
    return y, new_state


def _gates(params, x: jnp.ndarray):
    """fp32 decay a_t and gated input; x (B,S,C) in compute dtype."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ params["w_a"].astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(x32 @ params["w_x"].astype(jnp.float32)
                       + params["b_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) via expm1 for numerical accuracy near a = 1.  The clamp
    # keeps sqrt away from 0 where its gradient is inf: a == 1 exactly
    # (sigmoid underflow in r) means "pure memory, no input" — a zero
    # gradient there is the correct limit, not NaN.
    beta = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    gated = beta * (i * x32)
    return a, gated


def rglru_scan(params, x: jnp.ndarray) -> jnp.ndarray:
    """Training-mode RG-LRU over (B,S,C) via parallel associative scan."""
    a, gated = _gates(params, x)                       # fp32 (B,S,C)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype)


def rglru_step(params, h: jnp.ndarray, x: jnp.ndarray):
    """Decode: one step. h (B,C) fp32 carried state; x (B,1,C)."""
    a, gated = _gates(params, x)                       # (B,1,C)
    h_new = a[:, 0] * h + gated[:, 0]
    return h_new.astype(jnp.float32), h_new.astype(x.dtype)[:, None]


def rglru_block_apply(params, x: jnp.ndarray, *, conv_width: int = 4,
                      state: dict | None = None, ):
    """Full Griffin recurrent block.  x (B,S,d_model) -> same shape.

    ``state``: None for training; dict(h=(B,C) fp32, conv=(B,W-1,C)) for
    decode — returns (y, new_state) in that case.
    """
    dtype = x.dtype
    u = x @ params["w_in_x"].astype(dtype)             # (B,S,C) recurrent branch
    g = x @ params["w_in_gate"].astype(dtype)          # gate branch
    u = shard(u, ("batch", "seq", "rnn"))
    if state is None:
        u, _ = _causal_conv(u, params["conv_w"], params["conv_b"])
        h = rglru_scan(params, u)
        y = h * jax.nn.gelu(g)
        out = y @ params["w_out"].astype(dtype)
        return shard(out, ("batch", "seq", "embed"))
    u, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"],
                                 state["conv"])
    h_new, h_out = rglru_step(params, state["h"], u)
    y = h_out * jax.nn.gelu(g)
    out = y @ params["w_out"].astype(dtype)
    return out, {"h": h_new, "conv": conv_state}


def rglru_serve_chunk(params, x, state, valid, *, conv_width: int = 4):
    """Chunked-prefill / ragged-decode serve entry point.

    x (B,C,d_model); state dict(h=(B,d_rnn) fp32, conv=(B,W-1,d_rnn));
    valid (B,) int32 — leading real positions per row.  Returns
    (y (B,C,d_model), new_state).

    A sequential per-position ``lax.scan`` executing exactly the
    decode-branch ops (projections batched — row-wise identical matmuls;
    conv + ``rglru_step`` sequential) so greedy serving is token-identical
    to per-token ``decode()``.  Padded positions (>= valid) are exact
    state no-ops via masked selects; their outputs are never gathered.
    """
    dtype = x.dtype
    b, c, _ = x.shape
    u = x @ params["w_in_x"].astype(dtype)
    g = x @ params["w_in_gate"].astype(dtype)
    u = shard(u, ("batch", "seq", "rnn"))
    w, wb = params["conv_w"], params["conv_b"]

    def step(carry, inp):
        conv, h = carry
        ut, ok = inp
        hist = jnp.concatenate([conv, ut[:, None]], axis=1)
        y = sum(hist[:, i] * w[i].astype(ut.dtype) for i in range(w.shape[0]))
        y = y + wb.astype(ut.dtype)
        h_new, h_out = rglru_step(params, h, y[:, None])
        conv = jnp.where(ok[:, None, None], hist[:, 1:], conv)
        h = jnp.where(ok[:, None], h_new, h)
        return (conv, h), h_out[:, 0]

    ok = jnp.arange(c)[:, None] < valid[None, :]             # (C, B)
    (conv, h), ys = jax.lax.scan(step, (state["conv"], state["h"]),
                                 (u.transpose(1, 0, 2), ok))
    y = ys.transpose(1, 0, 2) * jax.nn.gelu(g)
    out = y @ params["w_out"].astype(dtype)
    return shard(out, ("batch", "seq", "embed")), {"h": h, "conv": conv}


def rglru_state_spec(batch: int, d_rnn: int, conv_width: int, dtype):
    return {
        "h": jax.ShapeDtypeStruct((batch, d_rnn), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, conv_width - 1, d_rnn), dtype),
    }
