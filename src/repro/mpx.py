"""Paper-style alias: ``import repro.mpx as mpx`` (or ``from repro import mpx``).

Everything in :mod:`repro.core`, re-exported under the name used throughout
the MPX paper's listings.
"""
from repro.core import *  # noqa: F401,F403
from repro.core import __all__  # noqa: F401
