"""Checkpointing: atomic, async-capable, elastic (mesh-independent) restore.

Layout of one checkpoint::

    <dir>/step_000123/
        manifest.json        # treedef, shapes, dtypes, step, extra metadata
        leaf_00000.npy ...   # one file per array leaf (np.save format)
    <dir>/LATEST             # text file: "step_000123" (atomic rename)

Design points for fleet-scale operation:

- **Atomicity**: written to ``step_N.tmp`` then ``os.rename``d; the LATEST
  pointer is only updated after the rename, so a preemption mid-write can
  never corrupt the restore path.
- **Elasticity**: leaves are stored *unsharded* (fully replicated values are
  gathered by ``np.asarray``); restore ``device_put``s onto whatever
  sharding tree the *new* job derives from its own mesh — a 512-chip
  checkpoint restores onto 256 chips (or 1 CPU) unchanged.  This is the
  restart-based elastic-scaling story in DESIGN.md §5.
- **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes files on a background thread, overlapping the next train steps.
- **GC**: ``keep_n`` newest checkpoints survive.

Scaling note (documented limitation): at true multi-pod scale one would
write per-host shard files (à la Orbax/TensorStore); the manifest format
here has a ``shards`` field reserved for that extension.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _leaves_and_treedef(tree):
    return jax.tree.flatten(tree)


class Checkpointer:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: PyTree, extra: Optional[dict] = None):
        """Synchronous atomic save."""
        self.wait()
        self._write(step, *self._snapshot(tree), extra or {})

    def save_async(self, step: int, tree: PyTree,
                   extra: Optional[dict] = None):
        """Snapshot now (host copy), write in the background."""
        self.wait()
        leaves, treedef = self._snapshot(tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, leaves, treedef, extra or {}),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, tree):
        leaves, treedef = _leaves_and_treedef(tree)
        host = [np.asarray(leaf) for leaf in leaves]   # gathers if sharded
        return host, treedef

    def _write(self, step: int, leaves, treedef, extra: dict):
        name = f"step_{step:09d}"
        tmp = self.dir / (name + ".tmp")
        final = self.dir / name
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i, leaf in enumerate(leaves):
            np.save(tmp / f"leaf_{i:05d}.npy", leaf)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "shapes": [list(np.shape(x)) for x in leaves],
            "dtypes": [str(np.asarray(x).dtype) for x in leaves],
            "shards": None,     # reserved: per-host shard files at pod scale
            "extra": extra,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        # atomic LATEST update
        latest_tmp = self.dir / "LATEST.tmp"
        latest_tmp.write_text(name)
        os.rename(latest_tmp, self.dir / "LATEST")
        self._gc()

    def _gc(self):
        ckpts = sorted(p for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for stale in ckpts[:-self.keep_n] if self.keep_n > 0 else []:
            shutil.rmtree(stale)

    # ------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        latest = self.dir / "LATEST"
        if not latest.exists():
            return None
        return int(latest.read_text().strip().split("_")[1])

    def restore(self, abstract_tree: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> tuple[PyTree, dict]:
        """Restore onto the structure of ``abstract_tree``.

        ``shardings`` (optional NamedSharding tree) re-shards every leaf for
        the *current* mesh — the elastic-restart path.  Returns
        ``(tree, extra_metadata)``.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        path = self.dir / f"step_{step:09d}"
        manifest = json.loads((path / "manifest.json").read_text())
        _, treedef = _leaves_and_treedef(abstract_tree)
        if manifest["n_leaves"] != treedef.num_leaves:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"state needs {treedef.num_leaves} — architecture mismatch")
        leaves = [np.load(path / f"leaf_{i:05d}.npy")
                  for i in range(manifest["n_leaves"])]
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))
            leaves = [jax.device_put(leaf, sh)
                      for leaf, sh in zip(leaves, sh_leaves)]
        else:
            leaves = [jax.device_put(leaf) for leaf in leaves]
        return jax.tree.unflatten(treedef, leaves), manifest.get("extra", {})
