"""Token sampling from bf16 logits, computed in fp32 (mpx policy).

The model's head emits logits in the compute dtype (bf16 on the serving
path).  Sampling is one of the paper's "known-fragile spots": softmax over
a 100k-entry vocabulary in bf16 loses the tail, and temperature/top-p
renormalization compounds it.  Every transform here upcasts once to fp32
and stays there; only the sampled token ids (and, for speculative
verification, accepted-prefix counts) leave.

``SamplingParams`` is static configuration — ``make_sampler`` closes over
it so the jitted step specializes (greedy compiles to a bare argmax with
no PRNG traffic).  Samplers return *probabilities alongside ids*: the
speculative-decoding verify step needs the full post-transform
distribution, not just its sample, to run the Leviathan accept/residual
rule (:func:`rejection_sample`) in fp32 over the bf16 window logits.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Static sampling configuration.

    temperature 0 means greedy (argmax); top_k 0 and top_p 1.0 disable the
    respective truncations.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0: {self.top_k}")

    @property
    def is_greedy(self) -> bool:
        return self.temperature == 0.0


def _apply_top_k(logits: jnp.ndarray, k: int) -> jnp.ndarray:
    kth = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits < kth, NEG_INF, logits)


def _apply_top_p(logits: jnp.ndarray, p: float) -> jnp.ndarray:
    """Nucleus filter via a per-row threshold, no full-vocab scatter.

    The sorted pass computes, per row, the *smallest surviving logit*
    (every token whose preceding cumulative mass is < p survives — the
    top token always does, even when its own probability exceeds p); the
    filter is then a ``jnp.where`` against that threshold on the original
    layout.  Equivalent to scattering the filtered sorted logits back
    through ``sorted_idx``, without materializing a second (..., V)
    scatter buffer — except at exact ties with the threshold logit, where
    ALL tied tokens survive.  Ties are real on the serving path (bf16
    head logits quantize many tail tokens to equal values even after the
    fp32 upcast), so this is a deliberate semantic choice, not a corner
    case: the kept nucleus is a deterministic, token-order-independent
    superset of the scatter formulation's, which broke ties by sort
    position — an ordering just as arbitrary with respect to p, since
    the boundary token already overshoots the target mass by definition.
    """
    vocab = logits.shape[-1]
    sorted_l = jax.lax.top_k(logits, vocab)[0]
    probs = jax.nn.softmax(sorted_l, axis=-1)
    cum_before = jnp.cumsum(probs, axis=-1) - probs
    thresh = jnp.min(jnp.where(cum_before < p, sorted_l, jnp.inf),
                     axis=-1, keepdims=True)
    return jnp.where(logits >= thresh, logits, NEG_INF)


def transform_logits(logits: jnp.ndarray, sp: SamplingParams) -> jnp.ndarray:
    """(..., V) any float -> fp32 logits with temperature/top-k/top-p
    applied.  Greedy (temperature 0) is the caller's argmax fast path —
    this function requires temperature > 0."""
    if sp.is_greedy:
        raise ValueError("transform_logits needs temperature > 0; "
                         "greedy sampling is a bare argmax")
    l32 = logits.astype(jnp.float32) / sp.temperature
    if sp.top_k > 0 and sp.top_k < logits.shape[-1]:
        l32 = _apply_top_k(l32, sp.top_k)
    if sp.top_p < 1.0:
        l32 = _apply_top_p(l32, sp.top_p)
    return l32


def probs_from_logits(logits: jnp.ndarray, sp: SamplingParams
                      ) -> jnp.ndarray:
    """(..., V) -> fp32 post-transform probabilities.

    Greedy collapses to a one-hot at the fp32 argmax — the degenerate
    distribution the rejection-sampling accept rule needs so that
    temperature=0 speculative decoding is exactly greedy decoding.
    """
    l32 = logits.astype(jnp.float32)
    if sp.is_greedy:
        return jax.nn.one_hot(jnp.argmax(l32, axis=-1), l32.shape[-1],
                              dtype=jnp.float32)
    return jax.nn.softmax(transform_logits(l32, sp), axis=-1)


def sample_logits(logits: jnp.ndarray, key, sp: SamplingParams,
                  ) -> jnp.ndarray:
    """logits (B, V) any float dtype -> token ids (B,) int32, fp32 inside."""
    l32 = logits.astype(jnp.float32)
    if sp.is_greedy:
        return jnp.argmax(l32, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, transform_logits(l32, sp),
                                  axis=-1).astype(jnp.int32)


def make_sampler(sp: SamplingParams):
    """Returns a jittable ``sampler(logits (B, V), key) -> (ids, probs)``.

    ``ids`` is (B,) int32; ``probs`` is the (B, V) fp32 post-transform
    distribution the ids were drawn from (one-hot for greedy).  Samplers
    expose the distribution, not just its sample, because speculative
    verification is distribution-level (accept/residual needs target
    mass, see :func:`rejection_sample`); callers that only decode ignore
    the second element, and under jit the unused softmax is dead-code
    eliminated.
    """

    def sampler(logits, key):
        return sample_logits(logits, key, sp), probs_from_logits(logits, sp)

    return sampler


# --------------------------------------------------------------------------
# speculative decoding: fp32 rejection sampling over window logits
# --------------------------------------------------------------------------

def rejection_sample(logits: jnp.ndarray, draft: jnp.ndarray,
                     draft_len: jnp.ndarray, key, sp: SamplingParams,
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Leviathan-style accept/residual verification, fp32 over bf16 logits.

    ``logits`` (B, W, V): row ``j`` is the target model's distribution for
    the token *after* window position ``j`` (window position 0 is the
    slot's committed token, positions 1..k its proposed draft).  ``draft``
    (B, W-1) int32: proposed token ``j`` was fed at window position
    ``j + 1``, so it is verified against row ``j``.  ``draft_len`` (B,)
    is each slot's live draft count (0 = no speculation: plain sampling
    from row 0, which is how prefill slots and non-speculative decode
    flow through the same jitted step).

    Returns ``(accept (B,) int32, token (B,) int32)``: the accepted draft
    prefix length and the one extra sampled token — a residual-corrected
    token when a draft was rejected, a bonus token from the row after the
    last draft when everything was accepted.  Either way each slot emits
    ``accept + 1`` tokens per step.

    The proposer is deterministic (a host-side n-gram lookup), i.e. the
    draft distribution q is a one-hot, so the accept rule
    ``u < min(1, p(d)/q(d))`` reduces to ``u < p(d)`` and the residual
    ``normalize(max(p - q, 0))`` to p with the rejected token zeroed.
    With temperature 0 the target p is itself a one-hot at the argmax
    (see :func:`probs_from_logits`), so acceptance is exact argmax
    equality and the corrected token is the argmax — token-identical to
    non-speculative greedy decoding, the property the engine tests pin.
    """
    b, w, _ = logits.shape
    kmax = w - 1
    l32 = logits.astype(jnp.float32)
    jj = jnp.arange(kmax)[None, :]
    live = jj < draft_len[:, None]                           # (B, kmax)

    if sp.is_greedy:
        am = jnp.argmax(l32, axis=-1).astype(jnp.int32)      # (B, W)
        ok = (draft == am[:, :kmax]) & live
        accept = jnp.cumprod(ok.astype(jnp.int32), axis=-1).sum(axis=-1)
        token = jnp.take_along_axis(am, accept[:, None], axis=1)[:, 0]
        return accept.astype(jnp.int32), token

    probs = probs_from_logits(l32, sp)                       # (B, W, V) fp32
    if kmax > 0:
        p_draft = jnp.take_along_axis(probs[:, :kmax], draft[..., None],
                                      axis=-1)[..., 0]       # (B, kmax)
        key, ku = jax.random.split(key)
        u = jax.random.uniform(ku, (b, kmax))
        ok = (u < p_draft) & live
        accept = jnp.cumprod(ok.astype(jnp.int32), axis=-1).sum(axis=-1)
    else:
        accept = jnp.zeros((b,), jnp.int32)
    row = jnp.take_along_axis(probs, accept[:, None, None], axis=1)[:, 0]
    if kmax > 0:
        # residual on rejection: zero the rejected draft token's mass
        # (q is one-hot, so max(p - q, 0) is p with that entry removed);
        # categorical renormalizes, and rejection implies p(d) < 1 so the
        # residual always has mass
        rejected = accept < draft_len
        d_rej = jnp.take_along_axis(
            draft, jnp.minimum(accept, kmax - 1)[:, None], axis=1)[:, 0]
        hot = jax.nn.one_hot(d_rej, row.shape[-1], dtype=row.dtype)
        row = jnp.where(rejected[:, None], row * (1.0 - hot), row)
    token = jax.random.categorical(key, jnp.log(jnp.maximum(row, 1e-30)),
                                   axis=-1)
    return accept.astype(jnp.int32), token.astype(jnp.int32)


def guard_nonfinite(logits: jnp.ndarray, accept: jnp.ndarray,
                    token: jnp.ndarray
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Nonfinite-logit guard over the (B, W, V) window logits.

    A NaN/Inf in a slot's window — a quantized-path overflow, a poisoned
    weight — would otherwise be silently argmax'd into the token stream
    (``jnp.argmax`` over an all-NaN row returns 0: a plausible-looking
    token id).  The MPX discipline is that half-precision failure modes
    are *detected*, not assumed away: this masks any slot whose window
    contains a nonfinite value to ``accept = 0`` and ``token = -1``, the
    host-side failure sentinel — real token ids are nonnegative, so the
    verdict rides the two ``(B,)`` arrays the engine step already
    transfers.  Detection costs one elementwise ``isfinite`` reduce on
    device and **zero added host syncs** (the tests/test_obs.py
    transfer-count pin holds with the guard compiled in).
    """
    bad = jnp.any(~jnp.isfinite(logits.astype(jnp.float32)), axis=(1, 2))
    return (jnp.where(bad, 0, accept).astype(accept.dtype),
            jnp.where(bad, -1, token).astype(token.dtype))


def make_verifier(sp: SamplingParams):
    """Returns a jittable ``verify(logits (B, W, V), draft (B, W-1),
    draft_len (B,), key) -> (accept (B,), token (B,))`` closure over the
    static sampling configuration — the device half of the speculative
    propose/verify/commit loop."""

    def verify(logits, draft, draft_len, key):
        return rejection_sample(logits, draft, draft_len, key, sp)

    return verify
