"""Token sampling from bf16 logits, computed in fp32 (mpx policy).

The model's head emits logits in the compute dtype (bf16 on the serving
path).  Sampling is one of the paper's "known-fragile spots": softmax over
a 100k-entry vocabulary in bf16 loses the tail, and temperature/top-p
renormalization compounds it.  Every transform here upcasts once to fp32
and stays there; only the sampled token ids leave.

``SamplingParams`` is static configuration — ``make_sampler`` closes over
it so the jitted step specializes (greedy compiles to a bare argmax with
no PRNG traffic).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Static sampling configuration.

    temperature 0 means greedy (argmax); top_k 0 and top_p 1.0 disable the
    respective truncations.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0: {self.top_k}")

    @property
    def is_greedy(self) -> bool:
        return self.temperature == 0.0


def _apply_top_k(logits: jnp.ndarray, k: int) -> jnp.ndarray:
    kth = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits < kth, NEG_INF, logits)


def _apply_top_p(logits: jnp.ndarray, p: float) -> jnp.ndarray:
    vocab = logits.shape[-1]
    sorted_l, sorted_idx = jax.lax.top_k(logits, vocab)
    probs = jax.nn.softmax(sorted_l, axis=-1)
    # keep every token whose preceding cumulative mass is < p (the first
    # token always survives, even when its own probability exceeds p)
    cum_before = jnp.cumsum(probs, axis=-1) - probs
    sorted_l = jnp.where(cum_before < p, sorted_l, NEG_INF)
    out = jnp.full_like(logits, NEG_INF)
    batch = jnp.arange(logits.shape[0])[:, None]
    return out.at[batch, sorted_idx].set(sorted_l)


def sample_logits(logits: jnp.ndarray, key, sp: SamplingParams,
                  ) -> jnp.ndarray:
    """logits (B, V) any float dtype -> token ids (B,) int32, fp32 inside."""
    l32 = logits.astype(jnp.float32)
    if sp.is_greedy:
        return jnp.argmax(l32, axis=-1).astype(jnp.int32)
    l32 = l32 / sp.temperature
    if sp.top_k > 0 and sp.top_k < logits.shape[-1]:
        l32 = _apply_top_k(l32, sp.top_k)
    if sp.top_p < 1.0:
        l32 = _apply_top_p(l32, sp.top_p)
    return jax.random.categorical(key, l32, axis=-1).astype(jnp.int32)


def make_sampler(sp: SamplingParams):
    """Returns a jittable ``sampler(logits (B, V), key) -> (B,) int32``."""

    def sampler(logits, key):
        return sample_logits(logits, key, sp)

    return sampler
