"""Serving metrics: per-request TTFT / tok-s, aggregate throughput, ITL,
speculative acceptance.

Host-side plain Python — recorded around the jitted steps, never inside
them.  ``EngineStats`` aggregates per-step records (occupancy, tokens,
wall time, per-slot prefill/decode token counts, proposed/accepted draft
counts) and per-request records (time-to-first-token, decode rate,
inter-token gaps, acceptance rate) into the summary the benchmarks and
the example client print.  The p50/p95 **inter-token latency** (gap
between consecutive emitted tokens of one request) is the metric that
makes scheduler stalls visible: under prefill-priority scheduling a
decode slot's gap spans every step of another slot's prompt; under
mixed-chunk scheduling it spans exactly one step.  With speculative
decoding a window's tokens arrive together, so one gap is recorded per
request per step and **tokens per step** becomes the headline speculation
metric: how many engine steps each generated token costs, the quantity
the accept rate buys down.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional


@dataclasses.dataclass
class RequestMetrics:
    """Lifecycle timestamps for one request (``time.perf_counter`` values)."""
    request_id: int
    prompt_len: int
    submit_time: float
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    new_tokens: int = 0
    proposed_tokens: int = 0    # speculative drafts the verifier saw
    accepted_tokens: int = 0    # drafts the verifier accepted

    @property
    def acceptance_rate(self) -> Optional[float]:
        """Accepted / proposed draft tokens (None when never speculated)."""
        if self.proposed_tokens == 0:
            return None
        return self.accepted_tokens / self.proposed_tokens

    @property
    def ttft(self) -> Optional[float]:
        """Seconds from submit to first sampled token (prefill latency)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def decode_tok_per_s(self) -> Optional[float]:
        """Generation rate after the first token."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        if self.new_tokens <= 1:
            return None
        dt = self.finish_time - self.first_token_time
        return (self.new_tokens - 1) / max(dt, 1e-9)


def _percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile: smallest value covering >= q of the mass."""
    vals = sorted(values)
    idx = math.ceil(q * len(vals)) - 1
    return vals[max(0, min(idx, len(vals) - 1))]


class EngineStats:
    """Aggregate counters the engine updates once per step / per finish."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.steps = 0
        self.prefill_steps = 0
        self.decode_steps = 0
        self.mixed_steps = 0
        self.total_new_tokens = 0
        self.total_prompt_tokens = 0
        self.elapsed = 0.0
        self._occupancy_sum = 0.0
        # per-slot token accounting: how many prompt tokens each slot fed
        # and how many decode tokens it stepped (batch-balance diagnostics)
        self.slot_prefill_tokens: List[int] = [0] * n_slots
        self.slot_decode_tokens: List[int] = [0] * n_slots
        # speculation: drafts offered to / accepted by the verify step
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.itl_gaps: List[float] = []     # inter-token gaps, all requests
        self.finished: List[RequestMetrics] = []

    def record_step(self, kind: str, busy_slots: int, new_tokens: int,
                    dt: float, prefill_tokens=None, decode_tokens=None,
                    proposed: int = 0, accepted: int = 0) -> None:
        """``kind`` is "prefill" / "decode" / "mixed"; the optional
        ``prefill_tokens`` / ``decode_tokens`` are per-slot (B,) counts of
        real tokens this step (a decode slot's count includes its
        speculative window); ``proposed`` / ``accepted`` are the step's
        draft-token totals."""
        self.steps += 1
        if kind == "prefill":
            self.prefill_steps += 1
        elif kind == "decode":
            self.decode_steps += 1
        else:
            self.mixed_steps += 1
        self.total_new_tokens += new_tokens
        self.elapsed += dt
        self._occupancy_sum += busy_slots / self.n_slots
        if prefill_tokens is not None:
            for b, n in enumerate(prefill_tokens):
                self.slot_prefill_tokens[b] += int(n)
        if decode_tokens is not None:
            for b, n in enumerate(decode_tokens):
                self.slot_decode_tokens[b] += int(n)
        self.spec_proposed += proposed
        self.spec_accepted += accepted

    def record_token_gap(self, gap: float) -> None:
        """One inter-token gap (seconds between consecutive tokens of a
        request, first token excluded — that interval is the TTFT)."""
        self.itl_gaps.append(gap)

    def record_finish(self, rm: RequestMetrics) -> None:
        self.finished.append(rm)
        self.total_prompt_tokens += rm.prompt_len

    @property
    def mean_occupancy(self) -> float:
        return self._occupancy_sum / self.steps if self.steps else 0.0

    @property
    def throughput_tok_per_s(self) -> float:
        return self.total_new_tokens / max(self.elapsed, 1e-9)

    @property
    def tokens_per_step(self) -> float:
        """Generated tokens per engine step — the speculation payoff."""
        return self.total_new_tokens / self.steps if self.steps else 0.0

    @property
    def spec_accept_rate(self) -> float:
        """Accepted / proposed drafts over the engine lifetime (0 when the
        engine never speculated)."""
        if self.spec_proposed == 0:
            return 0.0
        return self.spec_accepted / self.spec_proposed

    def summary(self) -> Dict[str, float]:
        ttfts = [rm.ttft for rm in self.finished if rm.ttft is not None]
        out = {
            "requests": float(len(self.finished)),
            "steps": float(self.steps),
            "prefill_steps": float(self.prefill_steps),
            "decode_steps": float(self.decode_steps),
            "mixed_steps": float(self.mixed_steps),
            "new_tokens": float(self.total_new_tokens),
            "prompt_tokens": float(self.total_prompt_tokens),
            "prefill_tokens_fed": float(sum(self.slot_prefill_tokens)),
            "decode_tokens_fed": float(sum(self.slot_decode_tokens)),
            "elapsed_s": self.elapsed,
            "tok_per_s": self.throughput_tok_per_s,
            "tokens_per_step": self.tokens_per_step,
            "mean_occupancy": self.mean_occupancy,
            "spec_proposed": float(self.spec_proposed),
            "spec_accepted": float(self.spec_accepted),
            "spec_accept_rate": self.spec_accept_rate,
        }
        if ttfts:
            out["ttft_mean_s"] = sum(ttfts) / len(ttfts)
            out["ttft_p95_s"] = _percentile(ttfts, 0.95)
        if self.itl_gaps:
            out["itl_p50_s"] = _percentile(self.itl_gaps, 0.50)
            out["itl_p95_s"] = _percentile(self.itl_gaps, 0.95)
            out["itl_mean_s"] = sum(self.itl_gaps) / len(self.itl_gaps)
        return out
