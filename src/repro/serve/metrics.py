"""Serving metrics: per-request TTFT / tok-s, aggregate throughput, ITL,
speculative acceptance — registry-backed.

Host-side plain Python — recorded around the jitted steps, never inside
them.  ``EngineStats`` aggregates per-step records (occupancy, tokens,
wall time, per-slot prefill/decode token counts, proposed/accepted draft
counts) and per-request records (time-to-first-token, decode rate,
inter-token gaps, acceptance rate) into the summary the benchmarks and
the example client print.  The p50/p95 **inter-token latency** (gap
between consecutive emitted tokens of one request) is the metric that
makes scheduler stalls visible: under prefill-priority scheduling a
decode slot's gap spans every step of another slot's prompt; under
mixed-chunk scheduling it spans exactly one step.  With speculative
decoding a window's tokens arrive together, so one gap is recorded per
request per step and **tokens per step** becomes the headline speculation
metric: how many engine steps each generated token costs, the quantity
the accept rate buys down.

Since the ``repro.obs`` refactor the counters live in an
:class:`repro.obs.Registry` (``serve_steps_total{kind=}``,
``serve_new_tokens_total``, ``serve_slot_tokens_total{slot=,kind=}``,
``serve_spec_tokens_total{which=}``, an ITL histogram, ...), so the same
numbers export as Prometheus text or a JSON snapshot alongside the
engine-level gauges.  The ``summary()`` dict keys are **pinned**
(tests/test_obs.py) — they predate the registry and the bench/CI
artifact schema keys on them; exact percentiles still come from the raw
gap list (the histogram is the export view, log2 buckets).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.obs.registry import Registry


@dataclasses.dataclass
class RequestMetrics:
    """Lifecycle timestamps for one request (``time.perf_counter`` values)."""
    request_id: int
    prompt_len: int
    submit_time: float
    admit_time: Optional[float] = None   # first admission into a slot
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    preempted_seconds: float = 0.0  # total time evicted awaiting re-admission
    last_evict_time: Optional[float] = None  # set while preempted-and-waiting
    new_tokens: int = 0
    proposed_tokens: int = 0    # speculative drafts the verifier saw
    accepted_tokens: int = 0    # drafts the verifier accepted
    preemptions: int = 0        # times evicted + recomputed mid-flight
    cached_prefix_tokens: int = 0  # prefill tokens absorbed by shared pages
    error: Optional[str] = None  # why status == "failed", else None

    @property
    def acceptance_rate(self) -> Optional[float]:
        """Accepted / proposed draft tokens (None when never speculated)."""
        if self.proposed_tokens == 0:
            return None
        return self.accepted_tokens / self.proposed_tokens

    @property
    def ttft(self) -> Optional[float]:
        """Seconds from submit to first sampled token (prefill latency)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def queue_wait(self) -> Optional[float]:
        """Seconds from submit to *first* admission into a slot — the
        phase TTFT hides: time spent waiting behind the bounded queue."""
        if self.admit_time is None:
            return None
        return self.admit_time - self.submit_time

    @property
    def prefill_seconds(self) -> Optional[float]:
        """Seconds from first admission to first sampled token (chunked
        prefill, including any preempted-recompute time in between)."""
        if self.first_token_time is None or self.admit_time is None:
            return None
        return self.first_token_time - self.admit_time

    @property
    def decode_seconds(self) -> Optional[float]:
        """Seconds from first token to finish (the decode phase)."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        return self.finish_time - self.first_token_time

    @property
    def decode_tok_per_s(self) -> Optional[float]:
        """Generation rate after the first token."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        if self.new_tokens <= 1:
            return None
        dt = self.finish_time - self.first_token_time
        return (self.new_tokens - 1) / max(dt, 1e-9)


def _percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile: smallest value covering >= q of the mass."""
    vals = sorted(values)
    idx = math.ceil(q * len(vals)) - 1
    return vals[max(0, min(idx, len(vals) - 1))]


class EngineStats:
    """Aggregate counters the engine updates once per step / per finish.

    Backed by a :class:`repro.obs.Registry` (fresh per instance unless
    one is passed — resetting ``engine.stats`` must zero the counters):
    every historical attribute (``steps``, ``prefill_steps``,
    ``total_new_tokens``, ``slot_decode_tokens``, ...) is a view over
    registry series, so ``stats.registry.prometheus()`` exports the same
    numbers ``summary()`` reports.
    """

    def __init__(self, n_slots: int, registry: Optional[Registry] = None):
        self.n_slots = n_slots
        self.registry = registry if registry is not None else Registry()
        r = self.registry
        self._steps = r.counter(
            "serve_steps_total", "engine ticks by plan kind",
            labels=("kind",))
        self._new_tokens = r.counter(
            "serve_new_tokens_total", "generated tokens committed")
        self._prompt_tokens = r.counter(
            "serve_prompt_tokens_total", "prompt tokens of finished requests")
        self._elapsed = r.counter(
            "serve_elapsed_seconds_total",
            "wall seconds across engine ticks (admit through commit)")
        self._slot_tokens = r.counter(
            "serve_slot_tokens_total",
            "real tokens fed per slot, by phase", labels=("slot", "kind"))
        self._spec = r.counter(
            "serve_spec_tokens_total",
            "speculative draft tokens offered to / accepted by the verifier",
            labels=("which",))
        self._requests = r.counter(
            "serve_requests_finished_total", "requests retired")
        self._occupancy = r.gauge(
            "serve_occupancy", "busy slots / n_slots, last tick")
        self._itl_hist = r.histogram(
            "serve_itl_seconds", "inter-token gap (log2 buckets)",
            lo_exp=-14, hi_exp=4)
        self._ttft_hist = r.histogram(
            "serve_ttft_seconds", "submit-to-first-token (log2 buckets)",
            lo_exp=-14, hi_exp=4)
        # time-in-phase histograms: TTFT = queue wait + prefill, then
        # decode until finish — queue wait is the phase a saturated
        # engine hides inside TTFT (the postmortem CLI reads the same
        # numbers per request from the flight-recorder journal)
        self._queue_wait_hist = r.histogram(
            "serve_queue_wait_seconds",
            "submit-to-first-admission queue wait (log2 buckets)",
            lo_exp=-14, hi_exp=4)
        self._prefill_hist = r.histogram(
            "serve_prefill_seconds",
            "first-admission-to-first-token prefill time (log2 buckets)",
            lo_exp=-14, hi_exp=4)
        self._decode_hist = r.histogram(
            "serve_decode_seconds",
            "first-token-to-finish decode time (log2 buckets)",
            lo_exp=-14, hi_exp=4)
        self._occupancy_sum = 0.0
        self.itl_gaps: List[float] = []     # raw gaps: exact percentiles
        self.finished: List[RequestMetrics] = []

    # -- registry-backed attribute views ------------------------------------

    @property
    def steps(self) -> int:
        return int(self._steps.total)

    @property
    def prefill_steps(self) -> int:
        return int(self._steps.value(kind="prefill"))

    @property
    def decode_steps(self) -> int:
        return int(self._steps.value(kind="decode"))

    @property
    def mixed_steps(self) -> int:
        return int(self._steps.value(kind="mixed"))

    @property
    def total_new_tokens(self) -> int:
        return int(self._new_tokens.total)

    @property
    def total_prompt_tokens(self) -> int:
        return int(self._prompt_tokens.total)

    @property
    def elapsed(self) -> float:
        return self._elapsed.total

    @property
    def slot_prefill_tokens(self) -> List[int]:
        return [int(self._slot_tokens.value(slot=str(b), kind="prefill"))
                for b in range(self.n_slots)]

    @property
    def slot_decode_tokens(self) -> List[int]:
        return [int(self._slot_tokens.value(slot=str(b), kind="decode"))
                for b in range(self.n_slots)]

    @property
    def spec_proposed(self) -> int:
        return int(self._spec.value(which="proposed"))

    @property
    def spec_accepted(self) -> int:
        return int(self._spec.value(which="accepted"))

    # -- recording ----------------------------------------------------------

    def record_step(self, kind: str, busy_slots: int, new_tokens: int,
                    dt: float, prefill_tokens=None, decode_tokens=None,
                    proposed: int = 0, accepted: int = 0) -> None:
        """``kind`` is "prefill" / "decode" / "mixed"; the optional
        ``prefill_tokens`` / ``decode_tokens`` are per-slot (B,) counts of
        real tokens this step (a decode slot's count includes its
        speculative window); ``proposed`` / ``accepted`` are the step's
        draft-token totals."""
        self._steps.inc(kind=kind)
        self._new_tokens.inc(new_tokens)
        self._elapsed.inc(dt)
        occ = busy_slots / self.n_slots
        self._occupancy_sum += occ
        self._occupancy.set(occ)
        if prefill_tokens is not None:
            for b, n in enumerate(prefill_tokens):
                if n:
                    self._slot_tokens.inc(int(n), slot=str(b),
                                          kind="prefill")
        if decode_tokens is not None:
            for b, n in enumerate(decode_tokens):
                if n:
                    self._slot_tokens.inc(int(n), slot=str(b),
                                          kind="decode")
        if proposed:
            self._spec.inc(proposed, which="proposed")
        if accepted:
            self._spec.inc(accepted, which="accepted")

    def record_token_gap(self, gap: float) -> None:
        """One inter-token gap (seconds between consecutive tokens of a
        request, first token excluded — that interval is the TTFT)."""
        self.itl_gaps.append(gap)
        self._itl_hist.observe(gap)

    def record_finish(self, rm: RequestMetrics) -> None:
        self.finished.append(rm)
        self._requests.inc()
        self._prompt_tokens.inc(rm.prompt_len)
        if rm.ttft is not None:
            self._ttft_hist.observe(rm.ttft)
        if rm.queue_wait is not None:
            self._queue_wait_hist.observe(rm.queue_wait)
        if rm.prefill_seconds is not None:
            self._prefill_hist.observe(rm.prefill_seconds)
        if rm.decode_seconds is not None:
            self._decode_hist.observe(rm.decode_seconds)

    # -- derived ------------------------------------------------------------

    @property
    def mean_occupancy(self) -> float:
        return self._occupancy_sum / self.steps if self.steps else 0.0

    @property
    def throughput_tok_per_s(self) -> float:
        return self.total_new_tokens / max(self.elapsed, 1e-9)

    @property
    def tokens_per_step(self) -> float:
        """Generated tokens per engine step — the speculation payoff."""
        return self.total_new_tokens / self.steps if self.steps else 0.0

    @property
    def spec_accept_rate(self) -> float:
        """Accepted / proposed drafts over the engine lifetime (0 when the
        engine never speculated)."""
        if self.spec_proposed == 0:
            return 0.0
        return self.spec_accepted / self.spec_proposed

    def summary(self) -> Dict[str, float]:
        """The pinned summary schema (pre-registry keys, verbatim)."""
        ttfts = [rm.ttft for rm in self.finished if rm.ttft is not None]
        out = {
            "requests": float(len(self.finished)),
            "steps": float(self.steps),
            "prefill_steps": float(self.prefill_steps),
            "decode_steps": float(self.decode_steps),
            "mixed_steps": float(self.mixed_steps),
            "new_tokens": float(self.total_new_tokens),
            "prompt_tokens": float(self.total_prompt_tokens),
            "prefill_tokens_fed": float(sum(self.slot_prefill_tokens)),
            "decode_tokens_fed": float(sum(self.slot_decode_tokens)),
            "elapsed_s": self.elapsed,
            "tok_per_s": self.throughput_tok_per_s,
            "tokens_per_step": self.tokens_per_step,
            "mean_occupancy": self.mean_occupancy,
            "spec_proposed": float(self.spec_proposed),
            "spec_accepted": float(self.spec_accepted),
            "spec_accept_rate": self.spec_accept_rate,
        }
        if ttfts:
            out["ttft_mean_s"] = sum(ttfts) / len(ttfts)
            out["ttft_p95_s"] = _percentile(ttfts, 0.95)
        if self.itl_gaps:
            out["itl_p50_s"] = _percentile(self.itl_gaps, 0.50)
            out["itl_p95_s"] = _percentile(self.itl_gaps, 0.95)
            out["itl_mean_s"] = sum(self.itl_gaps) / len(self.itl_gaps)
        return out
