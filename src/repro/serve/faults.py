"""Seeded, scriptable fault injection at the ServeEngine's seams.

The resilience layer's test harness: a :class:`FaultInjector` scripts
failures against the exact seams the engine exposes, so the chaos suite
(tests/test_serve_faults.py) can *prove* — not assume — that ``drain()``
terminates with correct statuses and intact pool invariants under every
schedule:

- **NaN-poison a slot's logits** (:meth:`FaultInjector.poison_logits`) —
  the engine threads a per-slot ``poison`` mask into its jitted step and
  overwrites the poisoned slot's window logits with NaN *before* the
  verifier, exercising the nonfinite-logit guard exactly the way a
  quantized-path overflow would (MPX §3.3: half-precision failure modes
  are detected and survived, not assumed away);
- **force pool exhaustion** (:meth:`FaultInjector.exhaust_pool`) — holds
  free pages out of the allocator for a scripted tick window
  (:meth:`~repro.serve.cache.PagedKVCache.hold_pages`), the pressure that
  makes admission stall and preemption-and-recompute fire;
- **fail the Nth device step** (:meth:`FaultInjector.fail_device_step`) —
  raises :class:`InjectedFault` in place of the jitted step, exercising
  the tick's fail-the-plan cleanup path (slots retired, pages reclaimed,
  partial output delivered with status ``"failed"``);
- **freeze the clock past a deadline** (:class:`FakeClock` +
  :meth:`FaultInjector.advance_clock`) — the engine accepts an injectable
  clock, so deadline expiry is a scripted event, not a sleep.

Everything is host-side and deterministic: schedules key on the engine
tick index (``begin_tick`` advances it once per ``step()``), fired events
land in :attr:`FaultInjector.log`, and the ``seed`` only feeds the
``rng`` attribute tests may use to build randomized schedules — the
injector itself never draws from it.  ``drain()`` treats an injector
with :attr:`~FaultInjector.pending` scheduled events as forward progress
(the fault that blocks this tick is scripted to lift later), so an
exhaustion window cannot trip the no-progress guard before it closes.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """A scripted device-step failure.  The engine converts it into
    status ``"failed"`` for the slots in flight and keeps serving; any
    *other* exception on the same path gets the identical cleanup
    (no leaked pages, no busy slots) and then propagates."""


class FakeClock:
    """Injectable engine clock: time moves only when the script says so.

    Pass as ``ServeEngine(clock=...)`` (or via
    ``FaultInjector(clock=...)``); ``advance()`` — directly or through a
    scheduled :meth:`FaultInjector.advance_clock` — is the "freeze the
    clock past a deadline" fault: a request's deadline expires at an
    exact tick, with zero wall-time dependence.
    """

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clocks only move forward: advance({dt})")
        self.t += dt


class FaultInjector:
    """Scriptable fault schedules keyed on the engine tick index.

    Script first (``poison_logits`` / ``fail_device_step`` /
    ``exhaust_pool`` / ``advance_clock`` — all chainable), then hand the
    injector to ``ServeEngine(faults=...)``.  The engine drives the
    hooks: ``begin_tick`` once at the top of every ``step()`` (applies
    pool holds/releases and clock advances), ``poison_mask`` when
    building the device batch, ``maybe_fail_step`` just before the
    jitted step.  Fired events append ``(tick, kind, ...)`` tuples to
    :attr:`log`.
    """

    def __init__(self, seed: int = 0, clock: Optional[FakeClock] = None):
        self.rng = np.random.default_rng(seed)
        self._seed = int(seed)              # journaled by schedule()
        self.clock = clock
        self.tick = -1                      # advanced by begin_tick
        self.log: List[Tuple] = []
        self._poison: Dict[int, Optional[int]] = {}   # rid -> tick|None
        self._fail_steps: Set[int] = set()
        self._exhaust: List[dict] = []
        self._advances: Dict[int, float] = {}

    # -- scripting ----------------------------------------------------------

    def poison_logits(self, rid: int,
                      tick: Optional[int] = None) -> "FaultInjector":
        """NaN-poison request ``rid``'s window logits — at ``tick``, or
        (default) at every tick the request is live, which means its
        first device step: the nonfinite guard fails it on detection."""
        self._poison[int(rid)] = tick if tick is None else int(tick)
        return self

    def fail_device_step(self, tick: int) -> "FaultInjector":
        """Raise :class:`InjectedFault` in place of tick ``tick``'s
        device step (fires once)."""
        self._fail_steps.add(int(tick))
        return self

    def exhaust_pool(self, from_tick: int, until_tick: Optional[int] = None,
                     pages: Optional[int] = None) -> "FaultInjector":
        """Hold ``pages`` free pages (all of them when None) out of the
        pool from tick ``from_tick`` until tick ``until_tick`` (forever
        when None — the permanent-wedge schedule)."""
        if until_tick is not None and until_tick <= from_tick:
            raise ValueError(
                f"exhaust window [{from_tick}, {until_tick}) is empty")
        self._exhaust.append({"from": int(from_tick), "until": until_tick,
                              "pages": pages})
        return self

    def advance_clock(self, tick: int, dt: float) -> "FaultInjector":
        """Advance the injected :class:`FakeClock` by ``dt`` seconds at
        the top of tick ``tick`` (requires ``FaultInjector(clock=...)``)."""
        self._advances[int(tick)] = self._advances.get(int(tick), 0.0) + dt
        return self

    def schedule(self) -> dict:
        """JSON-serializable snapshot of the scripted schedule — the
        flight recorder journals it at engine attach, which happens
        before any tick fires (``fail_device_step`` / ``advance_clock``
        entries are consumed as they fire, so capture-then-replay only
        round-trips from the pre-drive state).

        :meth:`from_schedule` inverts it.
        """
        return {"seed": self._seed,
                "poison": {str(r): t for r, t in self._poison.items()},
                "fail_steps": sorted(self._fail_steps),
                "exhaust": [dict(ex) for ex in self._exhaust],
                "advances": {str(t): dt
                             for t, dt in self._advances.items()},
                "has_clock": self.clock is not None}

    @classmethod
    def from_schedule(cls, sched: dict) -> "FaultInjector":
        """Rebuild an injector from :meth:`schedule` — same scripted
        events, fresh tick counter, and (when the original carried one)
        a fresh :class:`FakeClock` so ``advance_clock`` entries have a
        clock to move.  Used by ``replay_journal``: the replayed engine
        reads time from the journal's recorded samples, so this clock
        only absorbs the advances."""
        inj = cls(seed=int(sched.get("seed", 0)),
                  clock=FakeClock() if sched.get("has_clock") else None)
        inj._poison = {int(r): (None if t is None else int(t))
                       for r, t in sched.get("poison", {}).items()}
        inj._fail_steps = {int(t) for t in sched.get("fail_steps", ())}
        inj._exhaust = [
            {"from": int(ex["from"]),
             "until": None if ex["until"] is None else int(ex["until"]),
             "pages": None if ex["pages"] is None else int(ex["pages"])}
            for ex in sched.get("exhaust", ())]
        inj._advances = {int(t): float(dt)
                         for t, dt in sched.get("advances", {}).items()}
        return inj

    @property
    def pending(self) -> bool:
        """True while scheduled events remain that could unblock future
        ticks — ``drain()`` counts this as progress, so a scripted
        exhaustion window doesn't trip the no-progress guard before its
        scheduled release."""
        if self._advances or self._fail_steps:
            return True
        for ex in self._exhaust:
            if ex["from"] > self.tick:
                return True
            if ex["until"] is not None and ex["until"] > self.tick:
                return True
        return False

    # -- engine-facing hooks ------------------------------------------------

    def begin_tick(self, cache) -> None:
        """Advance the tick counter and apply this tick's scheduled pool
        holds/releases and clock advances.  Called once at the top of
        every ``ServeEngine.step()``."""
        self.tick += 1
        dt = self._advances.pop(self.tick, None)
        if dt is not None:
            if self.clock is None:
                raise RuntimeError(
                    "advance_clock schedules need FaultInjector("
                    "clock=FakeClock()) — there is no clock to advance")
            self.clock.advance(dt)
            self.log.append((self.tick, "clock", dt))
        for ex in self._exhaust:
            if ex["from"] == self.tick:
                held = cache.hold_pages(ex["pages"])
                self.log.append((self.tick, "exhaust", held))
            if ex["until"] == self.tick:
                released = cache.release_held()
                self.log.append((self.tick, "release", released))

    def poison_mask(self, slot_rids: Sequence[Optional[int]]) -> np.ndarray:
        """(B,) bool: which slots' logits the jitted step NaN-poisons
        this tick."""
        mask = np.zeros(len(slot_rids), bool)
        for b, rid in enumerate(slot_rids):
            if rid is None or rid not in self._poison:
                continue
            when = self._poison[rid]
            if when is None or when == self.tick:
                mask[b] = True
                self.log.append((self.tick, "poison", rid))
        return mask

    def maybe_fail_step(self) -> None:
        """Raise :class:`InjectedFault` if this tick's device step is
        scheduled to fail."""
        if self.tick in self._fail_steps:
            self._fail_steps.discard(self.tick)
            self.log.append((self.tick, "fail_step"))
            raise InjectedFault(
                f"injected device-step failure at tick {self.tick}")
