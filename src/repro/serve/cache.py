"""Per-layer-kind paged state pool: paged KV for attention layers,
O(1) per-slot state for recurrent layers, one host allocator for both.

Every layer kind gets the state layout its decode math wants:

- **attention** ('attn', 'local_attn') — the paged KV pool.  The
  monolithic ``T.init_cache`` slab commits ``n_slots * max_seq`` of KV
  HBM up front whether slots are busy or not; the paged pool commits
  memory per *admitted request* instead: a shared pool of ``num_pages``
  fixed-size pages per attention layer, and a page table row per slot
  mapping logical page -> physical page.  Token position ``p`` of slot
  ``b`` lives at ``pages[table[b, p // page_size], p % page_size]``.
- **recurrent** ('rglru', 'ssd') — O(1) per-slot decode state (the
  RG-LRU hidden vector + conv buffer, the SSD state accumulator + conv
  buffers), batch row = slot.  No pages, no page-table entries, no
  reservation pressure on the pool: the state neither grows with
  sequence length nor fragments, so the allocator's only job is hygiene
  — **admitting a slot zeroes its recurrent state rows** (a jitted
  donated ``.at[slot].set(0)``, dispatched asynchronously; no host
  sync) so a reused slot can never leak the previous request's state.
  Per the MPX fragile-spot policy the carried states are fp32 (the
  recurrences compound rounding over thousands of steps); conv buffers
  ride the compute dtype.

A pure-recurrent config gets ``num_pages = 0`` — no KV pools exist and
admission never touches the free list.  Hybrid stacks use both halves at
once: attention layers reserve pages, recurrent layers reset their rows,
one ``admit()`` call.

**Storage precision is a policy, not a constant** (``kv_dtype``, a
``repro.quant`` format).  The bf16 passthrough is the PR-1..4 layout:
one ``(num_pages, page_size, K, D)`` bf16 K and V pool per attention
layer.  Quantized formats ("i8", "f8_e4m3", "f8_e3m4") store the pools
at 1 byte/element on the format's value grid and add a
``(num_pages, K)`` fp32 amax-scale *sidecar* per pool — one symmetric
scale per (page, kv-head), ~``page_size * head_dim / 4`` times smaller
than the pool it describes.  The write-quantize / read-dequantize
contract:

- **writes quantize** — ``paged_attend`` routes each chunk's new K/V
  through :func:`repro.quant.ops.quantized_pool_write`, which gathers
  exactly the pages the chunk touches, splices the new values into
  their dequantized image, recomputes each touched page's amax, and
  requantizes that page (untouched pages keep their bits and scales);
- **reads dequantize in the consumer** — the paged-attention kernel
  multiplies the sidecar scales back onto K/V blocks in VMEM before the
  score/output matmuls (the gather fallback dequantizes its dense
  oracle view), so the sub-bf16 pool is the only HBM-resident image of
  the cache and decode's KV read traffic drops with the itemsize.

Bookkeeping (free list, tables, per-slot lengths) is host-side numpy — it
mutates a few ints per request, never touches the device, and stays out of
the jitted step.  Passing a ``repro.obs`` registry makes the allocator
observable at the same zero device cost: ``serve_pages_free`` /
``serve_pages_used`` / ``serve_pages_used_peak`` gauges (the peak is the
pool-sizing signal) and ``serve_truncations_total`` /
``serve_spec_rejected_tokens_total`` counters for speculative tails
discarded by ``truncate()``.  The device side is a pytree of page pools (scale
sidecars riding in the same per-layer dicts, scan-stacked like the
params) built by :func:`repro.models.transformer.init_paged_cache`; all
layers share one table, so admission allocates pages once per sequence.

Allocation policy: the full budget (prompt + max_new tokens) is reserved at
admission, so a running request can never hit pool exhaustion mid-decode —
admission control is the only backpressure point.  Speculative decoding
adds a second, token-granular piece of bookkeeping on top: a step may
*write* KV for a whole proposed window (``note_write``) and then *commit*
only the accepted prefix (``truncate``), leaving the rejected tail as dead
positions beyond the slot's length.  No page churn happens — the pages
were reserved at admission and the dead positions are overwritten by the
next window — but the committed/written watermarks make the invariant
("committed <= written <= reserved capacity, never rolling a committed
prefix back") explicitly checkable.  (Under a quantized ``kv_dtype`` a
dead tail can still nudge a page's amax until it is overwritten — it
costs precision headroom, never correctness, since attention masks by
committed position.)  Recurrent state only moves forward — there is no
watermark to truncate back to — so speculative windows are refused at
engine construction for recurrent/hybrid stacks (see
:class:`~repro.serve.engine.ServeEngine`).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.quant import formats as qfmt

PyTree = Any


class PagedKVCache:
    """Per-layer-kind state pool + host allocator for ``n_slots`` slots.

    Attention layers get device page pools; recurrent layers get
    slot-indexed state rows (reset on admit).  The sentinel physical index
    ``num_pages`` marks unallocated table entries: device-side writes
    through it are dropped, reads are clamped and masked by sequence
    length.  ``kv_dtype`` selects the KV page storage format
    (``repro.quant`` name or :class:`~repro.quant.KVFormat`;
    "bf16" = passthrough, quantized formats add the scale sidecars) —
    recurrent state precision is policy-pinned (fp32 carried state),
    not configurable here.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq: int, *,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 dtype=jnp.bfloat16,
                 kv_dtype: Union[str, qfmt.KVFormat] = "bf16",
                 registry=None):
        if max_seq % page_size:
            raise ValueError(f"max_seq {max_seq} must be a multiple of "
                             f"page_size {page_size}")
        self.cfg = cfg
        self.max_seq = max_seq
        kinds = cfg.layer_kinds()
        self.has_paged = any(k in ("attn", "local_attn") for k in kinds)
        self.has_recurrent = any(k in tfm._RECURRENT_KINDS for k in kinds)
        self.page_size = page_size
        self.max_pages_per_slot = max_seq // page_size
        if not self.has_paged:
            num_pages = 0            # page-free stack: no KV pools at all
        self.num_pages = (num_pages if num_pages is not None
                          else n_slots * self.max_pages_per_slot)
        self.n_slots = n_slots
        self.sentinel = self.num_pages
        self.kv_format = qfmt.resolve(kv_dtype)
        self.pages: PyTree = tfm.init_paged_cache(
            cfg, self.num_pages, page_size, dtype,
            kv_format=self.kv_format.name, n_slots=n_slots)
        # slot admission state: recurrent rows have no pages to witness
        # occupancy, so track it explicitly.  ``_dirty`` marks slots whose
        # recurrent state still holds a retired request's values; admit()
        # must clear it by resetting the rows before reuse
        # (check_invariants catches stale-state leaks).
        self._admitted: List[bool] = [False] * n_slots
        self._reserved: List[int] = [0] * n_slots
        self._dirty: List[bool] = [False] * n_slots
        self._reset_slot_state = None
        if self.has_recurrent:
            mask = tfm.slot_state_mask(cfg, kv_format=self.kv_format.name)

            def raw_reset(pages, slot):
                out = {}
                for key, sub in pages.items():
                    stacked = key == "scan"
                    out[key] = jax.tree.map(
                        lambda a, m, st=stacked: (
                            (a.at[:, slot].set(jnp.zeros((), a.dtype))
                             if st else
                             a.at[slot].set(jnp.zeros((), a.dtype)))
                            if m else a),
                        sub, mask[key])
                return out

            self._reset_slot_state = jax.jit(raw_reset, donate_argnums=(0,))
        self._free: List[int] = list(range(self.num_pages))
        # fault-injection hold (see hold_pages): pages taken out of the
        # free list without an owner.  A third, first-class page state —
        # check_invariants accounts for it, so a scripted exhaustion
        # window can't masquerade as a leak.
        self._held: List[int] = []
        self._tables = np.full((n_slots, self.max_pages_per_slot),
                               self.sentinel, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        # per-slot token watermarks: committed <= written <= capacity.
        # ``written`` is the KV high-water mark (speculative windows write
        # ahead of the committed length); ``committed`` the accepted prefix.
        self._committed: List[int] = [0] * n_slots
        self._written: List[int] = [0] * n_slots
        self._table_device = None        # invalidated on alloc/free
        # telemetry (repro.obs): page-pool occupancy gauges + a
        # high-watermark, and the speculative rejected-tail counter.
        # All host-side ints — the allocator never touches the device, so
        # neither does its instrumentation.  None = uninstrumented.
        self._free_gauge = self._used_gauge = self._peak_gauge = None
        self._truncations = self._rejected_tokens = None
        if registry is not None:
            state_bytes = registry.gauge(
                "serve_state_bytes",
                "decode-state bytes held per layer kind "
                "(KV page pools vs O(1) recurrent slot state)",
                labels=("kind",))
            for kind, nbytes in self._state_bytes_by_kind().items():
                state_bytes.set(nbytes, kind=kind)
            self._free_gauge = registry.gauge(
                "serve_pages_free", "free pages in the shared pool")
            self._used_gauge = registry.gauge(
                "serve_pages_used", "pages held by admitted slots")
            self._peak_gauge = registry.gauge(
                "serve_pages_used_peak",
                "high-watermark of pages held (pool sizing signal)")
            self._truncations = registry.counter(
                "serve_truncations_total",
                "truncate() calls that discarded written positions")
            self._rejected_tokens = registry.counter(
                "serve_spec_rejected_tokens_total",
                "speculative window positions rolled back by truncate()")
            self._free_gauge.set(self.num_pages)
            self._used_gauge.set(0)
            self._peak_gauge.set(0)

    def _update_pool_gauges(self) -> None:
        if self._free_gauge is not None:
            used = self.used_pages
            self._free_gauge.set(len(self._free))
            self._used_gauge.set(used)
            self._peak_gauge.set_max(used)

    def _state_bytes_by_kind(self) -> Dict[str, int]:
        """Device bytes of decode state held per layer kind (where decode
        memory lives: KV page pools vs O(1) recurrent slot state)."""
        n_groups, rem = tfm._layout(self.cfg)
        totals: Dict[str, int] = {}

        def add(kind: str, sub: PyTree) -> None:
            nbytes = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                         for a in jax.tree.leaves(sub))
            totals[kind] = totals.get(kind, 0) + nbytes

        if n_groups > 0:
            for i, kind in enumerate(self.cfg.pattern):
                add(kind, self.pages["scan"][f"b{i}"])
        for j, kind in enumerate(rem):
            add(kind, self.pages[f"tail{j}"])
        return totals

    # -- allocation ---------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Number of pages a sequence of ``n_tokens`` tokens occupies."""
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        if not self.has_paged:
            return n_tokens <= self.max_seq
        return self.pages_for(n_tokens) <= len(self._free)

    def admit(self, slot: int, n_tokens: int) -> bool:
        """Reserve capacity for ``n_tokens`` total tokens in ``slot``:
        pages for the attention layers (if any), plus a zero-reset of the
        slot's recurrent state rows (if any).

        Returns False (allocating nothing) if the pool or the slot's table
        row can't hold the request.
        """
        if self._admitted[slot] or self._owned[slot]:
            raise ValueError(f"slot {slot} already holds pages")
        if n_tokens > self.max_seq:
            return False
        need = self.pages_for(n_tokens) if self.has_paged else 0
        if need > len(self._free) or need > self.max_pages_per_slot:
            return False
        got = [self._free.pop() for _ in range(need)]
        self._owned[slot] = got
        self._tables[slot, :need] = got
        self._admitted[slot] = True
        self._reserved[slot] = n_tokens
        self._committed[slot] = 0
        self._written[slot] = 0
        self._table_device = None
        if self._reset_slot_state is not None:
            # async jit dispatch — zeroes the slot's recurrent rows on
            # device (donated buffers, no host transfer, no sync)
            self.pages = self._reset_slot_state(self.pages,
                                                jnp.int32(slot))
            self._dirty[slot] = False
        self._update_pool_gauges()
        return True

    def retire(self, slot: int) -> None:
        """Return the slot's pages to the free list and mark its recurrent
        state rows stale (the next ``admit`` must reset them)."""
        if self._admitted[slot] and self.has_recurrent:
            self._dirty[slot] = True
        self._free.extend(self._owned[slot])
        self._owned[slot] = []
        self._tables[slot, :] = self.sentinel
        self._admitted[slot] = False
        self._reserved[slot] = 0
        self._committed[slot] = 0
        self._written[slot] = 0
        self._table_device = None
        self._update_pool_gauges()

    def slot_pages(self, slot: int) -> int:
        """Pages currently owned by ``slot`` (0 when idle or page-free)."""
        return len(self._owned[slot])

    # -- fault injection (repro.serve.faults) --------------------------------

    def hold_pages(self, n: Optional[int] = None) -> int:
        """Take up to ``n`` pages (all free pages when None) out of the
        free list with no owner — the fault-injection seam that simulates
        pool exhaustion.  Held pages stay fully accounted
        (``check_invariants`` treats held as a third page state beside
        owned and free); :meth:`release_held` returns them.  Returns the
        number of pages actually taken."""
        if not self.has_paged:
            return 0
        take = len(self._free) if n is None else min(int(n),
                                                     len(self._free))
        for _ in range(take):
            self._held.append(self._free.pop())
        self._update_pool_gauges()
        return take

    def release_held(self) -> int:
        """Return every held page to the free list; returns the count."""
        n = len(self._held)
        self._free.extend(self._held)
        self._held = []
        self._update_pool_gauges()
        return n

    # -- length bookkeeping (speculative windows) ---------------------------

    def capacity(self, slot: int) -> int:
        """Tokens the slot can hold: its reserved pages for paged stacks,
        the admitted request's token budget for page-free ones."""
        if self.has_paged:
            return len(self._owned[slot]) * self.page_size
        return self._reserved[slot]

    def slot_length(self, slot: int) -> int:
        """The slot's committed token count (accepted prefix)."""
        return self._committed[slot]

    def note_write(self, slot: int, end: int) -> None:
        """Record that KV for positions ``[0, end)`` has been written.

        The scheduler calls this when it plans a chunk or speculative
        window for the slot; ``end`` may run ahead of the committed length
        by the window size but never past the reserved capacity.
        """
        if end > self.capacity(slot):
            raise RuntimeError(
                f"slot {slot}: write to position {end} exceeds reserved "
                f"capacity {self.capacity(slot)} "
                f"({len(self._owned[slot])} pages x {self.page_size})")
        self._written[slot] = max(self._written[slot], end)

    def truncate(self, slot: int, new_len: int) -> None:
        """Commit the slot's length to ``new_len``, discarding any written
        positions beyond it (rejected speculative tokens).

        The dead tail needs no page churn — pages were reserved at
        admission and the next window overwrites those positions before
        anything can read them (attention masks by position).  Raises
        ``RuntimeError`` if ``new_len`` rolls back a committed prefix or
        claims positions that were never written.
        """
        if new_len < self._committed[slot]:
            raise RuntimeError(
                f"slot {slot}: truncate to {new_len} would roll back the "
                f"committed prefix ({self._committed[slot]} tokens)")
        if new_len > self._written[slot]:
            raise RuntimeError(
                f"slot {slot}: truncate to {new_len} beyond written "
                f"watermark {self._written[slot]}")
        rejected = self._written[slot] - new_len
        if rejected and self._truncations is not None:
            self._truncations.inc()
            self._rejected_tokens.inc(rejected)
        self._committed[slot] = new_len
        self._written[slot] = new_len

    # -- views --------------------------------------------------------------

    def table_device(self) -> jnp.ndarray:
        """(n_slots, max_pages_per_slot) int32 page table on device."""
        if self._table_device is None:
            self._table_device = jnp.asarray(self._tables)
        return self._table_device

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def held_pages(self) -> int:
        """Pages held out of the pool by fault injection (see
        :meth:`hold_pages`)."""
        return len(self._held)

    def check_invariants(self) -> None:
        """No page is double-owned, owned + free + held covers the pool
        exactly, and per-slot lengths respect committed <= written <=
        capacity.

        Raises ``RuntimeError`` (not ``assert`` — these must survive
        ``python -O``) on the first violated invariant.
        """
        owned = [p for row in self._owned for p in row]
        if len(owned) != len(set(owned)):
            raise RuntimeError("double-allocated page")
        if set(owned) & set(self._free):
            raise RuntimeError("page both owned and free")
        if set(self._held) & (set(owned) | set(self._free)):
            raise RuntimeError("held page also owned or free")
        if len(owned) + len(self._free) + len(self._held) != self.num_pages:
            raise RuntimeError("leaked page")
        for slot, row in enumerate(self._owned):
            mapped = [p for p in self._tables[slot] if p != self.sentinel]
            if mapped != row:
                raise RuntimeError(
                    f"slot {slot}: table/ownership mismatch "
                    f"(mapped {mapped}, owned {row})")
            if not (0 <= self._committed[slot] <= self._written[slot]
                    <= self.capacity(slot)):
                raise RuntimeError(
                    f"slot {slot}: length invariant violated — committed "
                    f"{self._committed[slot]} <= written "
                    f"{self._written[slot]} <= capacity "
                    f"{self.capacity(slot)} must hold")
            if self.has_paged and not row and self._written[slot]:
                raise RuntimeError(
                    f"slot {slot}: nonzero written watermark "
                    f"{self._written[slot]} with no pages owned")
            if self._admitted[slot] and self._dirty[slot]:
                raise RuntimeError(
                    f"slot {slot}: stale recurrent state — the slot was "
                    f"re-admitted without resetting the previous "
                    f"request's device state rows")


# The class predates the per-layer-kind generalization; the name that
# matches what it now is.  Both names are exported from repro.serve.
PagedStatePool = PagedKVCache
