"""Per-layer-kind paged state pool: paged KV for attention layers,
O(1) per-slot state for recurrent layers, one host allocator for both —
with refcounted, copy-on-write page sharing for cached prefixes.

Every layer kind gets the state layout its decode math wants:

- **attention** ('attn', 'local_attn') — the paged KV pool.  The
  monolithic ``T.init_cache`` slab commits ``n_slots * max_seq`` of KV
  HBM up front whether slots are busy or not; the paged pool commits
  memory per *admitted request* instead: a shared pool of ``num_pages``
  fixed-size pages per attention layer, and a page table row per slot
  mapping logical page -> physical page.  Token position ``p`` of slot
  ``b`` lives at ``pages[table[b, p // page_size], p % page_size]``.
- **recurrent** ('rglru', 'ssd') — O(1) per-slot decode state (the
  RG-LRU hidden vector + conv buffer, the SSD state accumulator + conv
  buffers), batch row = slot.  No pages, no page-table entries, no
  reservation pressure on the pool: the state neither grows with
  sequence length nor fragments, so the allocator's only job is hygiene
  — **admitting a slot zeroes its recurrent state rows** (a jitted
  donated ``.at[slot].set(0)``, dispatched asynchronously; no host
  sync) so a reused slot can never leak the previous request's state.
  Per the MPX fragile-spot policy the carried states are fp32 (the
  recurrences compound rounding over thousands of steps); conv buffers
  ride the compute dtype.

A pure-recurrent config gets ``num_pages = 0`` — no KV pools exist and
admission never touches the free list.  Hybrid stacks use both halves at
once: attention layers reserve pages, recurrent layers reset their rows,
one ``admit()`` call.

**Storage precision is a policy, not a constant** (``kv_dtype``, a
``repro.quant`` format).  The bf16 passthrough is the PR-1..4 layout:
one ``(num_pages, page_size, K, D)`` bf16 K and V pool per attention
layer.  Quantized formats ("i8", "f8_e4m3", "f8_e3m4") store the pools
at 1 byte/element on the format's value grid and add a
``(num_pages, K)`` fp32 amax-scale *sidecar* per pool — one symmetric
scale per (page, kv-head), ~``page_size * head_dim / 4`` times smaller
than the pool it describes.  The write-quantize / read-dequantize
contract:

- **writes quantize** — ``paged_attend`` routes each chunk's new K/V
  through :func:`repro.quant.ops.quantized_pool_write`, which gathers
  exactly the pages the chunk touches, splices the new values into
  their dequantized image, recomputes each touched page's amax, and
  requantizes that page (untouched pages keep their bits and scales);
- **reads dequantize in the consumer** — the paged-attention kernel
  multiplies the sidecar scales back onto K/V blocks in VMEM before the
  score/output matmuls (the gather fallback dequantizes its dense
  oracle view), so the sub-bf16 pool is the only HBM-resident image of
  the cache and decode's KV read traffic drops with the itemsize.

**Prefix caching** (``prefix_cache=True``) adds page-level sharing on
top: every page carries a **refcount**, and a content-addressed *prefix
index* maps a chained per-page hash of committed token ids to the
physical page already holding that page's KV.  When a new request's
feed begins with pages that are resident — a hot system prompt, a
few-shot template, a preempted request re-admitting its own history —
admission maps the slot's page table onto those pages (refcount
incremented, zero device work) and chunked prefill **skips the cached
tokens entirely**: the paged-attention kernel needs no changes because
it already resolves logical -> physical pages through the per-slot
table.  A retiring slot *decrements* instead of freeing; a registered
page whose refcount reaches zero parks on an LRU list of **cached**
pages — still resident, still hittable, reclaimed lazily (LRU-first)
when the allocator runs out of free pages, and always reclaimed before
a live slot would be preempted.  Writes into a shared page never happen
in place: the one geometric case where a new tenant must write into a
hit page (every feed page hit, so the final feed token — at least one
token must be fed to produce logits — lands in the last shared page)
is resolved by **copy-on-write at admission**: a private physical copy
is queued (value pages AND the fp32 amax-scale sidecars — requantizing
scatter is a read-modify-write of the whole touched page, so it must
never see another tenant's page), the slot's table points at the copy,
and :meth:`flush_cow` dispatches all pending copies in one donated
jitted gather/scatter right before the engine's device step.
``note_write`` re-checks the planned write span and COWs defensively if
any target page is still shared — the write paths
(:func:`repro.nn.attention.paged_write`,
:func:`repro.quant.ops.quantized_paged_write`) therefore always own
their touched pages exclusively.  Sharing by token *ids* is only sound
when skipping prefill is: recurrent layers carry history-dependent
per-slot state that cannot be skipped into existence, so
``prefix_cache`` is active only for pure-attention stacks (the flag is
accepted and ignored, with all refcounts pinned at <= 1, otherwise).

Bookkeeping (free list, tables, refcounts, per-slot lengths, the prefix
index) is host-side numpy/dict — it mutates a few ints per request, never
touches the device, and stays out of the jitted step.  Passing a
``repro.obs`` registry makes the allocator observable at the same zero
device cost: ``serve_pages_free`` / ``serve_pages_used`` /
``serve_pages_used_peak`` gauges (the peak is the pool-sizing signal),
``serve_pages_shared`` / ``serve_pages_cached`` gauges for the sharing
layer, ``serve_prefix_hits_total`` / ``serve_prefix_miss_total`` /
``serve_cow_copies_total`` counters for the prefix index, and
``serve_truncations_total`` / ``serve_spec_rejected_tokens_total``
counters for speculative tails discarded by ``truncate()``.  The device
side is a pytree of page pools (scale sidecars riding in the same
per-layer dicts, scan-stacked like the params) built by
:func:`repro.models.transformer.init_paged_cache`; all layers share one
table, so admission allocates pages once per sequence.

Allocation policy: the full budget (prompt + max_new tokens) is reserved
at admission, so a running request can never hit pool exhaustion
mid-decode — admission control is the only backpressure point.  (A
shared prefix page counts against the reservation exactly once per
tenant: refcounts make the accounting per-reference, not per-page.)
Speculative decoding adds a second, token-granular piece of bookkeeping
on top: a step may *write* KV for a whole proposed window
(``note_write``) and then *commit* only the accepted prefix
(``truncate``), leaving the rejected tail as dead positions beyond the
slot's length.  No page churn happens — the pages were reserved at
admission and the dead positions are overwritten by the next window —
but the committed/written watermarks make the invariant ("committed <=
written <= reserved capacity, never rolling a committed prefix back")
explicitly checkable.  Only *full, committed* pages register in the
prefix index, and a slot's forward writes always begin at its committed
length, so a registered page is immutable for as long as it is resident
— rollback can land in a COW copy, never in the original.  (Under a
quantized ``kv_dtype`` a dead tail can still nudge a page's amax until
it is overwritten — it costs precision headroom, never correctness,
since attention masks by committed position.)  Recurrent state only
moves forward — there is no watermark to truncate back to — so
speculative windows are refused at engine construction for
recurrent/hybrid stacks (see :class:`~repro.serve.engine.ServeEngine`).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.quant import formats as qfmt

PyTree = Any

#: bytes per chained page digest (blake2b) — 128 bits: collisions across
#: a pool of at most a few thousand resident pages are not a concern.
_DIGEST_BYTES = 16


class PagedKVCache:
    """Per-layer-kind state pool + host allocator for ``n_slots`` slots.

    Attention layers get device page pools; recurrent layers get
    slot-indexed state rows (reset on admit).  The sentinel physical index
    ``num_pages`` marks unallocated table entries: device-side writes
    through it are dropped, reads are clamped and masked by sequence
    length.  ``kv_dtype`` selects the KV page storage format
    (``repro.quant`` name or :class:`~repro.quant.KVFormat`;
    "bf16" = passthrough, quantized formats add the scale sidecars) —
    recurrent state precision is policy-pinned (fp32 carried state),
    not configurable here.  ``prefix_cache=True`` enables refcounted
    prefix-page sharing with copy-on-write (pure-attention stacks only;
    see the module docstring) — with it off, every page's refcount stays
    <= 1 and the allocator behaves exactly as before.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq: int, *,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 dtype=jnp.bfloat16,
                 kv_dtype: Union[str, qfmt.KVFormat] = "bf16",
                 prefix_cache: bool = False,
                 registry=None):
        if max_seq % page_size:
            raise ValueError(f"max_seq {max_seq} must be a multiple of "
                             f"page_size {page_size}")
        self.cfg = cfg
        self.max_seq = max_seq
        kinds = cfg.layer_kinds()
        self.has_paged = any(k in ("attn", "local_attn") for k in kinds)
        self.has_recurrent = any(k in tfm._RECURRENT_KINDS for k in kinds)
        self.page_size = page_size
        self.max_pages_per_slot = max_seq // page_size
        if not self.has_paged:
            num_pages = 0            # page-free stack: no KV pools at all
        self.num_pages = (num_pages if num_pages is not None
                          else n_slots * self.max_pages_per_slot)
        self.n_slots = n_slots
        self.sentinel = self.num_pages
        self.kv_format = qfmt.resolve(kv_dtype)
        # prefix sharing needs pages to share AND the license to skip
        # prefill over them; recurrent state is a function of the full
        # token history, so a skipped prefix would leave it wrong —
        # accept the flag but keep sharing inert for those stacks.
        self.prefix_cache = bool(prefix_cache and self.has_paged
                                 and not self.has_recurrent)
        self.pages: PyTree = tfm.init_paged_cache(
            cfg, self.num_pages, page_size, dtype,
            kv_format=self.kv_format.name, n_slots=n_slots)
        # slot admission state: recurrent rows have no pages to witness
        # occupancy, so track it explicitly.  ``_dirty`` marks slots whose
        # recurrent state still holds a retired request's values; admit()
        # must clear it by resetting the rows before reuse
        # (check_invariants catches stale-state leaks).
        self._admitted: List[bool] = [False] * n_slots
        self._reserved: List[int] = [0] * n_slots
        self._dirty: List[bool] = [False] * n_slots
        self._reset_slot_state = None
        if self.has_recurrent:
            mask = tfm.slot_state_mask(cfg, kv_format=self.kv_format.name)

            def raw_reset(pages, slot):
                out = {}
                for key, sub in pages.items():
                    stacked = key == "scan"
                    out[key] = jax.tree.map(
                        lambda a, m, st=stacked: (
                            (a.at[:, slot].set(jnp.zeros((), a.dtype))
                             if st else
                             a.at[slot].set(jnp.zeros((), a.dtype)))
                            if m else a),
                        sub, mask[key])
                return out

            self._reset_slot_state = jax.jit(raw_reset, donate_argnums=(0,))
        self._free: List[int] = list(range(self.num_pages))
        # fault-injection hold (see hold_pages): pages taken out of the
        # free list without an owner.  A first-class page state —
        # check_invariants accounts for it, so a scripted exhaustion
        # window can't masquerade as a leak.
        self._held: List[int] = []
        # page-sharing state.  Every physical page is in exactly one of
        # four states, which check_invariants proves cover the pool:
        #   free       — on ``_free``, refcount 0, unregistered
        #   held       — on ``_held`` (fault injection), refcount 0
        #   referenced — refcount >= 1: mapped by that many slot tables
        #   cached     — refcount 0 but registered in the prefix index;
        #                parked on the ``_lru`` list (oldest first),
        #                evicted lazily under allocation pressure
        self._refcount: List[int] = [0] * self.num_pages
        self._index: Dict[bytes, int] = {}       # chained digest -> phys
        self._page_digest: Dict[int, bytes] = {}  # phys -> chained digest
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        # rolling per-page hash state per slot: (pages hashed so far,
        # chain digest after them).  Extended incrementally as chunks
        # commit, so registration and the admission probe never rehash
        # an already-hashed prefix — O(pages touched), not O(context).
        self._hash_seed = hashlib.blake2b(
            f"{cfg.name}:{self.kv_format.name}:{page_size}".encode(),
            digest_size=_DIGEST_BYTES).digest()
        self._hash_state: List[Tuple[int, bytes]] = [
            (0, self._hash_seed)] * n_slots
        # queued copy-on-write page copies, flushed in one donated jitted
        # gather/scatter (values + scale sidecars) before the device step
        self._cow_pending: List[Tuple[int, int]] = []
        self._copy_pages = None
        if self.prefix_cache:
            mask = tfm.slot_state_mask(cfg, kv_format=self.kv_format.name)

            def raw_copy(pages, src, dst):
                out = {}
                for key, sub in pages.items():
                    stacked = key == "scan"
                    out[key] = jax.tree.map(
                        lambda a, m, st=stacked: a if m else (
                            a.at[:, dst].set(a[:, src]) if st
                            else a.at[dst].set(a[src])),
                        sub, mask[key])
                return out

            self._copy_pages = jax.jit(raw_copy, donate_argnums=(0,))
        self._tables = np.full((n_slots, self.max_pages_per_slot),
                               self.sentinel, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        # per-slot token watermarks: committed <= written <= capacity.
        # ``written`` is the KV high-water mark (speculative windows write
        # ahead of the committed length); ``committed`` the accepted prefix.
        self._committed: List[int] = [0] * n_slots
        self._written: List[int] = [0] * n_slots
        self._table_device = None        # invalidated on alloc/free
        # telemetry (repro.obs): page-pool occupancy gauges + a
        # high-watermark, prefix-index hit/miss/COW counters, and the
        # speculative rejected-tail counter.  All host-side ints — the
        # allocator never touches the device, so neither does its
        # instrumentation.  None = uninstrumented.
        self._free_gauge = self._used_gauge = self._peak_gauge = None
        self._shared_gauge = self._cached_gauge = None
        self._truncations = self._rejected_tokens = None
        self._hits = self._misses = self._cows = None
        if registry is not None:
            state_bytes = registry.gauge(
                "serve_state_bytes",
                "decode-state bytes held per layer kind "
                "(KV page pools vs O(1) recurrent slot state)",
                labels=("kind",))
            for kind, nbytes in self._state_bytes_by_kind().items():
                state_bytes.set(nbytes, kind=kind)
            self._free_gauge = registry.gauge(
                "serve_pages_free", "free pages in the shared pool")
            self._used_gauge = registry.gauge(
                "serve_pages_used", "pages held by admitted slots")
            self._peak_gauge = registry.gauge(
                "serve_pages_used_peak",
                "high-watermark of pages held (pool sizing signal)")
            self._shared_gauge = registry.gauge(
                "serve_pages_shared",
                "physical pages mapped by more than one slot (refcount "
                ">= 2)")
            self._cached_gauge = registry.gauge(
                "serve_pages_cached",
                "unreferenced pages parked in the prefix index "
                "(LRU-evictable under pool pressure)")
            self._truncations = registry.counter(
                "serve_truncations_total",
                "truncate() calls that discarded written positions")
            self._rejected_tokens = registry.counter(
                "serve_spec_rejected_tokens_total",
                "speculative window positions rolled back by truncate()")
            self._hits = registry.counter(
                "serve_prefix_hits_total",
                "feed pages mapped onto resident cached pages at "
                "admission")
            self._misses = registry.counter(
                "serve_prefix_miss_total",
                "admission probes that ended on an uncached feed page")
            self._cows = registry.counter(
                "serve_cow_copies_total",
                "shared pages privately copied before a divergent write")
            self._free_gauge.set(self.num_pages)
            self._used_gauge.set(0)
            self._peak_gauge.set(0)
            self._shared_gauge.set(0)
            self._cached_gauge.set(0)
            # export the counters from tick zero (schema-pinned by
            # tests/test_obs.py): inc(0) materializes the series
            self._hits.inc(0)
            self._misses.inc(0)
            self._cows.inc(0)

    def _update_pool_gauges(self) -> None:
        if self._free_gauge is not None:
            used = self.used_pages
            self._free_gauge.set(len(self._free))
            self._used_gauge.set(used)
            self._peak_gauge.set_max(used)
            self._shared_gauge.set(self.shared_pages)
            self._cached_gauge.set(len(self._lru))

    def _state_bytes_by_kind(self) -> Dict[str, int]:
        """Device bytes of decode state held per layer kind (where decode
        memory lives: KV page pools vs O(1) recurrent slot state)."""
        n_groups, rem = tfm._layout(self.cfg)
        totals: Dict[str, int] = {}

        def add(kind: str, sub: PyTree) -> None:
            nbytes = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                         for a in jax.tree.leaves(sub))
            totals[kind] = totals.get(kind, 0) + nbytes

        if n_groups > 0:
            for i, kind in enumerate(self.cfg.pattern):
                add(kind, self.pages["scan"][f"b{i}"])
        for j, kind in enumerate(rem):
            add(kind, self.pages[f"tail{j}"])
        return totals

    # -- refcounting / page states ------------------------------------------

    def _incref(self, page: int) -> None:
        if self._refcount[page] == 0:
            self._lru.pop(page, None)    # cached -> referenced
        self._refcount[page] += 1

    def _decref(self, page: int) -> None:
        rc = self._refcount[page] = self._refcount[page] - 1
        if rc < 0:
            raise RuntimeError(f"page {page}: refcount underflow")
        if rc == 0:
            if page in self._page_digest:
                self._lru[page] = None   # referenced -> cached (MRU end)
            else:
                self._free.append(page)  # referenced -> free

    def _evict_cached(self) -> int:
        """Reclaim the least-recently-parked cached page: drop it from
        the prefix index and return it (now free for reuse)."""
        page, _ = self._lru.popitem(last=False)
        digest = self._page_digest.pop(page)
        del self._index[digest]
        return page

    def _alloc_page(self) -> int:
        """One unreferenced physical page: the free list first, then LRU
        eviction of cached pages — cached prefixes are reclaimed lazily,
        and always before admission pressure escalates to preempting a
        live slot (the scheduler only preempts when this pool, cached
        pages included, cannot cover a reservation)."""
        if self._free:
            return self._free.pop()
        if self._lru:
            return self._evict_cached()
        raise RuntimeError(
            "page pool exhausted: no free and no cached-evictable pages "
            "— admission accounting should have prevented this "
            "allocation")

    def _page_hash(self, prev: bytes, tokens: Sequence[int]) -> bytes:
        """Chained digest of one page's token ids: H(prev || ids).

        Chaining makes a page's digest identify the *entire prefix*
        through it, so matching page k implies pages 0..k-1 matched too —
        the index needs no trie, just a flat digest -> page dict."""
        h = hashlib.blake2b(prev, digest_size=_DIGEST_BYTES)
        h.update(np.asarray(tokens, np.int64).tobytes())
        return h.digest()

    # -- allocation ---------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Number of pages a sequence of ``n_tokens`` tokens occupies."""
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        if not self.has_paged:
            return n_tokens <= self.max_seq
        return self.pages_for(n_tokens) <= self.available_pages

    def admit(self, slot: int, n_tokens: int,
              feed: Optional[Sequence[int]] = None) -> bool:
        """Reserve capacity for ``n_tokens`` total tokens in ``slot``:
        pages for the attention layers (if any), plus a zero-reset of the
        slot's recurrent state rows (if any).

        With the prefix cache enabled, ``feed`` (the token ids chunked
        prefill would write) is probed against the prefix index page by
        page — hashing lazily and stopping at the first miss, so the
        probe costs O(pages hit), not O(context).  Hit pages are mapped
        into the slot's table with their refcount incremented and the
        slot's committed/written watermarks start past them
        (:meth:`slot_length` tells the scheduler how many feed tokens to
        skip).  At least one token must always be fed to produce logits,
        so when *every* feed page hits, the final feed token is re-fed —
        and because that write would land inside the last shared page,
        that page is copy-on-write'd here, at admission (the private
        copy is queued for :meth:`flush_cow`; the reservation accounts
        for the extra page).

        Returns False (allocating nothing) if the pool or the slot's
        table row can't hold the request.
        """
        if self._admitted[slot] or self._owned[slot]:
            raise ValueError(f"slot {slot} already holds pages")
        if n_tokens > self.max_seq:
            return False
        need = self.pages_for(n_tokens) if self.has_paged else 0
        if need > self.max_pages_per_slot:
            return False
        ps = self.page_size
        shared: List[int] = []
        digests: List[bytes] = []
        probe_missed = False
        feed_len = len(feed) if feed is not None else 0
        if self.prefix_cache and feed_len >= ps:
            d = self._hash_seed
            for k in range(feed_len // ps):
                d = self._page_hash(d, feed[k * ps:(k + 1) * ps])
                phys = self._index.get(d)
                if phys is None:
                    probe_missed = True
                    break
                shared.append(phys)
                digests.append(d)
        # the skip cap: at least one feed token must run through the
        # model to produce the logits the first sample needs
        skip = min(len(shared) * ps, feed_len - 1) if shared else 0
        boundary = bool(shared) and len(shared) * ps > skip
        n_mapped = len(shared) - 1 if boundary else len(shared)
        fresh_needed = need - n_mapped
        hit_set = set(shared)
        evictable = sum(1 for p in self._lru if p not in hit_set)
        if fresh_needed > len(self._free) + evictable:
            return False             # clean failure: nothing was mutated
        if self._hits is not None:
            if shared:
                self._hits.inc(len(shared))
            if probe_missed:
                self._misses.inc()
        row = list(shared[:n_mapped])
        for p in row:
            self._incref(p)
        cow_src = shared[-1] if boundary else None
        if cow_src is not None:
            # pin the COW source against this admission's own LRU
            # eviction while the fresh pages are allocated
            self._incref(cow_src)
        fresh = [self._alloc_page() for _ in range(fresh_needed)]
        for p in fresh:
            self._incref(p)
        if cow_src is not None:
            # the boundary page: fresh[0] is its private copy at logical
            # index ``n_mapped`` — queue the device copy (value pages and
            # scale sidecars alike) and unpin the source, which stays
            # resident for other tenants / the index
            self._cow_pending.append((cow_src, fresh[0]))
            if self._cows is not None:
                self._cows.inc()
            self._decref(cow_src)
        row += fresh
        self._owned[slot] = row
        self._tables[slot, :len(row)] = row
        self._admitted[slot] = True
        self._reserved[slot] = n_tokens
        self._committed[slot] = skip
        self._written[slot] = skip
        self._hash_state[slot] = ((len(shared), digests[-1]) if shared
                                  else (0, self._hash_seed))
        self._table_device = None
        if self._reset_slot_state is not None:
            # async jit dispatch — zeroes the slot's recurrent rows on
            # device (donated buffers, no host transfer, no sync)
            self.pages = self._reset_slot_state(self.pages,
                                                jnp.int32(slot))
            self._dirty[slot] = False
        self._update_pool_gauges()
        return True

    def retire(self, slot: int) -> None:
        """Drop the slot's references and mark its recurrent state rows
        stale (the next ``admit`` must reset them).  A page this slot
        shared with another stays referenced; a registered page whose
        last reference this was parks on the cached LRU list (still
        hittable); everything else returns to the free list."""
        if self._admitted[slot] and self.has_recurrent:
            self._dirty[slot] = True
        for p in self._owned[slot]:
            self._decref(p)
        if self._cow_pending:
            # drop queued copies whose destination just lost its only
            # owner — the copy would scribble on a page the allocator
            # may hand to the next admission
            self._cow_pending = [(s, d) for s, d in self._cow_pending
                                 if self._refcount[d] > 0]
        self._owned[slot] = []
        self._tables[slot, :] = self.sentinel
        self._admitted[slot] = False
        self._reserved[slot] = 0
        self._committed[slot] = 0
        self._written[slot] = 0
        self._hash_state[slot] = (0, self._hash_seed)
        self._table_device = None
        self._update_pool_gauges()

    def slot_pages(self, slot: int) -> int:
        """Pages currently owned by ``slot`` (0 when idle or page-free)."""
        return len(self._owned[slot])

    def reclaimable_pages(self, slot: int) -> int:
        """Pages that evicting ``slot`` would make allocatable: its
        exclusively-referenced pages (refcount 1 — they go free or
        cached-evictable on retire).  Shared pages stay referenced by
        their other tenants and are not reclaimed."""
        return sum(1 for p in self._owned[slot] if self._refcount[p] == 1)

    # -- prefix index -------------------------------------------------------

    def note_committed(self, slot: int, ctx: Sequence[int]) -> None:
        """Register the slot's newly *full, committed* pages in the
        prefix index.  ``ctx`` is the slot's token history (prompt +
        committed generations); position ``p`` of the slot's KV holds
        ``ctx[p]`` for every committed position.

        Hashing is incremental: the slot carries (pages hashed, chain
        digest) and only the pages the committed watermark newly crossed
        are hashed — O(new pages), never a rehash of the prefix.  First
        registration wins: a digest already in the index (this slot
        admitted *through* it, or a concurrent slot beat it) is skipped,
        so exactly one physical page is canonical per prefix."""
        if not self.prefix_cache:
            return
        ps = self.page_size
        hashed, d = self._hash_state[slot]
        full = self._committed[slot] // ps
        while hashed < full:
            d = self._page_hash(d, ctx[hashed * ps:(hashed + 1) * ps])
            phys = int(self._tables[slot, hashed])
            if d not in self._index and phys not in self._page_digest:
                self._index[d] = phys
                self._page_digest[phys] = d
            hashed += 1
        self._hash_state[slot] = (hashed, d)

    def _cow_page(self, slot: int, logical: int) -> int:
        """Give ``slot`` a private copy of its shared ``logical`` page
        before a write can touch it: allocate a fresh physical page,
        queue the device copy (value pages and scale sidecars), patch
        the slot's table/ownership, and drop the slot's reference on the
        original — which stays intact for its other tenants."""
        old = int(self._tables[slot, logical])
        new = self._alloc_page()
        self._incref(new)
        self._cow_pending.append((old, new))
        self._tables[slot, logical] = new
        self._owned[slot][logical] = new
        self._decref(old)
        self._table_device = None
        if self._cows is not None:
            self._cows.inc()
        self._update_pool_gauges()
        return new

    def flush_cow(self) -> None:
        """Dispatch every queued copy-on-write page copy as one donated
        jitted gather/scatter over the page-pool leaves (scale sidecars
        included).  The engine calls this after planning and before the
        device step, so a write never races its page's copy.  Async
        dispatch — no host sync."""
        if not self._cow_pending:
            return
        pairs, self._cow_pending = self._cow_pending, []
        src = jnp.asarray(np.array([s for s, _ in pairs], np.int32))
        dst = jnp.asarray(np.array([d for _, d in pairs], np.int32))
        self.pages = self._copy_pages(self.pages, src, dst)

    # -- fault injection (repro.serve.faults) --------------------------------

    def hold_pages(self, n: Optional[int] = None) -> int:
        """Take up to ``n`` pages (all free pages when None) out of the
        free list with no owner — the fault-injection seam that simulates
        pool exhaustion.  Held pages stay fully accounted
        (``check_invariants`` treats held as a first-class page state
        beside owned, free and cached); :meth:`release_held` returns
        them.  Cached pages are not holdable — they carry data and stay
        reclaimable, which is exactly the semantics sharing wants under
        pressure.  Returns the number of pages actually taken."""
        if not self.has_paged:
            return 0
        take = len(self._free) if n is None else min(int(n),
                                                     len(self._free))
        for _ in range(take):
            self._held.append(self._free.pop())
        self._update_pool_gauges()
        return take

    def release_held(self) -> int:
        """Return every held page to the free list; returns the count."""
        n = len(self._held)
        self._free.extend(self._held)
        self._held = []
        self._update_pool_gauges()
        return n

    # -- length bookkeeping (speculative windows) ---------------------------

    def capacity(self, slot: int) -> int:
        """Tokens the slot can hold: its reserved pages for paged stacks,
        the admitted request's token budget for page-free ones."""
        if self.has_paged:
            return len(self._owned[slot]) * self.page_size
        return self._reserved[slot]

    def slot_length(self, slot: int) -> int:
        """The slot's committed token count (accepted prefix).  Right
        after :meth:`admit` this is the cached-prefix skip: the number
        of feed tokens whose KV is already resident via shared pages."""
        return self._committed[slot]

    def note_write(self, slot: int, end: int) -> None:
        """Record that KV for positions ``[0, end)`` has been written.

        The scheduler calls this when it plans a chunk or speculative
        window for the slot; ``end`` may run ahead of the committed length
        by the window size but never past the reserved capacity.  The
        planned span always starts at the current written watermark
        (prefill resumes at ``fed``, decode at the committed length), so
        this is also the copy-on-write barrier: any still-shared page in
        the span gets a private copy *before* the device step's
        ``paged_write`` / ``quantized_paged_write`` can touch it — the
        requantizing scatter is a read-modify-write of whole pages and
        must never see a page another slot maps.
        """
        if end > self.capacity(slot):
            raise RuntimeError(
                f"slot {slot}: write to position {end} exceeds reserved "
                f"capacity {self.capacity(slot)} "
                f"({len(self._owned[slot])} pages x {self.page_size})")
        if self.prefix_cache and end > self._written[slot]:
            ps = self.page_size
            for logical in range(self._written[slot] // ps,
                                 (end - 1) // ps + 1):
                if self._refcount[int(self._tables[slot, logical])] > 1:
                    self._cow_page(slot, logical)
        self._written[slot] = max(self._written[slot], end)

    def truncate(self, slot: int, new_len: int) -> None:
        """Commit the slot's length to ``new_len``, discarding any written
        positions beyond it (rejected speculative tokens).

        The dead tail needs no page churn — pages were reserved at
        admission and the next window overwrites those positions before
        anything can read them (attention masks by position).  Shared
        prefix pages are below the committed watermark by construction
        (only full committed pages register, and rollback never crosses
        ``committed``), so a truncate can land in a COW copy but never
        in a page another slot references.  Raises ``RuntimeError`` if
        ``new_len`` rolls back a committed prefix or claims positions
        that were never written.
        """
        if new_len < self._committed[slot]:
            raise RuntimeError(
                f"slot {slot}: truncate to {new_len} would roll back the "
                f"committed prefix ({self._committed[slot]} tokens)")
        if new_len > self._written[slot]:
            raise RuntimeError(
                f"slot {slot}: truncate to {new_len} beyond written "
                f"watermark {self._written[slot]}")
        rejected = self._written[slot] - new_len
        if rejected and self._truncations is not None:
            self._truncations.inc()
            self._rejected_tokens.inc(rejected)
        self._committed[slot] = new_len
        self._written[slot] = new_len

    # -- views --------------------------------------------------------------

    def table_device(self) -> jnp.ndarray:
        """(n_slots, max_pages_per_slot) int32 page table on device."""
        if self._table_device is None:
            self._table_device = jnp.asarray(self._tables)
        return self._table_device

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Unreferenced registered pages parked on the LRU list —
        resident and hittable, reclaimed lazily under pressure."""
        return len(self._lru)

    @property
    def available_pages(self) -> int:
        """Pages an admission could obtain: free plus cached-evictable."""
        return len(self._free) + len(self._lru)

    @property
    def shared_pages(self) -> int:
        """Physical pages currently mapped by more than one slot."""
        return sum(1 for rc in self._refcount if rc >= 2)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def held_pages(self) -> int:
        """Pages held out of the pool by fault injection (see
        :meth:`hold_pages`)."""
        return len(self._held)

    @property
    def prefix_hits(self) -> int:
        """Cumulative prefix-cache page hits (0 when uninstrumented) —
        host ints for the flight-recorder tick digest."""
        return int(self._hits.total) if self._hits is not None else 0

    @property
    def prefix_misses(self) -> int:
        """Cumulative prefix-index probe misses (0 when uninstrumented)."""
        return int(self._misses.total) if self._misses is not None else 0

    @property
    def cow_copies(self) -> int:
        """Cumulative copy-on-write page copies (0 when uninstrumented)."""
        return int(self._cows.total) if self._cows is not None else 0

    def check_invariants(self) -> None:
        """Every physical page is in exactly one state — free, held,
        referenced (refcount >= 1), or cached — refcounts equal table
        multiplicity, **no page is simultaneously free and referenced**,
        the prefix index is a bijection onto registered pages, and
        per-slot lengths respect committed <= written <= capacity.

        Raises ``RuntimeError`` (not ``assert`` — these must survive
        ``python -O``) on the first violated invariant.
        """
        counts: Dict[int, int] = {}
        for slot, row in enumerate(self._owned):
            if len(row) != len(set(row)):
                raise RuntimeError(
                    f"slot {slot} maps a physical page twice: {row}")
            for p in row:
                counts[p] = counts.get(p, 0) + 1
        for p in range(self.num_pages):
            if self._refcount[p] != counts.get(p, 0):
                raise RuntimeError(
                    f"page {p}: refcount {self._refcount[p]} but "
                    f"{counts.get(p, 0)} slot(s) map it")
        referenced = set(counts)
        free_set = set(self._free)
        if len(free_set) != len(self._free):
            raise RuntimeError("page on the free list twice")
        if referenced & free_set:
            raise RuntimeError(
                f"page(s) {sorted(referenced & free_set)} simultaneously "
                f"free and referenced")
        held_set = set(self._held)
        cached_set = set(self._lru)
        for name_a, a, name_b, b in (
                ("held", held_set, "free", free_set),
                ("held", held_set, "referenced", referenced),
                ("cached", cached_set, "free", free_set),
                ("cached", cached_set, "referenced", referenced),
                ("cached", cached_set, "held", held_set)):
            if a & b:
                raise RuntimeError(
                    f"page(s) {sorted(a & b)} both {name_a} and {name_b}")
        if (len(referenced) + len(free_set) + len(held_set)
                + len(cached_set) != self.num_pages):
            raise RuntimeError("leaked page")
        if len(self._index) != len(self._page_digest):
            raise RuntimeError("prefix index / digest map out of sync")
        for digest, p in self._index.items():
            if self._page_digest.get(p) != digest:
                raise RuntimeError(
                    f"page {p}: index digest does not round-trip")
        for p in self._page_digest:
            if p in free_set or p in held_set:
                raise RuntimeError(
                    f"registered page {p} is {'free' if p in free_set else 'held'}")
            if counts.get(p, 0) == 0 and p not in cached_set:
                raise RuntimeError(
                    f"registered page {p} neither referenced nor cached")
        for slot, row in enumerate(self._owned):
            mapped = [p for p in self._tables[slot] if p != self.sentinel]
            if mapped != row:
                raise RuntimeError(
                    f"slot {slot}: table/ownership mismatch "
                    f"(mapped {mapped}, owned {row})")
            if not (0 <= self._committed[slot] <= self._written[slot]
                    <= self.capacity(slot)):
                raise RuntimeError(
                    f"slot {slot}: length invariant violated — committed "
                    f"{self._committed[slot]} <= written "
                    f"{self._written[slot]} <= capacity "
                    f"{self.capacity(slot)} must hold")
            if self.has_paged and not row and self._written[slot]:
                raise RuntimeError(
                    f"slot {slot}: nonzero written watermark "
                    f"{self._written[slot]} with no pages owned")
            if self._admitted[slot] and self._dirty[slot]:
                raise RuntimeError(
                    f"slot {slot}: stale recurrent state — the slot was "
                    f"re-admitted without resetting the previous "
                    f"request's device state rows")


# The class predates the per-layer-kind generalization; the name that
# matches what it now is.  Both names are exported from repro.serve.
PagedStatePool = PagedKVCache
