"""Paged bf16 KV-cache pool: fixed-size pages, per-slot page tables.

The monolithic ``T.init_cache`` slab commits ``n_slots * max_seq`` of KV
HBM up front whether slots are busy or not.  The paged pool commits memory
per *admitted request* instead: a shared pool of ``num_pages`` fixed-size
pages per attention layer, and a page table row per slot mapping logical
page -> physical page.  Token position ``p`` of slot ``b`` lives at
``pages[table[b, p // page_size], p % page_size]``.

Bookkeeping (free list, tables) is host-side numpy — it mutates a few ints
per request, never touches the device, and stays out of the jitted step.
The device side is a pytree of page pools (one (num_pages, page_size, K, D)
K and V array per attention layer, scan-stacked like the params) built by
:func:`repro.models.transformer.init_paged_cache`; all layers share one
table, so admission allocates pages once per sequence.

Allocation policy: the full budget (prompt + max_new tokens) is reserved at
admission, so a running request can never hit pool exhaustion mid-decode —
admission control is the only backpressure point.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm

PyTree = Any


class PagedKVCache:
    """Device page pools + host allocator for ``n_slots`` decode slots.

    The sentinel physical index ``num_pages`` marks unallocated table
    entries: device-side writes through it are dropped, reads are clamped
    and masked by sequence length.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq: int, *,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 dtype=jnp.bfloat16):
        if max_seq % page_size:
            raise ValueError(f"max_seq {max_seq} must be a multiple of "
                             f"page_size {page_size}")
        self.page_size = page_size
        self.max_pages_per_slot = max_seq // page_size
        self.num_pages = (num_pages if num_pages is not None
                          else n_slots * self.max_pages_per_slot)
        self.n_slots = n_slots
        self.sentinel = self.num_pages
        self.pages: PyTree = tfm.init_paged_cache(
            cfg, self.num_pages, page_size, dtype)
        self._free: List[int] = list(range(self.num_pages))
        self._tables = np.full((n_slots, self.max_pages_per_slot),
                               self.sentinel, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        self._table_device = None        # invalidated on alloc/free

    # -- allocation ---------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Number of pages a sequence of ``n_tokens`` tokens occupies."""
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    def admit(self, slot: int, n_tokens: int) -> bool:
        """Reserve pages for ``n_tokens`` total tokens in ``slot``.

        Returns False (allocating nothing) if the pool or the slot's table
        row can't hold the request.
        """
        need = self.pages_for(n_tokens)
        if self._owned[slot]:
            raise ValueError(f"slot {slot} already holds pages")
        if need > len(self._free) or need > self.max_pages_per_slot:
            return False
        got = [self._free.pop() for _ in range(need)]
        self._owned[slot] = got
        self._tables[slot, :need] = got
        self._table_device = None
        return True

    def retire(self, slot: int) -> None:
        """Return the slot's pages to the free list."""
        self._free.extend(self._owned[slot])
        self._owned[slot] = []
        self._tables[slot, :] = self.sentinel
        self._table_device = None

    # -- views --------------------------------------------------------------

    def table_device(self) -> jnp.ndarray:
        """(n_slots, max_pages_per_slot) int32 page table on device."""
        if self._table_device is None:
            self._table_device = jnp.asarray(self._tables)
        return self._table_device

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def check_invariants(self) -> None:
        """No page is double-owned, free + owned covers the pool exactly."""
        owned = [p for row in self._owned for p in row]
        assert len(owned) == len(set(owned)), "double-allocated page"
        assert not set(owned) & set(self._free), "page both owned and free"
        assert len(owned) + len(self._free) == self.num_pages, "leaked page"
        for slot, row in enumerate(self._owned):
            mapped = [p for p in self._tables[slot] if p != self.sentinel]
            assert mapped == row, (slot, mapped, row)
