"""The ServeEngine facade: submit() / step() / drain().

Ties the subsystem together: the paged KV cache (device pools + host
allocator), the mixed-chunk continuous-batching scheduler (host plans),
ONE jitted ``(B, chunk_size)`` specialization of the unified
``serve_forward`` step — every tick is a mixed plan in which each active
slot contributes either a prefill chunk or its decode *window* — and fp32
verification/sampling over each slot's window logits.

Speculative decoding (``spec_tokens > 0``) turns the decode side of every
tick into a propose/verify/commit loop: a host-side
:class:`~repro.serve.propose.Proposer` (n-gram prompt lookup by default)
drafts up to ``spec_tokens`` tokens per decoding slot, the scheduler packs
committed-token + drafts into the slot's chunk columns, ``serve_forward``
returns per-position logits for the whole window (``logit_idx`` gather),
and :func:`repro.serve.sampling.rejection_sample` accepts the longest
matching prefix plus one corrected/bonus token — so one engine step can
emit up to ``spec_tokens + 1`` tokens per slot.  ``commit()`` rolls each
slot's cache length back over the rejected tail
(:meth:`PagedKVCache.truncate`); with temperature 0 the accept rule is
argmax equality, making the speculative engine token-identical to the
non-speculative one.  ``spec_tokens = 0`` is the same compiled program
shape with a 1-wide window — plain decoding.

When ``use_kernel`` is set, EVERY step — prefill, decode and mixed alike —
routes attention through the Pallas paged-attention kernel
(``repro.kernels.paged_attention``): the page table is a scalar-prefetch
operand and the kernel streams each slot's allocated pages straight from
the shared pools (``pages_per_block`` logical pages per K-block), so the
per-step gathered dense copy of the cache never exists and there is still
exactly one compiled step program.

Precision: params are expected pre-cast to the serving dtype (bf16); the
KV pages store in the ``kv_dtype`` policy format (bf16 passthrough, or
int8 / fp8 with per-page amax scales dequantized inside the kernel —
``repro.quant``); softmax inside the model, the sampling transforms and
the rejection-sampling accept/residual rule are fp32 — the inference half
of the MPX discipline (verification shares softmax's "known-fragile"
status: a bf16 tail probability flips accept decisions).  ``kv_dtype``
accepts the format name, a :class:`~repro.quant.KVFormat`, or a
:class:`~repro.core.policy.Policy` (its ``kv=`` component), so
``ServeEngine(cfg, params, kv_dtype=Policy.parse("p=f32,c=bf16,o=bf16,
kv=i8"))`` threads one policy string end to end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.serve.cache import PagedKVCache
from repro.serve.metrics import EngineStats, RequestMetrics
from repro.serve.propose import NGramProposer, Proposer
from repro.serve.sampling import SamplingParams, make_verifier
from repro.serve.scheduler import DECODE, PREFILL, Request, Scheduler

PyTree = Any


@dataclasses.dataclass
class RequestResult:
    """A finished request: generated tokens + lifecycle metrics."""
    request_id: int
    prompt: List[int]
    tokens: List[int]
    metrics: RequestMetrics


class ServeEngine:
    """Mixed-precision inference engine with paged KV cache.

    ``submit()`` enqueues requests; ``step()`` runs one scheduler tick
    (admit -> one mixed prefill+decode batch step with window
    verification -> retire finished); ``drain()`` steps until idle and
    returns results ordered by request id.  ``max_batched_tokens`` bounds
    the real tokens per step (committed decode tokens are planned first;
    draft windows and prefill chunks fill the remainder).
    ``spec_tokens`` sets the speculative window (0 disables);
    ``proposer`` overrides the default n-gram prompt-lookup drafter.
    """

    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 n_slots: int = 4, max_seq: int = 256,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 chunk_size: int = 32,
                 max_batched_tokens: Optional[int] = None,
                 sampling: SamplingParams = SamplingParams(),
                 spec_tokens: int = 0,
                 proposer: Optional[Proposer] = None,
                 use_kernel: bool = False, pages_per_block: int = 1,
                 kv_dtype="bf16", seed: int = 0):
        if not cfg.supports_decode():
            raise ValueError(f"{cfg.name} does not support decode")
        self.cfg = cfg
        self.params = params
        if hasattr(kv_dtype, "kv_dtype"):     # a core.policy.Policy
            kv_dtype = kv_dtype.kv_dtype
        self.kv_format = quant.resolve(kv_dtype)
        self.spec_tokens = int(spec_tokens)
        if proposer is not None and self.spec_tokens == 0:
            raise ValueError(
                "a proposer without spec_tokens > 0 would never be "
                "consulted — pass spec_tokens=k to size the speculative "
                "window")
        if self.spec_tokens > 0 and proposer is None:
            proposer = NGramProposer()
        self.proposer = proposer
        self.cache = PagedKVCache(cfg, n_slots, max_seq,
                                  page_size=page_size, num_pages=num_pages,
                                  kv_dtype=self.kv_format)
        self.scheduler = Scheduler(self.cache, chunk_size=chunk_size,
                                   max_batched_tokens=max_batched_tokens,
                                   spec_tokens=self.spec_tokens,
                                   proposer=self.proposer)
        self.sampling = sampling
        self.stats = EngineStats(n_slots)
        self._key = jax.random.key(seed)
        self._next_id = 0
        self._inflight: dict[int, RequestMetrics] = {}
        self._results: List[RequestResult] = []
        self._result_ids: set[int] = set()   # finished, kept for drain()

        verifier = make_verifier(sampling)

        def raw_step(params, pages, table, tokens, start, valid,
                     logit_idx, draft, draft_len, key):
            # serve_forward returns the (B, W, V) window logits named by
            # logit_idx — the unembed runs once per window position, not
            # per chunk position; verification/sampling runs in fp32
            logits, new_pages = tfm.serve_forward(
                params, cfg, pages, table, tokens, start, valid,
                logit_idx=logit_idx, page_size=page_size,
                use_kernel=use_kernel, pages_per_block=pages_per_block,
                kv_format=self.kv_format.name)
            accept, token = verifier(logits, draft, draft_len, key)
            return accept, token, new_pages

        # one compiled step shape AND program: (B, chunk_size) for
        # prefill, decode and mixed plans alike — the paged kernel covers
        # every plan, and the W-wide verify covers spec_tokens = 0 (W=1,
        # zero drafts) through full windows with no extra specialization.
        self._device_step = jax.jit(raw_step, donate_argnums=(1,))

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: List[int], max_new: int = 32,
               request_id: Optional[int] = None) -> int:
        """Enqueue a request; returns its id.

        An explicit ``request_id`` colliding with a queued, in-flight, or
        already-finished request is rejected — a duplicate would corrupt
        that request's metrics entry and collide in ``drain()``'s
        id-sorted results (results accumulate for the engine's lifetime).
        """
        # fail fast on a stub proposer: plan() would otherwise raise mid-
        # step, after this request reserved pages and entered a batch —
        # a traceback from inside the scheduler instead of an actionable
        # "this is a follow-on" at the API boundary
        unimplemented = getattr(self.proposer, "unimplemented", None)
        if unimplemented:
            raise NotImplementedError(unimplemented)
        rid = self._next_id if request_id is None else request_id
        if rid in self._inflight or rid in self._result_ids:
            raise ValueError(
                f"request id {rid} is already queued, in flight, or "
                f"finished — engine request ids are single-use")
        self.scheduler.submit(Request(rid, list(prompt), max_new))
        self._next_id = max(self._next_id, rid) + 1
        self._inflight[rid] = RequestMetrics(
            request_id=rid, prompt_len=len(prompt),
            submit_time=time.perf_counter())
        return rid

    def step(self) -> List[RequestResult]:
        """One scheduler tick.  Returns requests that finished this step."""
        self.scheduler.admit()
        if self.scheduler.busy_slots == 0:
            return []
        t0 = time.perf_counter()
        plan = self.scheduler.plan()
        if self.sampling.is_greedy:
            key = self._key
        else:
            self._key, key = jax.random.split(self._key)
        slot_rids = [None if s is None else s.req.request_id
                     for s in self.scheduler.slots]
        accept, token, self.cache.pages = self._device_step(
            self.params, self.cache.pages, self.cache.table_device(),
            jnp.asarray(plan.tokens), jnp.asarray(plan.start),
            jnp.asarray(plan.valid), jnp.asarray(plan.logit_idx),
            jnp.asarray(plan.draft), jnp.asarray(plan.draft_len), key)
        accept = np.asarray(accept)                   # blocks on the device
        token = np.asarray(token)
        now = time.perf_counter()

        # per-request speculation accounting, against the pre-commit
        # slot->request mapping (commit retires finished slots)
        for slot_id, rid in enumerate(slot_rids):
            k = int(plan.draft_len[slot_id])
            if rid is None or k == 0:
                continue
            rm = self._inflight[rid]
            rm.proposed_tokens += k
            rm.accepted_tokens += int(accept[slot_id])

        outcome = self.scheduler.commit(plan, token, accept)
        first = set(outcome.first_token)
        for rid, _ in outcome.emitted:
            rm = self._inflight[rid]
            if rid in first:
                rm.first_token_time = now
            else:
                # one gap per request per step: a speculative window's
                # tokens arrive together, so the gap spans the whole batch
                self.stats.record_token_gap(now - rm.last_token_time)
            rm.last_token_time = now
        results = []
        for _, slot in outcome.finished:
            rm = self._inflight.pop(slot.req.request_id)
            self._result_ids.add(slot.req.request_id)
            rm.finish_time = now
            rm.new_tokens = len(slot.out)
            self.stats.record_finish(rm)
            results.append(RequestResult(slot.req.request_id,
                                         slot.req.prompt, slot.out, rm))
        self.stats.record_step(
            plan.kind, self.scheduler.busy_slots + len(outcome.finished),
            outcome.n_tokens, now - t0,
            prefill_tokens=np.where(plan.kinds == PREFILL, plan.valid, 0),
            decode_tokens=np.where(plan.kinds == DECODE, plan.valid, 0),
            proposed=plan.n_draft,
            accepted=int(accept.sum()))
        self._results.extend(results)
        return results

    def drain(self) -> List[RequestResult]:
        """Run until queue and slots are empty; all results, by id."""
        while self.scheduler.has_work:
            self.step()
        return sorted(self._results, key=lambda r: r.request_id)
