"""The ServeEngine facade: submit() / step() / drain().

Ties the subsystem together: the per-layer-kind paged state pool (paged
KV pools for attention layers, O(1) per-slot fp32 state for rglru/ssd
layers — one host allocator for both), the mixed-chunk
continuous-batching scheduler (host plans), ONE jitted
``(B, chunk_size)`` specialization of the unified ``serve_forward`` step
— every tick is a mixed plan in which each active slot contributes
either a prefill chunk or its decode *window* — and fp32
verification/sampling over each slot's window logits.  One engine serves
attn, ssm, rglru and hybrid stacks; greedy output is token-identical to
the dense per-token ``decode()`` oracle for all of them.  Speculative
windows require the rollback only paged KV supports, so recurrent and
hybrid stacks must run with ``spec_tokens=0`` (refused with an
actionable error at construction).

Speculative decoding (``spec_tokens > 0``) turns the decode side of every
tick into a propose/verify/commit loop: a host-side
:class:`~repro.serve.propose.Proposer` (n-gram prompt lookup by default)
drafts up to ``spec_tokens`` tokens per decoding slot, the scheduler packs
committed-token + drafts into the slot's chunk columns, ``serve_forward``
returns per-position logits for the whole window (``logit_idx`` gather),
and :func:`repro.serve.sampling.rejection_sample` accepts the longest
matching prefix plus one corrected/bonus token — so one engine step can
emit up to ``spec_tokens + 1`` tokens per slot.  ``commit()`` rolls each
slot's cache length back over the rejected tail
(:meth:`PagedKVCache.truncate`); with temperature 0 the accept rule is
argmax equality, making the speculative engine token-identical to the
non-speculative one.  ``spec_tokens = 0`` is the same compiled program
shape with a 1-wide window — plain decoding.

When ``use_kernel`` is set, EVERY step — prefill, decode and mixed alike —
routes attention through the Pallas paged-attention kernel
(``repro.kernels.paged_attention``): the page table is a scalar-prefetch
operand and the kernel streams each slot's allocated pages straight from
the shared pools (``pages_per_block`` logical pages per K-block), so the
per-step gathered dense copy of the cache never exists and there is still
exactly one compiled step program.

Precision: params are expected pre-cast to the serving dtype (bf16); the
KV pages store in the ``kv_dtype`` policy format (bf16 passthrough, or
int8 / fp8 with per-page amax scales dequantized inside the kernel —
``repro.quant``); softmax inside the model, the sampling transforms and
the rejection-sampling accept/residual rule are fp32 — the inference half
of the MPX discipline (verification shares softmax's "known-fragile"
status: a bf16 tail probability flips accept decisions).  ``kv_dtype``
accepts the format name, a :class:`~repro.quant.KVFormat`, or a
:class:`~repro.core.policy.Policy` (its ``kv=`` component), so
``ServeEngine(cfg, params, kv_dtype=Policy.parse("p=f32,c=bf16,o=bf16,
kv=i8"))`` threads one policy string end to end.

Telemetry (``repro.obs``): the engine always carries a metrics
:class:`~repro.obs.Registry` — the scheduler reports queue depth and
admissions, the paged cache reports pool free/used/peak pages and
speculative truncations, and :class:`EngineStats` rides its own registry
(reset with ``engine.stats``) — export both with
:meth:`metrics_snapshot` / :meth:`prometheus`.  Passing a
:class:`~repro.obs.Tracer` additionally records every tick's phase spans
(``admit`` / ``plan`` / ``device step`` / ``host sync`` / ``commit`` on
the ``engine`` track) and each slot's request lifecycle (``submit`` /
``admit`` instants, ``prefill`` chunk spans, ``decode`` window spans
carrying ``{rid, tokens, drafts, accepted}``, ``truncate`` on rejected
tails, ``retire``) as Chrome trace events — ``tracer.export(path)`` then
loads in Perfetto as a per-slot timeline.  All instrumentation reads
host state and the two ``(B,)`` arrays the step already transfers
(``accept`` / ``token``): tracing adds **zero device syncs** to
``step()`` (pinned by tests/test_obs.py) and <3% tok/s on the bench
workload (``serving_obs_overhead_pct``).

Flight recorder (``repro.obs.journal``): passing
``journal=JournalRecorder(path)`` event-sources the whole drive — config
fingerprint, fault schedule, every clock sample, ``submit``/``cancel``,
a per-tick digest (plan summary, pool/prefix counters, a rolling hash
over each slot's sampled tokens) and every result — into an append-only
JSONL file that ``replay_journal(path)`` re-drives deterministically,
naming the first divergent tick on mismatch, and that
``python -m repro.obs.postmortem`` renders as a per-request incident
report.  Recording reads the same host-side state the tracer does (zero
added device syncs — the test_obs transfer pin holds with the journal
enabled) and costs <3% tok/s (``serving_journal_overhead_pct``).

Resilience: the engine assumes an adversarial world, not a cooperative
one.  Admission is bounded (``max_queue`` -> :class:`EngineOverloaded`
backpressure), pool pressure is survived by preempting the youngest
decoding slot and recomputing it through the chunked-prefill path
(greedy output token-identical, ``serve_preemptions_total`` counts the
cost), requests carry deadlines (``submit(deadline_ms=...)``) and can be
cancelled (:meth:`ServeEngine.cancel`) — both enforced at tick
boundaries with partial output delivered — and every step's window
logits pass a nonfinite guard whose verdict rides the two arrays already
transferred (a poisoned request dies with status ``"failed"``; its
batch neighbors don't notice).  A mid-tick exception fails exactly the
plan's requests and retires their slots, so pages cannot leak and the
engine keeps serving.  All of it is scriptable for chaos testing via
:mod:`repro.serve.faults` and counted/traced via ``repro.obs``
(``serve_preemptions_total`` / ``serve_timeouts_total`` /
``serve_cancelled_total`` / ``serve_nonfinite_total`` /
``serve_failed_total``; ``preempt`` / ``timeout`` / ``cancelled`` /
``nonfinite`` / ``failed`` tracer instants).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.obs.registry import Registry, merged_prometheus, merged_snapshot
from repro.obs.trace import Tracer
from repro.serve.cache import PagedKVCache
from repro.serve.faults import FaultInjector, InjectedFault
from repro.serve.metrics import EngineStats, RequestMetrics
from repro.serve.propose import NGramProposer, Proposer
from repro.serve.sampling import (SamplingParams, guard_nonfinite,
                                  make_verifier)
from repro.serve.scheduler import DECODE, PREFILL, Request, Scheduler

PyTree = Any

#: tracer track ids: tid 0 is the engine-phase track, slot b is 1 + b
TID_ENGINE = 0


def _slot_tid(slot_id: int) -> int:
    return 1 + slot_id


class EngineOverloaded(RuntimeError):
    """Typed backpressure from ``submit()`` when the bounded queue is
    full (``ServeEngine(max_queue=...)``).

    Carries ``queue_depth`` (requests waiting), ``max_queue``, and
    ``est_wait_s`` — a rough admission estimate (pending token work /
    observed throughput; None before any throughput history) — so a
    client can back off intelligently instead of retrying hot.
    """

    def __init__(self, queue_depth: int, max_queue: int,
                 est_wait_s: Optional[float] = None):
        self.queue_depth = queue_depth
        self.max_queue = max_queue
        self.est_wait_s = est_wait_s
        eta = ("no throughput history yet" if est_wait_s is None
               else f"~{est_wait_s:.2f}s of queued work ahead")
        super().__init__(
            f"engine overloaded: {queue_depth} requests waiting "
            f"(max_queue={max_queue}), {eta} — back off and resubmit")


@dataclasses.dataclass
class RequestResult:
    """A finished request: generated tokens + lifecycle metrics.

    ``status`` is the request's terminal disposition — partial output is
    always delivered alongside it, never dropped:

    - ``"ok"`` — ran to completion (``max_new`` tokens).  This includes
      requests that were preempted and recomputed along the way
      (``metrics.preemptions`` counts the evictions; greedy output is
      token-identical to an unpreempted run).
    - ``"cancelled"`` — retired by :meth:`ServeEngine.cancel` at a tick
      boundary; ``tokens`` holds whatever had been generated.
    - ``"timeout"`` — its ``deadline_ms`` passed; partial tokens.
    - ``"failed"`` — killed by the nonfinite-logit guard or a device-step
      / commit error; ``metrics.error`` says why.
    """
    request_id: int
    prompt: List[int]
    tokens: List[int]
    metrics: RequestMetrics
    status: str = "ok"


class ServeEngine:
    """Mixed-precision inference engine over the paged state pool.

    ``submit()`` enqueues requests; ``step()`` runs one scheduler tick
    (admit -> one mixed prefill+decode batch step with window
    verification -> retire finished); ``drain()`` steps until idle and
    returns results ordered by request id.  ``max_batched_tokens`` bounds
    the real tokens per step (committed decode tokens are planned first;
    draft windows and prefill chunks fill the remainder).
    ``spec_tokens`` sets the speculative window (0 disables);
    ``proposer`` overrides the default n-gram prompt-lookup drafter.

    ``prefix_cache=True`` turns on refcounted prefix-page sharing in the
    pool (pure-attention stacks; see :mod:`repro.serve.cache`): requests
    whose prompts begin with resident committed pages admit with those
    pages mapped shared into their tables and skip the cached prefix in
    prefill — ``RequestResult.metrics.cached_prefix_tokens`` counts the
    absorbed work, and ``serve_prefix_hits_total`` /
    ``serve_prefix_miss_total`` / ``serve_cow_copies_total`` /
    ``serve_pages_shared`` track the sharing layer.  Greedy output is
    token-identical with the flag on or off.

    Resilience knobs: ``max_queue`` bounds admission (``submit()`` raises
    :class:`EngineOverloaded` instead of queueing unboundedly);
    ``preempt`` enables eviction-and-recompute of the youngest decoding
    slot under pool pressure (on by default — with a default-sized pool
    it can never fire); ``submit(deadline_ms=...)`` and ``cancel(rid)``
    retire requests at tick boundaries with partial output (statuses on
    :class:`RequestResult`); every step's window logits pass a
    nonfinite guard that fails only the poisoned request.  ``faults``
    accepts a :class:`~repro.serve.faults.FaultInjector` (chaos
    testing); ``clock`` an alternative ``time.perf_counter`` (deadline
    tests use :class:`~repro.serve.faults.FakeClock` — defaults to the
    injector's clock when it has one).  ``journal`` accepts a
    :class:`~repro.obs.journal.JournalRecorder`: the flight recorder
    event-sources the drive for deterministic replay and postmortem
    analysis (see the module docstring and :mod:`repro.obs.journal`).
    """

    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 n_slots: int = 4, max_seq: int = 256,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 chunk_size: int = 32,
                 max_batched_tokens: Optional[int] = None,
                 sampling: SamplingParams = SamplingParams(),
                 spec_tokens: int = 0,
                 proposer: Optional[Proposer] = None,
                 use_kernel: bool = False, pages_per_block: int = 1,
                 kv_dtype="bf16", seed: int = 0,
                 prefix_cache: bool = False,
                 max_queue: Optional[int] = None,
                 preempt: bool = True,
                 faults: Optional[FaultInjector] = None,
                 clock: Optional[Callable[[], float]] = None,
                 registry: Optional[Registry] = None,
                 tracer: Optional[Tracer] = None,
                 journal=None):
        if not cfg.supports_decode():
            raise ValueError(
                f"{cfg.name} ({cfg.family}) does not support decode — "
                f"serving needs a causal LM stack")
        # fail fast (and with the layer kind named) before any state is
        # allocated, instead of a trace-time error from serve_forward
        tfm._require_paged_support(cfg)
        self.cfg = cfg
        self.params = params
        # engine-level telemetry is always on (host ints, zero device
        # cost); the tracer is opt-in.  EngineStats keeps a *separate*
        # registry so `engine.stats = EngineStats(n)` resets cleanly.
        self.registry = registry if registry is not None else Registry()
        self.tracer = tracer
        if tracer is not None:
            tracer.thread_name(TID_ENGINE, "engine")
            for b in range(n_slots):
                tracer.thread_name(_slot_tid(b), f"slot {b}")
        if hasattr(kv_dtype, "kv_dtype"):     # a core.policy.Policy
            kv_dtype = kv_dtype.kv_dtype
        self.kv_format = quant.resolve(kv_dtype)
        self.spec_tokens = int(spec_tokens)
        if proposer is not None and self.spec_tokens == 0:
            raise ValueError(
                "a proposer without spec_tokens > 0 would never be "
                "consulted — pass spec_tokens=k to size the speculative "
                "window")
        recurrent = sorted(set(cfg.layer_kinds()) & {"rglru", "ssd"})
        if self.spec_tokens > 0 and recurrent:
            raise ValueError(
                f"spec_tokens={self.spec_tokens}: speculative windows "
                f"need the state layer to roll back rejected draft "
                f"positions, and {cfg.name}'s "
                f"{', '.join(repr(k) for k in recurrent)} layer(s) carry "
                f"O(1) recurrent slot state that only moves forward — "
                f"there is no written-watermark to truncate back to the "
                f"way KV pages have.  Serve this model with "
                f"spec_tokens=0 (snapshot-and-restore of recurrent state "
                f"on rejection is the named follow-on).")
        if self.spec_tokens > 0 and proposer is None:
            proposer = NGramProposer()
        self.proposer = proposer
        self.cache = PagedKVCache(cfg, n_slots, max_seq,
                                  page_size=page_size, num_pages=num_pages,
                                  kv_dtype=self.kv_format,
                                  prefix_cache=prefix_cache,
                                  registry=self.registry)
        self.scheduler = Scheduler(self.cache, chunk_size=chunk_size,
                                   max_batched_tokens=max_batched_tokens,
                                   spec_tokens=self.spec_tokens,
                                   proposer=self.proposer,
                                   preempt=preempt,
                                   registry=self.registry)
        self.sampling = sampling
        self.stats = EngineStats(n_slots)
        self._key = jax.random.key(seed)
        self._next_id = 0
        self._inflight: dict[int, RequestMetrics] = {}
        self._results: List[RequestResult] = []
        self._result_ids: set[int] = set()   # finished, kept for drain()
        # drain()'s no-progress guard reads these per-tick flags
        self._last_tick_admitted = False
        self._last_tick_stepped = False
        # resilience state: bounded admission, deadlines/cancellation at
        # tick boundaries, fault injection, injectable clock
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1: {max_queue}")
        self.max_queue = max_queue
        self.faults = faults
        if clock is None and faults is not None and faults.clock is not None:
            clock = faults.clock
        self._clock: Callable[[], float] = (clock if clock is not None
                                            else time.perf_counter)
        # flight recorder (repro.obs.journal — duck-typed so the replay
        # hook plugs in the same seam): wrap the clock FIRST so every
        # sample the engine ever draws is journaled, then hand over the
        # config fingerprint + fault schedule for the header
        self.journal = journal
        if journal is not None:
            self._clock = journal.wrap_clock(self._clock)
            journal.on_attach(
                {"config": dataclasses.asdict(cfg),
                 "engine": {
                     "n_slots": n_slots, "max_seq": max_seq,
                     "page_size": page_size,
                     "num_pages": self.cache.num_pages,
                     "chunk_size": chunk_size,
                     "max_batched_tokens": max_batched_tokens,
                     "sampling": dataclasses.asdict(sampling),
                     "spec_tokens": self.spec_tokens,
                     "proposer": (None if self.proposer is None
                                  else type(self.proposer).__name__),
                     "use_kernel": bool(use_kernel),
                     "pages_per_block": pages_per_block,
                     "kv_dtype": self.kv_format.name,
                     "seed": seed,
                     "prefix_cache": self.cache.prefix_cache,
                     "max_queue": max_queue,
                     "preempt": bool(preempt)}},
                faults)
        self._deadlines: dict[int, float] = {}   # rid -> absolute expiry
        self._cancelled: set[int] = set()        # applied at tick start
        # the always-present poison operand for the jitted step (host
        # numpy, built once — jnp.asarray per step is a host->device
        # transfer, not a sync; the test_obs transfer pin counts only
        # device->host np.asarray calls)
        self._zero_poison = np.zeros(n_slots, np.bool_)
        self._timeouts = self.registry.counter(
            "serve_timeouts_total", "requests retired at their deadline")
        self._cancels = self.registry.counter(
            "serve_cancelled_total", "requests cancelled by the client")
        self._nonfinite = self.registry.counter(
            "serve_nonfinite_total",
            "requests failed by the nonfinite-logit guard")
        self._failures = self.registry.counter(
            "serve_failed_total",
            "requests failed by a device-step or commit error "
            "(includes nonfinite-guard kills)")

        verifier = make_verifier(sampling)

        def raw_step(params, pages, table, tokens, start, valid,
                     logit_idx, draft, draft_len, poison, key):
            # serve_forward returns the (B, W, V) window logits named by
            # logit_idx — the unembed runs once per window position, not
            # per chunk position; verification/sampling runs in fp32
            logits, new_pages = tfm.serve_forward(
                params, cfg, pages, table, tokens, start, valid,
                logit_idx=logit_idx, page_size=page_size,
                use_kernel=use_kernel, pages_per_block=pages_per_block,
                kv_format=self.kv_format.name)
            # fault seam: NaN-poison the masked slots' windows *before*
            # verification, so injected poison exercises the exact guard
            # path a real quantized-overflow NaN would take
            logits = jnp.where(poison[:, None, None], jnp.nan, logits)
            accept, token = verifier(logits, draft, draft_len, key)
            # nonfinite-logit guard: verdict rides the two (B,) arrays
            # already transferred (token -1 = failure sentinel) — zero
            # added syncs
            accept, token = guard_nonfinite(logits, accept, token)
            return accept, token, new_pages

        # one compiled step shape AND program: (B, chunk_size) for
        # prefill, decode and mixed plans alike — the paged kernel covers
        # every plan, and the W-wide verify covers spec_tokens = 0 (W=1,
        # zero drafts) through full windows with no extra specialization.
        self._device_step = jax.jit(raw_step, donate_argnums=(1,))

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: List[int], max_new: int = 32,
               request_id: Optional[int] = None,
               deadline_ms: Optional[float] = None) -> int:
        """Enqueue a request; returns its id.

        An explicit ``request_id`` colliding with a queued, in-flight, or
        already-finished request is rejected — a duplicate would corrupt
        that request's metrics entry and collide in ``drain()``'s
        id-sorted results (results accumulate for the engine's lifetime).

        ``deadline_ms`` caps end-to-end latency: at the first tick
        boundary at or past the deadline the request is retired with
        status ``"timeout"`` and whatever tokens it has.  With
        ``max_queue`` configured, a full waiting queue raises
        :class:`EngineOverloaded` (typed backpressure carrying queue
        depth and an admission estimate) before any state is touched.
        """
        # fail fast on a stub proposer: plan() would otherwise raise mid-
        # step, after this request reserved pages and entered a batch —
        # a traceback from inside the scheduler instead of an actionable
        # "this is a follow-on" at the API boundary
        unimplemented = getattr(self.proposer, "unimplemented", None)
        if unimplemented:
            raise NotImplementedError(unimplemented)
        if (self.max_queue is not None
                and len(self.scheduler.waiting) >= self.max_queue):
            raise EngineOverloaded(len(self.scheduler.waiting),
                                   self.max_queue,
                                   self._admission_estimate())
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0: {deadline_ms}")
        rid = self._next_id if request_id is None else request_id
        if rid in self._inflight or rid in self._result_ids:
            raise ValueError(
                f"request id {rid} is already queued, in flight, or "
                f"finished — engine request ids are single-use")
        self.scheduler.submit(Request(rid, list(prompt), max_new))
        self._next_id = max(self._next_id, rid) + 1
        now = self._clock()
        self._inflight[rid] = RequestMetrics(
            request_id=rid, prompt_len=len(prompt), submit_time=now)
        if deadline_ms is not None:
            self._deadlines[rid] = now + deadline_ms / 1e3
        if self.journal is not None:
            self.journal.record_submit(rid, prompt, max_new, deadline_ms)
        if self.tracer is not None:
            self.tracer.instant("submit", tid=TID_ENGINE, rid=rid,
                                prompt_len=len(prompt), max_new=max_new)
        return rid

    def cancel(self, rid: int) -> bool:
        """Request cancellation of a queued or in-flight request.

        Enforced at the next tick boundary: the request is retired with
        status ``"cancelled"`` and its partial output delivered.  Returns
        False for ids that are unknown or already finished (cancellation
        raced completion — the existing result stands).
        """
        if rid not in self._inflight:
            return False
        self._cancelled.add(rid)
        if self.journal is not None:
            self.journal.record_cancel(rid)
        return True

    def _admission_estimate(self) -> Optional[float]:
        """Rough seconds of queued+running token work ahead of a new
        request, from observed throughput (None without history)."""
        if self.stats.elapsed <= 0 or self.stats.total_new_tokens == 0:
            return None
        pending = sum(s.req.max_new - len(s.out)
                      for s in self.scheduler.slots if s is not None)
        pending += sum(r.max_new - len(r.resume_out or [])
                       for r in self.scheduler.waiting)
        return pending / self.stats.throughput_tok_per_s

    def step(self) -> List[RequestResult]:
        """One scheduler tick.  Returns requests that finished this step
        — by completion or by any resilience path (cancellation, deadline
        expiry, the nonfinite guard, a device failure: see
        :class:`RequestResult.status`).  Cancel/deadline sweeps run at
        the tick boundary, before admission, so an expired slot's pages
        are reclaimed in time for this tick's admissions.

        ``EngineStats.elapsed`` covers the **full** tick — admission
        through commit — so host-side scheduler work is charged to the
        step it belongs to and ``tok_per_s`` cannot flatter the engine by
        excluding it (regression-tested against ``drain()`` wall time).
        """
        tr = self.tracer
        t0 = self._clock()
        tick_us = tr.now_us() if tr is not None else 0.0
        if self.faults is not None:
            self.faults.begin_tick(self.cache)
        results: List[RequestResult] = []
        self._sweep_cancelled(results)
        self._sweep_deadlines(results)
        admitted, preempted = self.scheduler.admit()
        self._last_tick_admitted = bool(admitted)
        if self.cache.prefix_cache and admitted:
            # a slot admitted mid-feed got its prefix from shared pages:
            # the skip (slot.fed at admission, before any plan) is the
            # request's prefill work the cache absorbed
            admitted_set = set(admitted)
            for slot in self.scheduler.slots:
                if (slot is not None
                        and slot.req.request_id in admitted_set
                        and slot.fed > 0):
                    self._inflight[slot.req.request_id] \
                        .cached_prefix_tokens += slot.fed
        for rid in preempted:
            rm = self._inflight[rid]
            rm.preemptions += 1
            rm.last_evict_time = t0
            if tr is not None:
                tr.instant("preempt", tid=TID_ENGINE, rid=rid)
        # phase bookkeeping: first admission ends queue wait; a
        # re-admission after preemption closes the preempted-recompute gap
        # (processed after the preempted loop so a same-tick
        # evict-and-readmit charges zero preempted time)
        for rid in admitted:
            rm = self._inflight[rid]
            if rm.admit_time is None:
                rm.admit_time = t0
            if rm.last_evict_time is not None:
                rm.preempted_seconds += t0 - rm.last_evict_time
                rm.last_evict_time = None
        if tr is not None:
            t_admit = tr.now_us()
            tr.complete("admit", tick_us, t_admit - tick_us,
                        tid=TID_ENGINE,
                        args={"admitted": list(admitted),
                              "preempted": list(preempted)})
            for rid in admitted:
                tr.instant("admit", tid=TID_ENGINE, rid=rid)
        if self.scheduler.busy_slots == 0:
            self._last_tick_stepped = False
            self._journal_tick("idle", admitted, preempted, results)
            return results
        self._last_tick_stepped = True
        if tr is not None:
            plan_us = tr.now_us()
        plan = self.scheduler.plan()
        if self.sampling.is_greedy:
            key = self._key
        else:
            self._key, key = jax.random.split(self._key)
        slot_rids = [None if s is None else s.req.request_id
                     for s in self.scheduler.slots]
        # pre-commit slot snapshot: if commit() raises partway, the
        # cleanup path still knows each request's partial output
        slot_objs = list(self.scheduler.slots)
        poison = (self.faults.poison_mask(slot_rids)
                  if self.faults is not None else self._zero_poison)
        if tr is not None:
            dev_us = tr.now_us()
            tr.complete("plan", plan_us, dev_us - plan_us, tid=TID_ENGINE,
                        args=plan.summary())
        try:
            if self.faults is not None:
                # raised before the device call, while the donated page
                # buffers are still intact
                self.faults.maybe_fail_step()
            # dispatch pending copy-on-write page copies (queued by
            # admission / note_write) before the step can write into the
            # copies' target pages — async, no host sync
            self.cache.flush_cow()
            accept, token, self.cache.pages = self._device_step(
                self.params, self.cache.pages, self.cache.table_device(),
                jnp.asarray(plan.tokens), jnp.asarray(plan.start),
                jnp.asarray(plan.valid), jnp.asarray(plan.logit_idx),
                jnp.asarray(plan.draft), jnp.asarray(plan.draft_len),
                jnp.asarray(poison), key)
            if tr is not None:
                sync_us = tr.now_us()
                tr.complete("device step", dev_us, sync_us - dev_us,
                            tid=TID_ENGINE, args={"kind": plan.kind})
            accept = np.asarray(accept)               # blocks on the device
            token = np.asarray(token)
            now = self._clock()
            if tr is not None:
                commit_us = tr.now_us()
                tr.complete("host sync", sync_us, commit_us - sync_us,
                            tid=TID_ENGINE)

            # per-request speculation accounting, against the pre-commit
            # slot->request mapping (commit retires finished slots)
            for slot_id, rid in enumerate(slot_rids):
                k = int(plan.draft_len[slot_id])
                if rid is None or k == 0:
                    continue
                rm = self._inflight[rid]
                rm.proposed_tokens += k
                rm.accepted_tokens += int(accept[slot_id])

            # nonfinite-guard verdicts: token -1 flags a slot whose
            # window logits held NaN/Inf.  Fail just that request —
            # slot retired, pages reclaimed, partial output delivered —
            # and zero its plan entry so commit() skips it; the rest of
            # the batch continues untouched.
            for slot_id, rid in enumerate(slot_rids):
                if (rid is None or plan.valid[slot_id] == 0
                        or token[slot_id] >= 0):
                    continue
                self._nonfinite.inc()
                if tr is not None:
                    tr.instant("nonfinite", tid=_slot_tid(slot_id),
                               rid=rid)
                slot = self.scheduler.evict(slot_id)
                results.append(self._finish_request(
                    rid, slot.req.prompt, list(slot.out), "failed", now,
                    error="nonfinite logits in decode window"))
                plan.valid[slot_id] = 0

            outcome = self.scheduler.commit(plan, token, accept)
        except Exception as err:
            # commit/retire discipline under mid-tick failure: every
            # request the plan touched is failed + retired, so an
            # exception here can never leak pages or leave a slot busy
            results.extend(self._fail_plan(plan, slot_rids, slot_objs,
                                           err, self._clock()))
            if isinstance(err, InjectedFault):
                # scripted fault: keep serving.  The tick is journaled
                # (deterministic — the schedule is in the header); a real
                # exception re-raises WITHOUT a tick record, so replay
                # knows the final results belong to an aborted tick.
                self._journal_tick("fault", admitted, preempted, results,
                                   plan=plan)
                return results
            raise
        first = set(outcome.first_token)
        for rid, _ in outcome.emitted:
            rm = self._inflight[rid]
            if rid in first:
                rm.first_token_time = now
            else:
                # one gap per request per step: a speculative window's
                # tokens arrive together, so the gap spans the whole batch
                self.stats.record_token_gap(now - rm.last_token_time)
            rm.last_token_time = now
        for _, slot in outcome.finished:
            results.append(self._finish_request(
                slot.req.request_id, slot.req.prompt, slot.out, "ok", now))
        if tr is not None:
            end_us = tr.now_us()
            tr.complete("commit", commit_us, end_us - commit_us,
                        tid=TID_ENGINE,
                        args={"emitted": outcome.n_tokens,
                              "finished": len(outcome.finished)})
            tr.complete("tick", tick_us, end_us - tick_us, tid=TID_ENGINE,
                        args={"kind": plan.kind})
            self._trace_slots(plan, slot_rids, accept, outcome,
                              dev_us, sync_us)
        t_end = self._clock()
        self.stats.record_step(
            plan.kind, self.scheduler.busy_slots + len(outcome.finished),
            outcome.n_tokens, t_end - t0,
            prefill_tokens=np.where(plan.kinds == PREFILL, plan.valid, 0),
            decode_tokens=np.where(plan.kinds == DECODE, plan.valid, 0),
            proposed=plan.n_draft,
            accepted=int(accept.sum()))
        self._journal_tick(plan.kind, admitted, preempted, results,
                           plan=plan, slot_rids=slot_rids, accept=accept,
                           token=token, outcome=outcome)
        return results

    # -- resilience internals -----------------------------------------------

    def _journal_tick(self, kind: str, admitted, preempted, results,
                      plan=None, slot_rids=None, accept=None, token=None,
                      outcome=None) -> None:
        """Feed the flight recorder one tick's digest.

        Reads only host-side state: the plan summary, the scheduler's
        admit/preempt lists, pool/prefix counters (host ints on the
        cache), and the two already-transferred ``(B,)`` arrays — like
        the tracer, zero added device syncs (the test_obs transfer pin
        runs with the journal enabled).
        """
        if self.journal is None:
            return
        c = self.cache
        digest = {"kind": kind,
                  "admitted": list(admitted), "preempted": list(preempted),
                  "tokens": plan.n_tokens if plan is not None else 0,
                  "drafts": plan.n_draft if plan is not None else 0,
                  "accepted": int(accept.sum()) if accept is not None else 0,
                  "emitted": outcome.n_tokens if outcome is not None else 0,
                  "finished": [[r.request_id, r.status] for r in results],
                  "pool": [c.free_pages, c.used_pages, c.cached_pages,
                           c.shared_pages, c.held_pages],
                  "prefix": [c.prefix_hits, c.prefix_misses, c.cow_copies]}
        tok_items = []
        if token is not None:
            for slot_id, rid in enumerate(slot_rids):
                if rid is None or plan.valid[slot_id] == 0:
                    continue
                tok_items.append((slot_id, rid, int(token[slot_id]),
                                  int(accept[slot_id])))
        self.journal.record_tick(digest, tok_items)

    def _finish_request(self, rid: int, prompt: List[int],
                        tokens: List[int], status: str, now: float,
                        error: Optional[str] = None) -> RequestResult:
        """The single exit point for every terminal status: retire the
        request's engine-side bookkeeping and deliver its result (partial
        output included — never dropped)."""
        rm = self._inflight.pop(rid)
        self._result_ids.add(rid)
        self._deadlines.pop(rid, None)
        self._cancelled.discard(rid)
        rm.finish_time = now
        rm.new_tokens = len(tokens)
        if error is not None:
            rm.error = error
        self.stats.record_finish(rm)
        counter = {"cancelled": self._cancels, "timeout": self._timeouts,
                   "failed": self._failures}.get(status)
        if counter is not None:
            counter.inc()
        if self.tracer is not None and status != "ok":
            self.tracer.instant(status, tid=TID_ENGINE, rid=rid)
        result = RequestResult(rid, list(prompt), list(tokens), rm, status)
        self._results.append(result)
        if self.journal is not None:
            self.journal.record_result(result)
        return result

    def _terminate(self, rid: int, status: str, now: float,
                   error: Optional[str] = None) -> RequestResult:
        """Retire a queued or in-flight request before completion —
        reclaiming its slot and pages — with a terminal status."""
        req = self.scheduler.remove_waiting(rid)
        if req is not None:
            # still queued; a preempted requeue carries partial output
            return self._finish_request(rid, req.prompt,
                                        list(req.resume_out or []),
                                        status, now, error=error)
        for slot_id, slot in enumerate(self.scheduler.slots):
            if slot is not None and slot.req.request_id == rid:
                self.scheduler.evict(slot_id)
                return self._finish_request(rid, slot.req.prompt,
                                            list(slot.out), status, now,
                                            error=error)
        raise RuntimeError(
            f"request {rid} is tracked as in flight but sits in no "
            f"queue or slot — engine/scheduler bookkeeping diverged")

    def _sweep_cancelled(self, results: List[RequestResult]) -> None:
        """Apply pending cancel() calls at the tick boundary."""
        if not self._cancelled:
            return
        now = self._clock()
        for rid in sorted(self._cancelled):
            if rid in self._inflight:
                results.append(self._terminate(rid, "cancelled", now))
        self._cancelled.clear()

    def _sweep_deadlines(self, results: List[RequestResult]) -> None:
        """Retire every request whose deadline has passed (status
        "timeout", partial output delivered)."""
        if not self._deadlines:
            return
        now = self._clock()
        expired = [rid for rid, t in self._deadlines.items()
                   if now >= t and rid in self._inflight]
        for rid in expired:
            results.append(self._terminate(
                rid, "timeout", now,
                error=f"deadline exceeded at t={now:.3f}"))

    def _fail_plan(self, plan, slot_rids, slot_objs, err: Exception,
                   now: float) -> List[RequestResult]:
        """Cleanup after an exception between the device step and the end
        of commit: every request the plan touched is failed and its slot
        retired.  Requests commit() finished before raising lost their
        outcome with the exception, so they are failed too, with the
        partial output the pre-commit snapshot recorded."""
        failed = []
        for slot_id, rid in enumerate(slot_rids):
            if rid is None or plan.valid[slot_id] == 0:
                continue
            if rid not in self._inflight:
                continue               # finished before the exception
            slot = self.scheduler.slots[slot_id]
            if slot is not None and slot.req.request_id == rid:
                self.scheduler.evict(slot_id)
                tokens = list(slot.out)
            else:
                # commit retired the slot before raising — fall back to
                # the snapshot's view of the partial output
                tokens = list(slot_objs[slot_id].out)
            failed.append(self._finish_request(
                rid, slot_objs[slot_id].req.prompt, tokens, "failed",
                now, error=f"{type(err).__name__}: {err}"))
        return failed

    def _trace_slots(self, plan, slot_rids, accept, outcome,
                     dev_us: float, sync_us: float) -> None:
        """Per-slot lifecycle events for one tick.

        Each live slot gets an "X" span over the device-step interval on
        its own track (Perfetto renders a per-slot timeline); decode
        spans carry the window's draft/accept counts, rejected tails get
        a ``truncate`` instant, retiring slots a ``retire`` instant.
        Reads only the host-side plan and the already-transferred
        ``accept`` array — no device access.
        """
        tr = self.tracer
        dur = sync_us - dev_us
        finished = {slot_id for slot_id, _ in outcome.finished}
        for slot_id, rid in enumerate(slot_rids):
            if rid is None or plan.valid[slot_id] == 0:
                continue
            tid = _slot_tid(slot_id)
            if plan.kinds[slot_id] == PREFILL:
                tr.complete("prefill", dev_us, dur, tid=tid,
                            args={"rid": rid,
                                  "tokens": int(plan.valid[slot_id]),
                                  "start": int(plan.start[slot_id])})
            else:
                k = int(plan.draft_len[slot_id])
                acc = int(accept[slot_id])
                tr.complete("decode", dev_us, dur, tid=tid,
                            args={"rid": rid,
                                  "tokens": int(plan.valid[slot_id]),
                                  "drafts": k, "accepted": acc})
                if k > acc:
                    tr.instant("truncate", tid=tid,
                               rid=rid, rejected=k - acc)
            if slot_id in finished:
                tr.instant("retire", tid=tid, rid=rid)

    def drain(self) -> List[RequestResult]:
        """Run until queue and slots are empty; all results, by id.

        Guards against the no-progress spin: if a full tick admits
        nothing, runs no device step, and retires nothing while requests
        are still waiting, no future tick can differ (admission is the
        only way forward and its inputs didn't change) — raise an
        actionable error naming the stuck requests instead of looping
        forever.  Two resilience carve-outs: a request stuck only because
        its deadline expired is *swept* (status "timeout") rather than
        spun on — the sweep counts as progress and drain terminates —
        and a fault injector with events still scheduled counts as
        progress too (a scripted exhaustion window lifts at its
        scheduled tick).
        """
        while self.scheduler.has_work:
            n_results = len(self._results)
            self.step()
            progressed = (self._last_tick_admitted
                          or self._last_tick_stepped
                          or len(self._results) > n_results
                          or (self.faults is not None
                              and self.faults.pending))
            if not progressed:
                stuck = [r.request_id for r in self.scheduler.waiting]
                held = self.cache.held_pages
                hint = (f"  ({held} pages are held by fault injection "
                        f"with no scheduled release.)" if held else "")
                raise RuntimeError(
                    f"ServeEngine.drain(): no progress — tick admitted "
                    f"nothing, stepped nothing, and retired nothing, but "
                    f"requests {stuck} are still waiting.  The head "
                    f"request cannot fit the page pool "
                    f"({self.cache.free_pages} of {self.cache.num_pages} "
                    f"pages free, {self.cache.max_pages_per_slot} max per "
                    f"slot); submit() should have rejected it — if it "
                    f"was enqueued by other means, resize the pool or "
                    f"split the request.{hint}")
        return sorted(self._results, key=lambda r: r.request_id)

    # -- telemetry exports --------------------------------------------------

    def metrics_snapshot(self) -> dict:
        """Flat dict over the engine registry (queue/pool/admissions)
        and the stats registry (steps/tokens/latency histograms)."""
        return merged_snapshot(self.registry, self.stats.registry)

    def prometheus(self) -> str:
        """Prometheus text exposition of both registries (the
        ``--metrics-out`` artifact)."""
        return merged_prometheus(self.registry, self.stats.registry)
