"""The ServeEngine facade: submit() / step() / drain().

Ties the subsystem together: the paged KV cache (device pools + host
allocator), the mixed-chunk continuous-batching scheduler (host plans),
ONE jitted ``(B, chunk_size)`` specialization of the unified
``serve_forward`` step — every tick is a mixed plan in which each active
slot contributes either a prefill chunk or its single pending decode token,
so there are no separate prefill/decode compiled shapes and decode slots
never stall behind a long prompt — and fp32 sampling from each slot's last
valid chunk position.  Per-request TTFT and inter-token latency plus
aggregate throughput/occupancy are recorded around every device call.

When ``use_kernel`` is set, EVERY step — prefill, decode and mixed alike —
routes attention through the Pallas paged-attention kernel
(``repro.kernels.paged_attention``): the page table is a scalar-prefetch
operand and the kernel streams each slot's allocated pages straight from
the shared pools, so the per-step gathered dense copy of the cache never
exists and there is still exactly one compiled step program.

Precision: params are expected pre-cast to the serving dtype (bf16); the
KV pages are bf16; softmax inside the model and the sampling transform are
fp32 — the inference half of the MPX discipline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.serve.cache import PagedKVCache
from repro.serve.metrics import EngineStats, RequestMetrics
from repro.serve.sampling import SamplingParams, make_sampler
from repro.serve.scheduler import DECODE, PREFILL, Request, Scheduler

PyTree = Any


@dataclasses.dataclass
class RequestResult:
    """A finished request: generated tokens + lifecycle metrics."""
    request_id: int
    prompt: List[int]
    tokens: List[int]
    metrics: RequestMetrics


class ServeEngine:
    """Mixed-precision inference engine with paged KV cache.

    ``submit()`` enqueues requests; ``step()`` runs one scheduler tick
    (admit -> one mixed prefill+decode batch step -> retire finished);
    ``drain()`` steps until idle and returns results ordered by request id.
    ``max_batched_tokens`` bounds the real tokens per step (decode tokens
    are planned first; prefill chunks fill the remainder).
    """

    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 n_slots: int = 4, max_seq: int = 256,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 chunk_size: int = 32,
                 max_batched_tokens: Optional[int] = None,
                 sampling: SamplingParams = SamplingParams(),
                 use_kernel: bool = False, seed: int = 0):
        if not cfg.supports_decode():
            raise ValueError(f"{cfg.name} does not support decode")
        self.cfg = cfg
        self.params = params
        self.cache = PagedKVCache(cfg, n_slots, max_seq,
                                  page_size=page_size, num_pages=num_pages)
        self.scheduler = Scheduler(self.cache, chunk_size=chunk_size,
                                   max_batched_tokens=max_batched_tokens)
        self.sampling = sampling
        self.stats = EngineStats(n_slots)
        self._sampler = make_sampler(sampling)
        self._key = jax.random.key(seed)
        self._next_id = 0
        self._inflight: dict[int, RequestMetrics] = {}
        self._results: List[RequestResult] = []
        self._result_ids: set[int] = set()   # finished, kept for drain()

        sampler = self._sampler

        def raw_step(params, pages, table, tokens, start, valid, key):
            # serve_forward returns each slot's last-valid-position logits
            # (B, V) — the unembed already ran once per slot, not per
            # chunk position; sampling transforms run in fp32
            logits, new_pages = tfm.serve_forward(
                params, cfg, pages, table, tokens, start, valid,
                page_size=page_size, use_kernel=use_kernel)
            sampled = sampler(logits, key)
            return sampled, new_pages

        # one compiled step shape AND program: (B, chunk_size) for
        # prefill, decode and mixed plans alike — the paged kernel covers
        # every plan, so there is no decode-only specialization.
        self._device_step = jax.jit(raw_step, donate_argnums=(1,))

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: List[int], max_new: int = 32,
               request_id: Optional[int] = None) -> int:
        """Enqueue a request; returns its id.

        An explicit ``request_id`` colliding with a queued, in-flight, or
        already-finished request is rejected — a duplicate would corrupt
        that request's metrics entry and collide in ``drain()``'s
        id-sorted results (results accumulate for the engine's lifetime).
        """
        rid = self._next_id if request_id is None else request_id
        if rid in self._inflight or rid in self._result_ids:
            raise ValueError(
                f"request id {rid} is already queued, in flight, or "
                f"finished — engine request ids are single-use")
        self.scheduler.submit(Request(rid, list(prompt), max_new))
        self._next_id = max(self._next_id, rid) + 1
        self._inflight[rid] = RequestMetrics(
            request_id=rid, prompt_len=len(prompt),
            submit_time=time.perf_counter())
        return rid

    def step(self) -> List[RequestResult]:
        """One scheduler tick.  Returns requests that finished this step."""
        self.scheduler.admit()
        if self.scheduler.busy_slots == 0:
            return []
        t0 = time.perf_counter()
        plan = self.scheduler.plan()
        if self.sampling.is_greedy:
            key = self._key
        else:
            self._key, key = jax.random.split(self._key)
        sampled, self.cache.pages = self._device_step(
            self.params, self.cache.pages, self.cache.table_device(),
            jnp.asarray(plan.tokens), jnp.asarray(plan.start),
            jnp.asarray(plan.valid), key)
        sampled = np.asarray(sampled)                 # blocks on the device
        now = time.perf_counter()

        outcome = self.scheduler.commit(plan, sampled)
        first = set(outcome.first_token)
        for rid in outcome.emitted:
            rm = self._inflight[rid]
            if rid in first:
                rm.first_token_time = now
            else:
                self.stats.record_token_gap(now - rm.last_token_time)
            rm.last_token_time = now
        results = []
        for _, slot in outcome.finished:
            rm = self._inflight.pop(slot.req.request_id)
            self._result_ids.add(slot.req.request_id)
            rm.finish_time = now
            rm.new_tokens = len(slot.out)
            self.stats.record_finish(rm)
            results.append(RequestResult(slot.req.request_id,
                                         slot.req.prompt, slot.out, rm))
        self.stats.record_step(
            plan.kind, self.scheduler.busy_slots + len(outcome.finished),
            len(outcome.emitted), now - t0,
            prefill_tokens=np.where(plan.kinds == PREFILL, plan.valid, 0),
            decode_tokens=np.where(plan.kinds == DECODE, plan.valid, 0))
        self._results.extend(results)
        return results

    def drain(self) -> List[RequestResult]:
        """Run until queue and slots are empty; all results, by id."""
        while self.scheduler.has_work:
            self.step()
        return sorted(self._results, key=lambda r: r.request_id)
