"""The ServeEngine facade: submit() / step() / drain().

Ties the subsystem together: the paged KV cache (device pools + host
allocator), the continuous-batching scheduler (host plans), two jitted
specializations of the unified ``serve_forward`` step (a chunk-wide
prefill shape and a single-token decode shape — same traced function), and
fp32 sampling.  Per-request TTFT and aggregate throughput/occupancy are
recorded around every device call.

Precision: params are expected pre-cast to the serving dtype (bf16); the
KV pages are bf16; softmax inside the model and the sampling transform are
fp32 — the inference half of the MPX discipline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.serve.cache import PagedKVCache
from repro.serve.metrics import EngineStats, RequestMetrics
from repro.serve.sampling import SamplingParams, make_sampler
from repro.serve.scheduler import Request, Scheduler

PyTree = Any


@dataclasses.dataclass
class RequestResult:
    """A finished request: generated tokens + lifecycle metrics."""
    request_id: int
    prompt: List[int]
    tokens: List[int]
    metrics: RequestMetrics


class ServeEngine:
    """Mixed-precision inference engine with paged KV cache.

    ``submit()`` enqueues requests; ``step()`` runs one scheduler tick
    (admit -> one batched prefill chunk or decode step -> retire finished);
    ``drain()`` steps until idle and returns results ordered by request id.
    """

    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 n_slots: int = 4, max_seq: int = 256,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 chunk_size: int = 32,
                 sampling: SamplingParams = SamplingParams(),
                 use_kernel: bool = False, seed: int = 0):
        if not cfg.supports_decode():
            raise ValueError(f"{cfg.name} does not support decode")
        self.cfg = cfg
        self.params = params
        self.cache = PagedKVCache(cfg, n_slots, max_seq,
                                  page_size=page_size, num_pages=num_pages)
        self.scheduler = Scheduler(self.cache, chunk_size=chunk_size)
        self.sampling = sampling
        self.stats = EngineStats(n_slots)
        self._sampler = make_sampler(sampling)
        self._key = jax.random.key(seed)
        self._next_id = 0
        self._inflight: dict[int, RequestMetrics] = {}
        self._results: List[RequestResult] = []

        sampler = self._sampler

        def raw_step(params, pages, table, tokens, start, valid, key):
            logits, new_pages = tfm.serve_forward(
                params, cfg, pages, table, tokens, start, valid,
                page_size=page_size, use_kernel=use_kernel)
            # each slot samples from its last valid chunk position in fp32
            last = jnp.clip(valid - 1, 0)
            batch = jnp.arange(tokens.shape[0])
            sampled = sampler(logits[batch, last], key)
            return sampled, new_pages

        # one traced function, two compiled shapes: (B, chunk) and (B, 1)
        self._device_step = jax.jit(raw_step, donate_argnums=(1,))

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: List[int], max_new: int = 32,
               request_id: Optional[int] = None) -> int:
        """Enqueue a request; returns its id."""
        rid = self._next_id if request_id is None else request_id
        self._next_id = max(self._next_id, rid) + 1
        self.scheduler.submit(Request(rid, list(prompt), max_new))
        self._inflight[rid] = RequestMetrics(
            request_id=rid, prompt_len=len(prompt),
            submit_time=time.perf_counter())
        return rid

    def step(self) -> List[RequestResult]:
        """One scheduler tick.  Returns requests that finished this step."""
        self.scheduler.admit()
        if self.scheduler.busy_slots == 0:
            return []
        t0 = time.perf_counter()
        kind, tokens, start, valid = self.scheduler.plan()
        if self.sampling.is_greedy:
            key = self._key
        else:
            self._key, key = jax.random.split(self._key)
        sampled, self.cache.pages = self._device_step(
            self.params, self.cache.pages, self.cache.table_device(),
            jnp.asarray(tokens), jnp.asarray(start), jnp.asarray(valid),
            key)
        sampled = np.asarray(sampled)                 # blocks on the device
        now = time.perf_counter()

        first_ids, finished = self.scheduler.commit(kind, valid, sampled)
        for rid in first_ids:
            self._inflight[rid].first_token_time = now
        new_tokens = len(first_ids) if kind == "prefill" else int(
            (valid > 0).sum())
        results = []
        for _, slot in finished:
            rm = self._inflight.pop(slot.req.request_id)
            rm.finish_time = now
            rm.new_tokens = len(slot.out)
            self.stats.record_finish(rm)
            results.append(RequestResult(slot.req.request_id,
                                         slot.req.prompt, slot.out, rm))
        self.stats.record_step(kind, self.scheduler.busy_slots
                               + len(finished), new_tokens, now - t0)
        self._results.extend(results)
        return results

    def drain(self) -> List[RequestResult]:
        """Run until queue and slots are empty; all results, by id."""
        while self.scheduler.has_work:
            self.step()
        return sorted(self._results, key=lambda r: r.request_id)
