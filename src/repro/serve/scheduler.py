"""Continuous batching with mixed prefill+decode chunk steps and
speculative decode windows.

Host-side slot bookkeeping: a FIFO of waiting requests, ``n_slots``
decode slots, and per-step batch plans for the engine's jitted step.
Admission is FCFS with full-budget page reservation (see
:mod:`repro.serve.cache`); a finished request retires immediately and its
slot/pages are re-admitted the same step — the batch never drains to
refill, which is the whole point of continuous batching.

With the pool's prefix cache enabled, admission hands the candidate
slot's *feed* (prompt, or prompt + committed output for a recompute) to
:meth:`PagedKVCache.admit`, which maps any cached-prefix pages into the
slot's table and returns a committed skip — the slot starts with
``fed = length = skip`` and chunked prefill feeds only the uncached
tail.  ``commit()`` registers each slot's newly full committed pages in
the prefix index as they land (rolling per-page hash, O(new pages)).

Every step is one *mixed* ``(B, chunk_size)`` plan: each active slot
contributes either its next prefill chunk (a prompt runs through the model
``chunk_size`` tokens at a time via the batched ``serve_forward`` entry
point — one matmul over the chunk, not token-by-token decode) or its
decode *window*.  Without speculation the window is the single pending
decode token; with a :class:`~repro.serve.propose.Proposer` configured
(``spec_tokens > 0``) a decoding slot contributes up to ``1 + k`` tokens —
the committed token plus ``k`` host-proposed drafts — and the whole window
is verified by the model in the same batched step that would have decoded
one token.  ``commit()`` then keeps the accepted prefix (plus the
corrected/bonus token from rejection sampling) and rolls the slot's cache
length back over the rejected tail (:meth:`PagedKVCache.truncate` — the
dead KV positions are overwritten by the next window, no page churn).

Admission is backstopped by vLLM-style **preemption and recompute**:
when the pool can't cover the head request's reservation but a slot is
free, the youngest decoding slot is evicted — pages freed, recurrent
state claim dropped — and its request requeued with its committed tokens
as a recompute prefill (the ordinary chunked-prefill path re-feeds
prompt + output and resumes decoding exactly where it stopped; greedy
output is token-identical to the unpreempted run).  Long-prompt traffic
can therefore no longer wedge the engine behind in-flight decodes; the
cost is recomputing the victim's KV, which the engine counts
(``serve_preemptions_total``) and the bench prices
(``serving_preempt_recompute_overhead_pct``).

Decode slots keep emitting tokens while other slots are mid-prefill —
there is no prefill-priority phase in which in-flight generations stall
behind a long prompt (Orca-style iteration-level scheduling).  A per-step
token budget (``max_batched_tokens``, vLLM-style) bounds the total real
tokens in a step: each decode slot's committed token is planned first
(latency-critical, the budget always covers one per slot), then prefill
chunks, then speculative drafts from the genuinely spare remainder — a
window can never starve a prefilling slot of budget forever, and a
prefilling slot that gets no budget sits the step out (``valid = 0``)
and retries next step.
"""
from __future__ import annotations

import dataclasses
import inspect
from collections import deque
from typing import Deque, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.serve.cache import PagedKVCache
from repro.serve.propose import Proposer

#: per-slot step kinds in :class:`StepPlan.kinds`
IDLE, PREFILL, DECODE = 0, 1, 2


@dataclasses.dataclass
class Request:
    """One generation request.  ``prompt`` is a list of token ids.

    ``resume_out`` is set only on the requeued copy of a *preempted*
    request: the tokens it had already committed when its slot was
    evicted.  On re-admission the slot recomputes their KV through the
    ordinary chunked-prefill path (prompt + committed output re-fed as
    one long "prompt") and then resumes decoding exactly where it left
    off — the total token budget (``prompt + max_new``) is unchanged, so
    the page reservation is identical to the original admission.
    """
    request_id: int
    prompt: List[int]
    max_new: int = 32
    resume_out: Optional[List[int]] = None

    def __post_init__(self):
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1: {self.max_new}")


@dataclasses.dataclass
class _Slot:
    req: Request
    seq: int = 0          # admission sequence number (preemption order)
    fed: int = 0          # feed tokens written to the cache so far
    length: int = 0       # committed cached tokens (prompt + accepted gen)
    out: List[int] = dataclasses.field(default_factory=list)
    next_token: int = -1  # sampled but not yet fed to a decode step
    # prompt + out, maintained incrementally by commit() so the per-tick
    # proposer call costs O(new tokens), not an O(context) concat
    ctx: List[int] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.out = list(self.req.resume_out or [])
        self.resumed = bool(self.req.resume_out)
        self.ctx = list(self.req.prompt) + self.out
        # the token stream to (re)prefill.  For a fresh request: the
        # prompt.  For a preempted one: prompt + committed output minus
        # the final sampled token, whose KV was never written — it is
        # re-fed as the first decode token after the recompute prefill
        # (commit() restores it as next_token instead of sampling anew).
        self.feed = self.ctx[:-1] if self.resumed else list(self.req.prompt)

    def emit(self, tokens: List[int]) -> None:
        """Append committed generation tokens (keeps ctx == prompt+out)."""
        self.out.extend(tokens)
        self.ctx.extend(tokens)

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.feed)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.max_new


@dataclasses.dataclass
class StepPlan:
    """One mixed prefill+decode step over all slots.

    ``tokens`` is always ``(n_slots, chunk_size)`` — one compiled step
    shape.  ``kinds[b]`` says what slot ``b`` contributes (IDLE / PREFILL /
    DECODE); ``valid[b]`` is its real-token count (prefill: chunk length,
    decode: 1 + draft window, idle: 0).  ``draft`` / ``draft_len`` carry
    each decode slot's proposed tokens (fed at chunk columns ``1..k``) for
    the verify step's rejection sampler; ``logit_idx[b]`` names the chunk
    positions whose logits the step must return — the whole live window
    for a decode slot, the last valid position (broadcast) for prefill.
    ``decode_only`` is True when no slot prefills this step —
    informational (stats / tracing) since the paged-attention kernel
    covers prefill, decode and mixed plans with one program.
    """
    tokens: np.ndarray      # (B, C) int32
    start: np.ndarray       # (B,)   int32 absolute position of tokens[:, 0]
    valid: np.ndarray       # (B,)   int32 real tokens per slot
    kinds: np.ndarray       # (B,)   int8  IDLE | PREFILL | DECODE
    draft: np.ndarray       # (B, K) int32 proposed tokens (window cols 1..)
    draft_len: np.ndarray   # (B,)   int32 live drafts per slot
    logit_idx: np.ndarray   # (B, W) int32 chunk positions to unembed
    decode_only: bool

    @property
    def kind(self) -> str:
        """"prefill" / "decode" / "mixed" — for stats bucketing."""
        has_prefill = bool((self.kinds == PREFILL).any())
        has_decode = bool((self.kinds == DECODE).any())
        if has_prefill and has_decode:
            return "mixed"
        return "prefill" if has_prefill else "decode"

    @property
    def n_tokens(self) -> int:
        return int(self.valid.sum())

    @property
    def n_draft(self) -> int:
        return int(self.draft_len.sum())

    def summary(self) -> dict:
        """Host-int digest of the plan — the shared vocabulary of the
        tracer's ``plan`` span args and the flight-recorder journal's
        per-tick digest (:mod:`repro.obs.journal`)."""
        return {"kind": self.kind, "tokens": self.n_tokens,
                "drafts": self.n_draft}


@dataclasses.dataclass
class StepOutcome:
    """Host-side result of committing one step's verified tokens."""
    emitted: List[Tuple[int, int]]      # (request id, tokens gained)
    first_token: List[int]              # ids whose first token this step
    finished: List[Tuple[int, _Slot]]   # (slot_id, slot), already retired

    @property
    def n_tokens(self) -> int:
        return sum(n for _, n in self.emitted)


class Scheduler:
    """Admission, mixed-chunk planning, and completion bookkeeping."""

    def __init__(self, cache: PagedKVCache, chunk_size: int = 32,
                 max_batched_tokens: Optional[int] = None,
                 spec_tokens: int = 0,
                 proposer: Optional[Proposer] = None,
                 preempt: bool = True,
                 registry=None):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1: {chunk_size}")
        if spec_tokens < 0:
            raise ValueError(f"spec_tokens must be >= 0: {spec_tokens}")
        if spec_tokens + 1 > chunk_size:
            raise ValueError(
                f"speculative window {spec_tokens + 1} (spec_tokens + "
                f"committed token) must fit in chunk_size {chunk_size}")
        self.cache = cache
        self.n_slots = cache.n_slots
        self.chunk_size = chunk_size
        self.spec_tokens = spec_tokens
        self.proposer = proposer
        # pass request_id to proposers that accept it (NGramProposer keys
        # its incremental suffix index on it); plain (context, k)
        # proposers — e.g. test doubles — keep working unchanged
        self._propose_takes_id = False
        if proposer is not None:
            try:
                params = inspect.signature(proposer.propose).parameters
                self._propose_takes_id = "request_id" in params
            except (TypeError, ValueError):
                self._propose_takes_id = False
        if max_batched_tokens is None:
            # never throttles: every slot can contribute a full chunk
            max_batched_tokens = self.n_slots * chunk_size
        if max_batched_tokens < self.n_slots:
            # the budget must cover one decode token per slot, or a full
            # decode batch could never be planned in one step
            raise ValueError(
                f"max_batched_tokens {max_batched_tokens} must be >= "
                f"n_slots {self.n_slots}")
        self.max_batched_tokens = max_batched_tokens
        self.max_seq = cache.max_seq
        self.preempt = preempt
        self.waiting: Deque[Request] = deque()
        self.slots: List[Optional[_Slot]] = [None] * self.n_slots
        self._active_ids: Set[int] = set()   # queued or in-flight
        self._admit_seq = 0                  # preemption picks the youngest
        # telemetry (repro.obs): queue depth + admission counters, all
        # host ints updated where the bookkeeping already mutates
        self._queue_gauge = self._busy_gauge = None
        self._admissions = self._submitted = self._preemptions = None
        if registry is not None:
            self._queue_gauge = registry.gauge(
                "serve_queue_depth", "requests waiting for a slot")
            self._busy_gauge = registry.gauge(
                "serve_busy_slots", "slots holding an active request")
            self._admissions = registry.counter(
                "serve_admissions_total", "requests placed into slots")
            self._submitted = registry.counter(
                "serve_submitted_total", "requests accepted into the queue")
            self._preemptions = registry.counter(
                "serve_preemptions_total",
                "decoding slots evicted under pool pressure (recompute "
                "requeued)")

    # -- admission / eviction -----------------------------------------------

    def submit(self, req: Request) -> None:
        if req.request_id in self._active_ids:
            raise ValueError(
                f"request id {req.request_id} is already queued or in "
                f"flight — ids must be unique among active requests")
        total = len(req.prompt) + req.max_new
        if total > self.max_seq:
            raise ValueError(
                f"request {req.request_id}: prompt {len(req.prompt)} + "
                f"max_new {req.max_new} exceeds max_seq {self.max_seq}")
        if (self.cache.has_paged
                and self.cache.pages_for(total) > self.cache.num_pages):
            # would never be admittable: drain() would spin forever.
            # Page-free (pure recurrent) stacks have no pool to exhaust —
            # the max_seq check above is the only admission bound.
            raise ValueError(
                f"request {req.request_id}: needs "
                f"{self.cache.pages_for(total)} pages, pool has only "
                f"{self.cache.num_pages}")
        self.waiting.append(req)
        self._active_ids.add(req.request_id)
        if self._submitted is not None:
            self._submitted.inc()
            self._queue_gauge.set(len(self.waiting))

    def admit(self) -> Tuple[List[int], List[int]]:
        """Place waiting requests into free slots, FCFS; preempt under
        pool pressure.

        Stops at the first request whose page reservation doesn't fit
        (head-of-line order preserved — large requests are not starved by
        later small ones).  When the head can't fit but ``preempt`` is on,
        the youngest *decoding* slot whose pages would cover the shortfall
        is evicted first (at most one eviction per tick): its pages return
        to the pool, its recurrent state claim is dropped, and the request
        requeues just behind the head with its committed tokens carried as
        a recompute prefill (:attr:`Request.resume_out`).  Restricting
        victims to decoding (never prefilling) slots makes the worst-case
        ping-pong terminate: every preemption cycle the victim has
        committed at least one more token than the last time it ran.

        Returns ``(admitted request ids, preempted request ids)``.
        """
        admitted: List[int] = []
        preempted: List[int] = []
        for slot_id in range(self.n_slots):
            if self.slots[slot_id] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            total = len(req.prompt) + req.max_new
            # build the slot first: its feed (prompt, or prompt+committed
            # output for a recompute) is what the prefix index probes —
            # a hit maps shared pages into the table and tells us how
            # many feed tokens to skip (their KV is already resident)
            cand = _Slot(req, seq=self._admit_seq)
            ok = self.cache.admit(slot_id, total, feed=cand.feed)
            if not ok and self.preempt and not preempted:
                victim = self._preempt_victim(total)
                if victim is not None:
                    preempted.append(self._preempt(victim))
                    ok = self.cache.admit(slot_id, total, feed=cand.feed)
            if not ok:
                break
            skip = self.cache.slot_length(slot_id)
            cand.fed = skip
            cand.length = skip
            self.waiting.popleft()
            self.slots[slot_id] = cand
            self._admit_seq += 1
            admitted.append(req.request_id)
        if self._admissions is not None:
            if admitted:
                self._admissions.inc(len(admitted))
            self._queue_gauge.set(len(self.waiting))
            self._busy_gauge.set(self.busy_slots)
        return admitted, preempted

    def _preempt_victim(self, n_tokens: int) -> Optional[int]:
        """The youngest decoding slot whose pages, returned to the pool,
        would let a request of ``n_tokens`` total tokens admit; None when
        no such slot exists (caller then leaves the head waiting).

        A slot is only a victim once it has committed at least one token
        *beyond* what it resumed with — preemption terminates because
        every eviction strictly grows the victim's committed output.
        Without that guard two requests sharing a too-small pool
        ping-pong forever: a recompute prefill re-derives exactly the
        tokens it resumed with (its final sample is discarded), so the
        freshly resumed slot would look like a zero-progress victim
        again at the very next tick's admit.
        """
        if not self.cache.has_paged:
            return None      # page-free stacks have no pool to pressure
        need = self.cache.pages_for(n_tokens)
        if need > self.cache.max_pages_per_slot:
            return None      # never admittable; preemption can't help
        best = None
        for slot_id, slot in enumerate(self.slots):
            if slot is None or slot.prefilling:
                continue
            if len(slot.out) <= len(slot.req.resume_out or ()):
                continue     # no progress since resume — not evictable
            if best is None or slot.seq > self.slots[best].seq:
                best = slot_id
        if best is None:
            return None
        # what the pool could actually produce: free pages, cached pages
        # (the allocator LRU-evicts unreferenced prefix pages before this
        # path ever fires), and the victim's exclusively-owned pages —
        # a page the victim shares with another slot stays referenced
        # after the eviction and must not be counted toward the shortfall
        if need > (self.cache.available_pages
                   + self.cache.reclaimable_pages(best)):
            return None
        return best

    def _preempt(self, slot_id: int) -> int:
        """Evict a decoding slot: free its pages / drop its recurrent
        state claim, and requeue the request with its committed tokens as
        a recompute prefill.  The requeued copy goes just *behind* the
        current head (the request whose admission forced the eviction),
        otherwise preserving FCFS order, and the id stays active — the
        engine's metrics entry survives across the eviction.  The
        proposer memo is kept: the context tokens are unchanged, only
        their KV is recomputed.  Returns the preempted request id.
        """
        slot = self.slots[slot_id]
        self.cache.retire(slot_id)
        self.slots[slot_id] = None
        req = dataclasses.replace(slot.req, resume_out=list(slot.out))
        self.waiting.insert(min(1, len(self.waiting)), req)
        if self._preemptions is not None:
            self._preemptions.inc()
            self._queue_gauge.set(len(self.waiting))
            self._busy_gauge.set(self.busy_slots)
        return slot.req.request_id

    def remove_waiting(self, rid: int) -> Optional[Request]:
        """Pull a queued request out of the waiting queue (cancellation /
        deadline expiry before admission).  Returns the removed request —
        its ``resume_out`` carries any preempted partial output — or None
        if ``rid`` is not waiting."""
        for i, req in enumerate(self.waiting):
            if req.request_id == rid:
                del self.waiting[i]
                self._active_ids.discard(rid)
                if self.proposer is not None and hasattr(self.proposer,
                                                         "forget"):
                    self.proposer.forget(rid)
                if self._queue_gauge is not None:
                    self._queue_gauge.set(len(self.waiting))
                return req
        return None

    def evict(self, slot_id: int) -> _Slot:
        """Retire a slot before completion (cancellation, deadline
        expiry, nonfinite guard, mid-tick failure): pages reclaimed, id
        released, proposer memo dropped.  Returns the evicted slot —
        partial output on ``slot.out``."""
        return self._retire(slot_id)

    def _retire(self, slot_id: int) -> _Slot:
        slot = self.slots[slot_id]
        self.cache.retire(slot_id)
        self.slots[slot_id] = None
        self._active_ids.discard(slot.req.request_id)
        if self.proposer is not None and hasattr(self.proposer, "forget"):
            self.proposer.forget(slot.req.request_id)
        if self._busy_gauge is not None:
            self._busy_gauge.set(self.busy_slots)
        return slot

    # -- planning -----------------------------------------------------------

    @property
    def busy_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or self.busy_slots > 0

    def plan(self) -> StepPlan:
        """One mixed ``(B, chunk_size)`` step plan under the token budget.

        Budget order: each decode slot's committed token first (1 each —
        the budget always covers a full decode batch, see ``__init__``),
        then prefill chunks (``min(chunk_size, remaining prompt,
        remaining budget)`` FCFS by slot id), then speculative drafts
        from whatever is left.  Drafts are opportunistic throughput —
        funding them *after* prefill reservation guarantees a prefilling
        slot can never be starved forever by other slots' windows under a
        tight budget (a prefilling slot that still gets no budget sits
        the step out with ``valid = 0`` and retries next step).  Each
        window is additionally capped by ``max_new`` (it never claims
        tokens the request could not emit, which also keeps the window
        inside the slot's page reservation) and by ``spec_tokens``.
        """
        c = self.chunk_size
        w = self.spec_tokens + 1
        tokens = np.zeros((self.n_slots, c), np.int32)
        start = np.zeros(self.n_slots, np.int32)
        valid = np.zeros(self.n_slots, np.int32)
        kinds = np.zeros(self.n_slots, np.int8)
        draft = np.zeros((self.n_slots, self.spec_tokens), np.int32)
        draft_len = np.zeros(self.n_slots, np.int32)
        logit_idx = np.zeros((self.n_slots, w), np.int32)
        budget = self.max_batched_tokens
        decoding = [(i, s) for i, s in enumerate(self.slots)
                    if s is not None and not s.prefilling]
        budget -= len(decoding)              # 1 committed token per slot
        for slot_id, slot in enumerate(self.slots):
            if slot is None or not slot.prefilling or budget <= 0:
                continue
            take = min(c, len(slot.feed) - slot.fed, budget)
            tokens[slot_id, :take] = slot.feed[slot.fed:slot.fed + take]
            start[slot_id] = slot.fed
            valid[slot_id] = take
            kinds[slot_id] = PREFILL
            logit_idx[slot_id] = take - 1    # only the last position samples
            budget -= take
            self.cache.note_write(slot_id, slot.fed + take)
        for slot_id, slot in decoding:
            tokens[slot_id, 0] = slot.next_token
            start[slot_id] = slot.length
            valid[slot_id] = 1
            kinds[slot_id] = DECODE
            if self.proposer is not None and self.spec_tokens > 0:
                remaining = slot.req.max_new - len(slot.out)
                k_cap = min(self.spec_tokens, remaining - 1, budget)
                if k_cap > 0:
                    # prompt + out, maintained incrementally — handed to
                    # the proposer WITHOUT a copy (a copy would be the
                    # O(context)-per-tick cost the ctx field removes);
                    # the Proposer protocol pins context as read-only
                    ctx = slot.ctx
                    if self._propose_takes_id:
                        prop = self.proposer.propose(
                            ctx, k_cap,
                            request_id=slot.req.request_id)[:k_cap]
                    else:
                        prop = self.proposer.propose(ctx, k_cap)[:k_cap]
                    if prop:
                        k = len(prop)
                        tokens[slot_id, 1:1 + k] = prop
                        draft[slot_id, :k] = prop
                        draft_len[slot_id] = k
                        valid[slot_id] = 1 + k
                        budget -= k
            logit_idx[slot_id] = np.minimum(np.arange(w),
                                            valid[slot_id] - 1)
            self.cache.note_write(slot_id,
                                  int(start[slot_id] + valid[slot_id]))
        return StepPlan(tokens, start, valid, kinds, draft, draft_len,
                        logit_idx,
                        decode_only=not bool((kinds == PREFILL).any()))

    # -- completion ---------------------------------------------------------

    def commit(self, plan: StepPlan, sampled: Sequence[int],
               accept: Optional[Sequence[int]] = None) -> StepOutcome:
        """Apply one step's verified tokens to the slot state.

        ``sampled[b]`` is slot ``b``'s one new sampled token (the only
        token without cached KV — it feeds the next window); ``accept[b]``
        its accepted-draft count from rejection sampling (``None`` means
        no speculation: zero everywhere).  A decode slot therefore gains
        ``accept + 1`` tokens and its committed length advances past the
        accepted prefix — :meth:`PagedKVCache.truncate` discards the
        rejected tail's KV writes.  Prefill-vs-decode is derived per slot
        from the slot's own state (a slot with unfed prompt tokens was fed
        prompt this step), not from a global step kind — a single commit
        handles mixed steps.
        """
        if accept is None:
            accept = np.zeros(self.n_slots, np.int32)
        emitted: List[Tuple[int, int]] = []
        first_token: List[int] = []
        finished: List[Tuple[int, _Slot]] = []
        for slot_id, slot in enumerate(self.slots):
            if slot is None or plan.valid[slot_id] == 0:
                continue
            rid = slot.req.request_id
            if slot.prefilling:
                slot.fed += int(plan.valid[slot_id])
                slot.length = slot.fed
                self.cache.truncate(slot_id, slot.length)
                self.cache.note_committed(slot_id, slot.ctx)
                if not slot.prefilling:
                    if slot.resumed:
                        # recompute prefill of a preempted request: the
                        # committed tokens are already on slot.out — the
                        # step's sampled token is discarded (greedy: it
                        # equals out[-1]) and decoding resumes by re-
                        # feeding the final committed token, whose KV the
                        # original run never wrote either.  No emit, no
                        # first-token: the client saw these tokens already.
                        slot.next_token = slot.out[-1]
                        slot.resumed = False
                    else:
                        # prompt fully cached: the last position's logits
                        # sampled the first generated token
                        tok = int(sampled[slot_id])
                        slot.emit([tok])
                        slot.next_token = tok
                        first_token.append(rid)
                        emitted.append((rid, 1))
            else:
                a = int(accept[slot_id])
                if a > int(plan.draft_len[slot_id]):
                    raise RuntimeError(
                        f"slot {slot_id}: verifier accepted {a} of "
                        f"{int(plan.draft_len[slot_id])} drafts")
                new = [int(t) for t in plan.draft[slot_id, :a]]
                new.append(int(sampled[slot_id]))
                slot.emit(new)
                slot.next_token = new[-1]
                slot.length += len(new)
                self.cache.truncate(slot_id, slot.length)
                self.cache.note_committed(slot_id, slot.ctx)
                emitted.append((rid, len(new)))
            if slot.done:
                finished.append((slot_id, self._retire(slot_id)))
        return StepOutcome(emitted, first_token, finished)
