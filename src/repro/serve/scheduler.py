"""Continuous batching with chunked prefill.

Host-side slot bookkeeping: a FIFO of waiting requests, ``n_slots``
decode slots, and per-step batch plans for the engine's jitted steps.
Admission is FCFS with full-budget page reservation (see
:mod:`repro.serve.cache`); a finished request retires immediately and its
slot/pages are re-admitted the same step — the batch never drains to
refill, which is the whole point of continuous batching.

Prefill is *chunked*: a prompt runs through the model ``chunk_size``
tokens at a time via the batched ``serve_forward`` entry point (one matmul
over the chunk), not token-by-token through the decode step.  Scheduling
is prefill-priority: while any slot has unfed prompt tokens the step is a
prefill chunk over those slots; otherwise it is a single-token decode over
the generating slots.  Slots not participating in a step carry
``valid = 0`` and are masked inside the model.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.cache import PagedKVCache


@dataclasses.dataclass
class Request:
    """One generation request.  ``prompt`` is a list of token ids."""
    request_id: int
    prompt: List[int]
    max_new: int = 32

    def __post_init__(self):
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1: {self.max_new}")


@dataclasses.dataclass
class _Slot:
    req: Request
    fed: int = 0          # prompt tokens written to the cache so far
    length: int = 0       # total cached tokens (prompt + fed generations)
    out: List[int] = dataclasses.field(default_factory=list)
    next_token: int = -1  # sampled but not yet fed to a decode step

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.req.prompt)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.max_new


class Scheduler:
    """Admission, chunk planning, and completion bookkeeping."""

    def __init__(self, cache: PagedKVCache, chunk_size: int = 32):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1: {chunk_size}")
        self.cache = cache
        self.n_slots = cache.n_slots
        self.chunk_size = chunk_size
        self.max_seq = cache.max_pages_per_slot * cache.page_size
        self.waiting: Deque[Request] = deque()
        self.slots: List[Optional[_Slot]] = [None] * self.n_slots

    # -- admission / eviction -----------------------------------------------

    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new
        if total > self.max_seq:
            raise ValueError(
                f"request {req.request_id}: prompt {len(req.prompt)} + "
                f"max_new {req.max_new} exceeds max_seq {self.max_seq}")
        if self.cache.pages_for(total) > self.cache.num_pages:
            # would never be admittable: drain() would spin forever
            raise ValueError(
                f"request {req.request_id}: needs "
                f"{self.cache.pages_for(total)} pages, pool has only "
                f"{self.cache.num_pages}")
        self.waiting.append(req)

    def admit(self) -> List[int]:
        """Place waiting requests into free slots, FCFS.

        Stops at the first request whose page reservation doesn't fit
        (head-of-line order preserved — large requests are not starved by
        later small ones).  Returns the admitted request ids.
        """
        admitted = []
        for slot_id in range(self.n_slots):
            if self.slots[slot_id] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            if not self.cache.admit(slot_id,
                                    len(req.prompt) + req.max_new):
                break
            self.waiting.popleft()
            self.slots[slot_id] = _Slot(req)
            admitted.append(req.request_id)
        return admitted

    def _retire(self, slot_id: int) -> _Slot:
        slot = self.slots[slot_id]
        self.cache.retire(slot_id)
        self.slots[slot_id] = None
        return slot

    # -- planning -----------------------------------------------------------

    @property
    def busy_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or self.busy_slots > 0

    def plan(self) -> Tuple[str, np.ndarray, np.ndarray, np.ndarray]:
        """-> (kind, tokens (B, C), start (B,), valid (B,)) for one step.

        kind "prefill": C = chunk_size, each prefilling slot feeds its next
        prompt chunk.  kind "decode": C = 1, each generating slot feeds its
        last sampled token.  valid = 0 masks a slot out of the step.
        """
        prefill = any(s is not None and s.prefilling for s in self.slots)
        c = self.chunk_size if prefill else 1
        tokens = np.zeros((self.n_slots, c), np.int32)
        start = np.zeros(self.n_slots, np.int32)
        valid = np.zeros(self.n_slots, np.int32)
        for slot_id, slot in enumerate(self.slots):
            if slot is None:
                continue
            if prefill:
                if not slot.prefilling:
                    continue
                chunk = slot.req.prompt[slot.fed:slot.fed + c]
                tokens[slot_id, :len(chunk)] = chunk
                start[slot_id] = slot.fed
                valid[slot_id] = len(chunk)
            else:
                tokens[slot_id, 0] = slot.next_token
                start[slot_id] = slot.length
                valid[slot_id] = 1
        return ("prefill" if prefill else "decode"), tokens, start, valid

    # -- completion ---------------------------------------------------------

    def commit(self, kind: str, valid: np.ndarray, sampled: Sequence[int],
               ) -> Tuple[List[int], List[Tuple[int, _Slot]]]:
        """Apply one step's sampled tokens to the slot state.

        Returns (request ids that produced their first token this step,
        finished (slot_id, slot) pairs — already retired).
        """
        first_token: List[int] = []
        finished: List[Tuple[int, _Slot]] = []
        for slot_id, slot in enumerate(self.slots):
            if slot is None or valid[slot_id] == 0:
                continue
            if kind == "prefill":
                slot.fed += int(valid[slot_id])
                slot.length = slot.fed
                if not slot.prefilling:    # prompt fully cached: the last
                    tok = int(sampled[slot_id])  # position's logits sampled
                    slot.out.append(tok)
                    slot.next_token = tok
                    first_token.append(slot.req.request_id)
            else:
                tok = int(sampled[slot_id])
                slot.out.append(tok)
                slot.next_token = tok
                slot.length += 1
            if slot.done:
                finished.append((slot_id, self._retire(slot_id)))
        return first_token, finished
