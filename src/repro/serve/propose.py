"""Draft-token proposers for speculative decoding.

The serving engine's speculative loop is propose/verify/commit: a cheap
*proposer* guesses the next ``k`` tokens of a decoding slot on the host,
the batched ``serve_forward`` step verifies the whole window (committed
token + drafts) against the target model in one forward pass, and fp32
rejection sampling (:func:`repro.serve.sampling.rejection_sample`) keeps
the longest accepted prefix plus one corrected/bonus token.  A proposer
never changes the output distribution — a bad guess only wastes the
window's compute — so proposers are free to be heuristic.

:class:`NGramProposer` is the default: prompt-lookup decoding (the
draft-model-free scheme of Saxena's prompt-lookup / LLMA) — find the most
recent earlier occurrence of the context's suffix n-gram and propose its
historical continuation.  It costs a host-side substring scan, nothing on
the device, and wins big exactly where serving traffic is repetitive:
summarization, code edits, retrieval-augmented contexts, agent loops that
re-quote their own transcript.

:class:`DraftModelProposer` (a small model drafting for a large one) is a
named follow-on — the interface is here, the implementation is not.
"""
from __future__ import annotations

from typing import List, Protocol, Sequence, runtime_checkable


@runtime_checkable
class Proposer(Protocol):
    """Host-side draft source for one decoding slot."""

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing ``context`` (may be fewer,
        or empty when the proposer has no guess).  ``context`` is the
        slot's full token history: prompt + every committed generation,
        including the pending committed token the window will re-feed."""
        ...


class NGramProposer:
    """Prompt-lookup drafts: continue the most recent earlier occurrence
    of the context's suffix n-gram.

    Tries suffix lengths from ``max_ngram`` down to ``min_ngram``; for the
    first suffix that reappears earlier in the context, proposes the up-to
    ``k`` tokens that followed that occurrence.  Deterministic (the draft
    distribution is a one-hot), so the verify step's accept rule reduces
    to the target probability of the proposed token.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"min_ngram={min_ngram} max_ngram={max_ngram}")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        ctx = list(context)
        if k <= 0 or len(ctx) < self.min_ngram + 1:
            return []
        for n in range(min(self.max_ngram, len(ctx) - 1),
                       self.min_ngram - 1, -1):
            suffix = ctx[-n:]
            # most recent earlier occurrence with a non-empty continuation
            for i in range(len(ctx) - n - 1, -1, -1):
                if ctx[i:i + n] == suffix:
                    return ctx[i + n:i + n + k]
        return []


class DraftModelProposer:
    """Draft-model speculation stub (named follow-on).

    Running a small transformer as the drafter needs its own decode state
    threaded through the engine tick; this PR ships the host-side n-gram
    proposer and the verify/commit machinery only.
    """

    def __init__(self, *args, **kwargs):
        raise NotImplementedError(
            "draft-model proposer is a follow-on; use NGramProposer")

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        raise NotImplementedError
