"""Draft-token proposers for speculative decoding.

The serving engine's speculative loop is propose/verify/commit: a cheap
*proposer* guesses the next ``k`` tokens of a decoding slot on the host,
the batched ``serve_forward`` step verifies the whole window (committed
token + drafts) against the target model in one forward pass, and fp32
rejection sampling (:func:`repro.serve.sampling.rejection_sample`) keeps
the longest accepted prefix plus one corrected/bonus token.  A proposer
never changes the output distribution — a bad guess only wastes the
window's compute — so proposers are free to be heuristic.

:class:`NGramProposer` is the default: prompt-lookup decoding (the
draft-model-free scheme of Saxena's prompt-lookup / LLMA) — find the most
recent earlier occurrence of the context's suffix n-gram and propose its
historical continuation.  It costs a host-side lookup, nothing on the
device, and wins big exactly where serving traffic is repetitive:
summarization, code edits, retrieval-augmented contexts, agent loops that
re-quote their own transcript.  When the scheduler passes a
``request_id`` the proposer keeps a per-request *suffix index* (n-gram ->
its two most recent start positions) and extends it incrementally with
the tokens committed since the previous call, so each ``propose()`` is
O(new tokens) instead of the O(context) rescan that grew quadratically
over a generation; without an id it falls back to the stateless scan.

:class:`DraftModelProposer` (a small model drafting for a large one) is a
named follow-on — the stub constructs (so engine wiring can be written
against it) and raises an actionable error from ``propose()``; the
engine refuses it at ``submit()`` so the failure is immediate, not
buried in a mid-step traceback.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Protocol, Sequence, Tuple, \
    runtime_checkable


@runtime_checkable
class Proposer(Protocol):
    """Host-side draft source for one decoding slot."""

    def propose(self, context: Sequence[int], k: int, *,
                request_id: Optional[int] = None) -> List[int]:
        """Up to ``k`` draft tokens continuing ``context`` (may be fewer,
        or empty when the proposer has no guess).  ``context`` is the
        slot's full token history: prompt + every committed generation,
        including the pending committed token the window will re-feed.
        It is **read-only**: the scheduler passes its live incrementally-
        maintained history (no per-tick copy — that would be O(context)
        per step), so a proposer that mutated it would corrupt the
        slot's state for the rest of the generation.  ``request_id``,
        when given, keys any per-request incremental state; the context
        for one id only ever grows by appending."""
        ...

    def forget(self, request_id: int) -> None:
        """Drop per-request state (called when the request retires)."""
        ...


class NGramProposer:
    """Prompt-lookup drafts: continue the most recent earlier occurrence
    of the context's suffix n-gram.

    Tries suffix lengths from ``max_ngram`` down to ``min_ngram``; for the
    first suffix that reappears earlier in the context, proposes the up-to
    ``k`` tokens that followed that occurrence.  Deterministic (the draft
    distribution is a one-hot), so the verify step's accept rule reduces
    to the target probability of the proposed token.

    With a ``request_id`` the lookup is served from a memoized suffix
    index: for each n in [min_ngram, max_ngram], a dict mapping the
    n-gram tuple to its two most recent start positions, extended
    incrementally as the context grows (committed tokens are append-only
    per request).  Keeping *two* positions makes "most recent EARLIER
    occurrence" O(1): when the latest occurrence is the live suffix
    itself, the previous one is the answer.  The per-request cost of a
    generation step is O(tokens committed since the last call), not
    O(len(context)).
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"min_ngram={min_ngram} max_ngram={max_ngram}")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        # request_id -> (tokens seen so far,
        #                n -> {gram: (latest start, previous start)})
        self._index: Dict[int, Tuple[List[int],
                                     Dict[int, Dict[tuple,
                                                    Tuple[int, int]]]]] = {}

    # -- stateless scan (no request_id) -------------------------------------

    def _scan(self, ctx: List[int], k: int) -> List[int]:
        for n in range(min(self.max_ngram, len(ctx) - 1),
                       self.min_ngram - 1, -1):
            suffix = ctx[-n:]
            # most recent earlier occurrence with a non-empty continuation
            for i in range(len(ctx) - n - 1, -1, -1):
                if ctx[i:i + n] == suffix:
                    return ctx[i + n:i + n + k]
        return []

    # -- memoized suffix index (request_id) ---------------------------------

    def _extend(self, ctx: Sequence[int], request_id: int):
        toks, grams = self._index.setdefault(
            request_id, ([], {n: {} for n in range(self.min_ngram,
                                                   self.max_ngram + 1)}))
        done = len(toks)
        # O(1) extension guard — a full prefix compare would silently
        # reintroduce the O(context)-per-call cost this index removes.
        # Engine contexts are append-only per single-use id, so length
        # shrinkage or a changed boundary token are the only realistic
        # divergences; on either, rebuild rather than serve stale drafts.
        if len(ctx) < done or (done and int(ctx[done - 1]) != toks[-1]):
            toks.clear()
            for d in grams.values():
                d.clear()
            done = 0
        toks.extend(int(t) for t in ctx[done:])
        for n, d in grams.items():
            # index every complete n-gram that gained its start since the
            # last call: starts done-n+1 .. len-n (clamped)
            for i in range(max(done - n + 1, 0), len(toks) - n + 1):
                g = tuple(toks[i:i + n])
                last, _ = d.get(g, (-1, -1))
                if i != last:
                    d[g] = (i, last)
        return toks, grams

    def _lookup(self, toks: List[int],
                grams: Dict[int, Dict[tuple, Tuple[int, int]]],
                k: int) -> List[int]:
        for n in range(min(self.max_ngram, len(toks) - 1),
                       self.min_ngram - 1, -1):
            suffix = tuple(toks[-n:])
            last, prev = grams[n].get(suffix, (-1, -1))
            # the latest occurrence IS the live suffix (start len-n);
            # "most recent earlier" is the one before it
            i = prev if last == len(toks) - n else last
            if i >= 0:
                return toks[i + n:i + n + k]
        return []

    # -- Proposer protocol ---------------------------------------------------

    def propose(self, context: Sequence[int], k: int, *,
                request_id: Optional[int] = None) -> List[int]:
        if k <= 0 or len(context) < self.min_ngram + 1:
            return []
        if request_id is None:
            return self._scan(list(context), k)
        toks, grams = self._extend(context, request_id)
        return self._lookup(toks, grams, k)

    def forget(self, request_id: int) -> None:
        self._index.pop(request_id, None)


class DraftModelProposer:
    """Draft-model speculation stub (named ROADMAP follow-on).

    Running a small transformer as the drafter needs its own decode
    state threaded through the engine tick (a second paged cache, the
    draft model's own prefill of every admitted prompt, and rollback of
    its state over rejected windows).  The repo ships the host-side
    n-gram proposer and the verify/commit machinery; this class reserves
    the surface — it constructs (so callers can wire configuration) but
    every ``propose()`` raises, and :meth:`repro.serve.ServeEngine.submit`
    refuses a stub proposer up front so the failure names the follow-on
    instead of surfacing mid-step from inside ``Scheduler.plan``.
    """

    #: why this proposer cannot serve traffic — ServeEngine.submit checks
    #: for this attribute to fail fast with the same message.
    unimplemented = (
        "DraftModelProposer is the 'draft-model proposer' ROADMAP "
        "follow-on: drafting with a small transformer needs its own "
        "decode state (second paged cache + prefill + rejected-window "
        "rollback) threaded through the engine tick, which is not "
        "implemented yet.  Use NGramProposer (the default for "
        "spec_tokens > 0), or drop spec_tokens to disable speculation.")

    def __init__(self, draft_cfg=None, draft_params=None, **kwargs):
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.kwargs = kwargs

    def propose(self, context: Sequence[int], k: int, *,
                request_id: Optional[int] = None) -> List[int]:
        raise NotImplementedError(self.unimplemented)

    def forget(self, request_id: int) -> None:
        pass
