"""repro.serve — mixed-precision inference engine.

The serving half of the MPX discipline as a subsystem: bf16 weights and KV
cache on the hot path, fp32 only where precision matters (softmax inside
the model, sampling logits here).  Components:

- :mod:`~repro.serve.cache`     — paged bf16 KV-cache pool (fixed-size
  pages, per-sequence page tables, alloc on admit / free on retire)
- :mod:`~repro.serve.scheduler` — continuous batching with *mixed*
  prefill+decode chunk steps: every tick each active slot contributes
  either its next prefill chunk or its pending decode token under a
  per-step token budget (``max_batched_tokens``), so decode slots keep
  emitting while other slots are mid-prefill
- :mod:`~repro.serve.sampling`  — greedy/temperature/top-k/top-p in fp32
- :mod:`~repro.serve.engine`    — the :class:`ServeEngine` facade
  (``submit()`` / ``step()`` / ``drain()``), one compiled ``(B, chunk)``
  step shape for prefill, decode and mixed plans alike; with
  ``use_kernel=True`` every step (not just pure decode) runs attention
  through the native paged-attention Pallas kernel, which walks the page
  tables in-kernel instead of materializing a gathered contiguous copy
  of each slot's KV prefix
- :mod:`~repro.serve.metrics`   — TTFT / inter-token latency (p50/p95) /
  throughput / occupancy stats

Quickstart::

    from repro import mpx, serve
    from repro.models import transformer as T

    params = mpx.cast_to_bfloat16(T.init_params(key, cfg))
    engine = serve.ServeEngine(cfg, params, n_slots=4, max_seq=128)
    for prompt in prompts:
        engine.submit(prompt, max_new=32)
    for result in engine.drain():
        print(result.request_id, result.tokens)
    print(engine.stats.summary())
"""
from repro.serve.cache import PagedKVCache
from repro.serve.engine import RequestResult, ServeEngine
from repro.serve.metrics import EngineStats, RequestMetrics
from repro.serve.sampling import SamplingParams, make_sampler, sample_logits
from repro.serve.scheduler import Request, Scheduler, StepOutcome, StepPlan

# the legacy monolithic-slab serving step, generalized to take
# SamplingParams, lives with the train steps; re-export it here so
# serving callers have one import surface.
from repro.train.steps import make_serve_step

__all__ = [
    "EngineStats",
    "PagedKVCache",
    "Request",
    "RequestMetrics",
    "RequestResult",
    "SamplingParams",
    "Scheduler",
    "ServeEngine",
    "StepOutcome",
    "StepPlan",
    "make_sampler",
    "make_serve_step",
    "sample_logits",
]
