"""repro.serve — mixed-precision inference engine: one continuous-batching
ServeEngine for attention, SSM, RG-LRU, hybrid and MoE stacks, with
speculative decode and sub-bf16 quantized KV-cache storage.

The serving half of the MPX discipline as a subsystem, organized around a
**per-layer-kind state pool** (:class:`PagedStatePool`, née
:class:`PagedKVCache` — both names work): every layer kind gets the
decode state its math wants, managed by one host allocator and one
scheduler.  Attention layers ('attn', 'local_attn') get paged KV pools —
fixed-size pages, per-slot page tables, pages reserved on admit / freed
on retire, stored at whatever precision the ``kv_dtype`` policy names
(bf16 passthrough, or int8 / fp8 pages with per-page amax scales —
``repro.quant``).  Recurrent layers ('rglru', 'ssd') get O(1) per-slot
state instead — the RG-LRU hidden vector and the Mamba-2 SSD state
accumulator, pinned fp32 per the MPX fragile-spot policy (recurrences
compound rounding), plus compute-dtype conv buffers — no pages, no
page-table entries, zeroed on admit so slot reuse can't leak state.
fp32 appears only where precision matters (softmax and recurrent
gates/decays inside the model, sampling and speculative verification
here).  The quantized page-pool contract is write-quantize /
read-dequantize: every chunk's K/V is quantized as it is scattered into
the pages (the touched pages are requantized against a fresh amax,
scales ride a small fp32 sidecar pool), and the paged-attention kernel
multiplies the scales back onto K/V blocks in VMEM before the
score/output matmuls — decode streams the cache at 1 byte/element and a
dense bf16 image of it never exists.  Components:

- :mod:`~repro.serve.cache`     — the per-layer-kind state pool: paged
  KV sub-pools for attention layers (fixed-size pages, per-sequence
  page tables, alloc on admit / free on retire, optional quantized
  storage with the scale sidecar, and committed/written length
  watermarks so speculative windows can write KV ahead and
  ``truncate()`` back to the accepted prefix with the invariants still
  checkable) and slot-indexed recurrent state for rglru/ssd layers
  (init-reset on admit; ``check_invariants`` catches stale-state leaks);
  with ``prefix_cache=True`` the pool additionally refcounts pages and
  shares committed full pages across slots (see *Prefix sharing* below)
- :mod:`~repro.serve.scheduler` — continuous batching with *mixed*
  prefill+decode chunk steps: every tick each active slot contributes
  either its next prefill chunk or its decode window under a per-step
  token budget (``max_batched_tokens``), so decode slots keep emitting
  while other slots are mid-prefill
- :mod:`~repro.serve.propose`   — host-side draft proposers for
  speculative decoding; :class:`NGramProposer` (prompt-lookup) is the
  default, a draft-model proposer is a named follow-on
- :mod:`~repro.serve.sampling`  — greedy/temperature/top-k/top-p in fp32,
  samplers returning (ids, probabilities), and Leviathan-style
  :func:`rejection_sample` for window verification
- :mod:`~repro.serve.engine`    — the :class:`ServeEngine` facade
  (``submit()`` / ``step()`` / ``drain()``), one compiled ``(B, chunk)``
  step shape for prefill, decode, mixed and speculative plans alike,
  serving any registry architecture whose kinds the pool implements
  (attn / ssm / rglru / hybrid — greedy output token-identical to the
  dense per-token ``decode()`` oracle for each family; MoE blocks take
  a dense per-token expert-gather fast path at decode sizes); with
  ``use_kernel=True`` every step runs attention through the native
  paged-attention Pallas kernel, which walks the page tables in-kernel
  instead of materializing a gathered contiguous copy of each slot's KV;
  ``kv_dtype="i8"`` (or "f8_e4m3" / "f8_e3m4", or a ``Policy`` with a
  ``kv=`` component) selects quantized page storage.  Speculative
  windows need paged rollback, so recurrent/hybrid stacks serve with
  ``spec_tokens=0`` (refused with an actionable error otherwise)
- :mod:`~repro.serve.metrics`   — TTFT / inter-token latency (p50/p95) /
  throughput / occupancy / acceptance-rate / tokens-per-step stats,
  backed by a :class:`repro.obs.Registry` (labeled counters, gauges and
  log2-bucketed latency histograms) so the same numbers export as a
  Prometheus text snapshot or a JSON dump

Telemetry is layered (``repro.obs``), not bolted on: the engine always
carries a metrics registry — the scheduler reports queue depth and
admissions, the paged cache reports pool free/used/peak pages and
speculative truncations, :class:`EngineStats` rides its own registry so
``engine.stats = EngineStats(n)`` still resets cleanly — and
``engine.metrics_snapshot()`` / ``engine.prometheus()`` export both.
Passing ``tracer=repro.obs.Tracer()`` additionally records the full
request lifecycle (submit / admit / prefill chunks / decode windows with
draft-accept counts / truncate / retire) and every tick's engine phases
(admit / plan / device step / host sync / commit) as Chrome trace events;
``tracer.export(path)`` loads in Perfetto as per-slot timelines.  All of
it reads host state plus the two ``(B,)`` arrays each step already
transfers — zero added device syncs (pinned by tests/test_obs.py), <3%
tok/s (the bench's ``serving_obs_overhead_pct`` row).

**Flight recorder & postmortem** — pass
``journal=repro.obs.JournalRecorder(path, param_seed=...)`` and the
engine event-sources the *entire drive* into an append-only JSONL
journal: the config fingerprint (model config + every constructor knob),
the :class:`FaultInjector` schedule, every clock sample, every
``submit``/``cancel``, a per-tick digest (plan kind/counts,
admitted/preempted/finished rids, pool and prefix-cache state, and a
rolling hash chained over each accepted token) and every request result
with its phase breakdown (queue wait / prefill / decode / preempted
time — also exported as the ``serve_queue_wait_seconds`` /
``serve_prefill_seconds`` / ``serve_decode_seconds`` histograms).
``repro.obs.replay_journal(path)`` — or ``python -m repro.obs.journal
path`` — rebuilds the engine from the header alone (params
re-initialized from ``param_seed``), re-drives the recorded inputs with
the recorded clock, and asserts token identity plus per-tick digest
equality, naming the **first divergent tick** on mismatch; ``python -m
repro.obs.postmortem path [--trace ...] [--metrics ...]
[--precision ...]`` joins the journal with the Chrome trace, Prometheus
snapshot and precision telemetry into a per-request incident report.
Recording reads only host-side state (same zero-added-syncs pin; the
bench's ``serving_journal_overhead_pct`` row holds it <3% tok/s), and
CI records, replays and renders the scripted chaos drive every run.

The speculative loop (``spec_tokens > 0``) is propose/verify/commit:

1. **propose** — the :class:`~repro.serve.propose.Proposer` drafts up to
   ``spec_tokens`` tokens per decoding slot on the host (n-gram lookup
   over the slot's own prompt + generations by default);
2. **verify** — the scheduler packs committed token + drafts into the
   slot's chunk columns and ONE batched ``serve_forward`` step returns
   per-position logits for every slot's live window (``logit_idx``), so
   verification costs one engine tick regardless of window width;
3. **commit** — fp32 rejection sampling accepts the longest matching
   draft prefix plus one corrected/bonus token; the scheduler commits it
   and ``PagedKVCache.truncate`` rolls the cache length back over the
   rejected tail (dead positions, no page churn — the next window
   overwrites them).

With temperature 0 the accept rule is argmax equality, so the greedy
speculative engine is token-identical to the non-speculative engine —
speculation changes step count, never output.

**Prefix sharing** (``ServeEngine(prefix_cache=True)``) — the page pool
grows a refcounted, copy-on-write sharing layer so requests with a
common prompt prefix map the same physical KV pages instead of
recomputing and re-storing them:

- Every page carries a **refcount** equal to the number of page-table
  entries pointing at it; a page is *free*, *held* (fault injection),
  *referenced* (refcount >= 1) or *cached* (refcount 0 but still
  indexed, parked on an LRU list) — ``check_invariants()`` proves the
  four states partition the pool every tick, so no page can be
  simultaneously free and referenced.
- A **prefix index** keys committed full pages by a rolling chained
  hash of their token ids (per model config / kv-format / page size, so
  incompatible pools never alias).  Admission probes the index with the
  new request's prompt — O(pages touched), the chain digest per slot is
  incremental — maps every hit into the slot's page table with a
  refcount bump, and tells chunked prefill to **skip** the covered
  tokens: the hot-prefix request pays prefill only for its unique
  suffix.  ``RequestMetrics.cached_prefix_tokens`` records the skip.
- Writes keep sharing sound via **copy-on-write**: before any write
  lands on a page with refcount > 1 (or on a resident cached page the
  slot got at a page-aligned admission boundary), the pool allocates a
  fresh page, queues a device-side page copy — value pages *and* the
  fp32 amax-scale sidecars of quantized formats, since quantized
  scatter is a whole-page read-modify-write — and repoints only the
  writing slot.  ``flush_cow()`` executes the queued copies as one
  batched donated jit before the engine's device step, so greedy output
  is token-identical with the cache on or off, bf16 and int8 alike
  (pinned by tests/test_prefix_cache.py).
- On retire, pages drop to the LRU cache instead of the free list (if
  indexed); under pool pressure the scheduler reclaims **unreferenced
  cached pages first** — LRU eviction — before preempting a live slot.
- Observability: ``serve_prefix_hits_total`` / ``serve_prefix_miss_total``
  / ``serve_cow_copies_total`` counters and ``serve_pages_shared`` /
  ``serve_pages_cached`` gauges export with the usual snapshot; the
  bench's ``serving_prefix_*`` rows price the win (hot-prefix TTFT,
  prefill tokens actually fed, resident pages under sharing).

Recurrent state is a function of the *entire* history, not a page's
worth of it, so stacks with rglru/ssd layers silently serve with the
cache off — the flag is accepted but inert (pinned by tests).

**Failure semantics** — the resilience layer assumes an adversarial
world (overload, stragglers, poisoned numerics) and turns every
degradation into a typed, counted, partial-output-preserving outcome:

- Every request ends with exactly one :class:`RequestResult` whose
  ``status`` is ``"ok"``, ``"cancelled"``, ``"timeout"``, or
  ``"failed"`` — partial output is always delivered on ``tokens``,
  never dropped, and ``metrics.error`` explains a failure.
- **Backpressure**: ``ServeEngine(max_queue=N)`` bounds the waiting
  queue; a full queue makes ``submit()`` raise :class:`EngineOverloaded`
  (carrying ``queue_depth`` and an ``est_wait_s`` admission estimate)
  instead of growing host memory without bound.
- **Deadlines / cancellation**: ``submit(deadline_ms=...)`` and
  ``engine.cancel(rid)`` retire a queued or in-flight request at the
  next tick boundary (statuses ``"timeout"`` / ``"cancelled"``), freeing
  its slot and pages for the same tick's admissions.
- **Preemption & recompute**: when the page pool can't cover the head
  request but a slot is free, the scheduler evicts the *youngest
  decoding* slot — pages freed, recurrent-state claim dropped — and
  requeues it with its committed tokens as a recompute prefill through
  the ordinary chunked-prefill path.  A preempted request still ends
  ``"ok"`` with greedy output token-identical to the unpreempted run;
  the cost is re-prefilling prompt + output once per eviction
  (``metrics.preemptions``, ``serve_preemptions_total``, and the
  bench's ``serving_preempt_recompute_overhead_pct`` row price it).
- **Nonfinite guard**: each step's (B, W, V) window logits are checked
  for NaN/Inf inside the jitted step; the verdict rides the two (B,)
  arrays already transferred (zero added syncs), and only the poisoned
  request dies (status ``"failed"``, slot retired, pool reclaimed) —
  its batch neighbors' output is untouched.  A mid-tick exception gets
  the same discipline: the plan's requests fail with partial output,
  their slots retire, and ``check_invariants()`` still passes.
- **Chaos harness**: :mod:`repro.serve.faults` scripts NaN poison, pool
  exhaustion, Nth-step failure and clock jumps at the engine's seams
  (:class:`FaultInjector`, :class:`FakeClock`, :class:`InjectedFault`);
  tests/test_serve_faults.py drives it to prove ``drain()`` terminates
  with correct statuses under every schedule.

Quickstart::

    from repro import mpx, serve
    from repro.models import transformer as T

    params = mpx.cast_to_bfloat16(T.init_params(key, cfg))
    engine = serve.ServeEngine(cfg, params, n_slots=4, max_seq=128,
                               spec_tokens=3,    # n-gram speculative decode
                               kv_dtype="i8",    # int8 KV pages + scales
                               prefix_cache=True)  # share common prefixes
    for prompt in prompts:
        engine.submit(prompt, max_new=32)
    for result in engine.drain():
        print(result.request_id, result.tokens,
              result.metrics.acceptance_rate)
    print(engine.stats.summary())   # incl. spec_accept_rate, tokens_per_step
"""
from repro.serve.cache import PagedKVCache, PagedStatePool
from repro.serve.engine import EngineOverloaded, RequestResult, ServeEngine
from repro.serve.faults import FakeClock, FaultInjector, InjectedFault
from repro.serve.metrics import EngineStats, RequestMetrics
from repro.serve.propose import DraftModelProposer, NGramProposer, Proposer
from repro.serve.sampling import (SamplingParams, guard_nonfinite,
                                  make_sampler, make_verifier,
                                  probs_from_logits, rejection_sample,
                                  sample_logits)
from repro.serve.scheduler import Request, Scheduler, StepOutcome, StepPlan

# the legacy monolithic-slab serving step, generalized to take
# SamplingParams, lives with the train steps; re-export it here so
# serving callers have one import surface.
from repro.train.steps import make_serve_step

__all__ = [
    "DraftModelProposer",
    "EngineOverloaded",
    "EngineStats",
    "FakeClock",
    "FaultInjector",
    "InjectedFault",
    "NGramProposer",
    "PagedKVCache",
    "PagedStatePool",
    "Proposer",
    "Request",
    "RequestMetrics",
    "RequestResult",
    "SamplingParams",
    "Scheduler",
    "ServeEngine",
    "StepOutcome",
    "StepPlan",
    "guard_nonfinite",
    "make_sampler",
    "make_serve_step",
    "make_verifier",
    "probs_from_logits",
    "rejection_sample",
    "sample_logits",
]
