"""End-to-end driver: train a ~100M-parameter llama-style LM.

Exercises the full production stack on real (synthetic-corpus) data:
unified transformer, MPX mixed precision + dynamic loss scaling, AdamW with
warmup-cosine schedule, sharded state (single-device mesh here; the same
code drives the 16×16 pod), checkpoint/resume, prefetching pipeline.

~100M params: 12L × d768 × 12H × ff2048, 32k vocab.

Run: PYTHONPATH=src python examples/train_llm.py --steps 300
(CPU: ~1-2 s/step at the default batch; use --steps 20 for a quick pass.)
Kill and relaunch with the same --ckpt-dir to see fault-tolerant resume.
"""
import argparse

from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import MemmapTokens, SyntheticTokens, make_token_file
from repro.launch.mesh import single_device_mesh
from repro.models import transformer as T
from repro.optim import adamw, linear_warmup_cosine
from repro.optim.optimizers import Optimizer
from repro.train.trainer import Trainer, TrainerConfig

LLM_100M = ModelConfig(
    name="llm-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32000,
    pattern=("attn",), mlp="swiglu", norm="rmsnorm",
    rope_theta=10000.0, tie_embeddings=True, remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/mpx_llm_100m")
    ap.add_argument("--corpus", default=None,
                    help="path to an int32 token file (default: generated)")
    args = ap.parse_args()

    cfg = LLM_100M
    print(f"model: {T.count_params(cfg)/1e6:.0f}M params")
    run = RunConfig(learning_rate=3e-4, grad_accum=1, scaling_period=500)
    sched = linear_warmup_cosine(run.learning_rate, warmup_steps=50,
                                 total_steps=args.steps)
    optimizer = adamw(schedule=sched, weight_decay=run.weight_decay)

    if args.corpus:
        data = MemmapTokens(args.corpus, batch=args.batch, seq=args.seq)
    else:
        path = make_token_file("/tmp/mpx_corpus.bin", 2_000_000,
                               vocab=cfg.vocab_size, seed=1)
        data = MemmapTokens(path, batch=args.batch, seq=args.seq)

    trainer = Trainer(cfg, run, optimizer, data,
                      TrainerConfig(total_steps=args.steps,
                                    ckpt_dir=args.ckpt_dir, ckpt_every=100,
                                    log_every=10, watchdog_s=300.0),
                      mesh=single_device_mesh())
    history = trainer.fit()
    if history:
        print(f"\nfirst logged loss {history[0]['loss']:.3f} -> "
              f"last {history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
