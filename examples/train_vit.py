"""The paper's evaluation, in miniature: ViT training, fp32 vs mixed.

Reproduces the experimental setup of MPX §5 (desktop configuration: the
small ViT with feature size 256 / hidden 800 on CIFAR-100-shaped data) on
whatever device this runs on, and reports the paper's two measurements:

- per-step wall time, fp32 vs mixed        (paper Fig. 3)
- compiled memory (args+temps), fp32 vs mixed  (paper Fig. 2)

plus the accuracy trajectory, demonstrating "without compromising accuracy".

Run: PYTHONPATH=src python examples/train_vit.py [--steps 100] [--batch 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import mpx
from repro.models import vit
from repro.optim import adamw


def synthetic_cifar(key, n, image_size=32, classes=100):
    """Deterministic CIFAR-100-shaped data with learnable class structure."""
    kimg, klab, kproto = jax.random.split(key, 3)
    labels = jax.random.randint(klab, (n,), 0, classes)
    protos = jax.random.normal(kproto, (classes, image_size, image_size, 3))
    noise = jax.random.normal(kimg, (n, image_size, image_size, 3))
    return protos[labels] * 0.7 + 0.3 * noise, labels


def run_variant(mixed: bool, steps: int, batch: int, cfg: vit.ViTConfig,
                log=print):
    key = jax.random.key(0)
    params = vit.init_params(key, cfg)
    optimizer = adamw(3e-4, weight_decay=0.01)
    opt_state = optimizer.init(params)
    loss_fn = vit.make_loss_fn(cfg)
    scaling = (mpx.DynamicLossScaling(2.0 ** 15, period=500) if mixed
               else mpx.NoOpLossScaling())
    images, labels = synthetic_cifar(jax.random.key(1), 4 * batch)

    @jax.jit
    def train_step(params, opt_state, scaling, images, labels):
        scaling, finite, (loss, aux), grads = mpx.filter_value_and_grad(
            loss_fn, scaling, has_aux=True, use_mixed_precision=mixed)(
                params, {"images": images, "labels": labels})
        params, opt_state = mpx.optimizer_update(params, optimizer,
                                                 opt_state, grads, finite)
        return params, opt_state, scaling, loss, aux["acc"]

    # memory from the compiled artifact (paper Fig. 2 analogue)
    comp = train_step.lower(params, opt_state, scaling, images[:batch],
                            labels[:batch]).compile()
    mem = comp.memory_analysis()
    mem_bytes = mem.argument_size_in_bytes + mem.temp_size_in_bytes

    # warmup + timed steps (paper Fig. 3 analogue)
    t_hist, acc = [], 0.0
    for step in range(steps):
        i = (step * batch) % (3 * batch)
        t0 = time.perf_counter()
        params, opt_state, scaling, loss, acc = train_step(
            params, opt_state, scaling, images[i:i + batch],
            labels[i:i + batch])
        jax.block_until_ready(loss)
        if step > 2:
            t_hist.append(time.perf_counter() - t0)
        if (step + 1) % 20 == 0:
            log(f"  [{'mixed' if mixed else ' fp32'}] step {step+1:4d} "
                f"loss={float(loss):.3f} acc={float(acc):.2f}")
    return float(np.mean(t_hist)), mem_bytes, float(acc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()
    cfg = vit.PAPER_DESKTOP

    print("== MPX paper §5, desktop ViT (256-wide, 800-hidden) ==")
    t32, m32, a32 = run_variant(False, args.steps, args.batch, cfg)
    t16, m16, a16 = run_variant(True, args.steps, args.batch, cfg)
    print(f"\nfp32 : {t32*1e3:7.1f} ms/step  {m32/2**20:7.0f} MiB  "
          f"final acc {a32:.2f}")
    print(f"mixed: {t16*1e3:7.1f} ms/step  {m16/2**20:7.0f} MiB  "
          f"final acc {a16:.2f}")
    print(f"memory ratio fp32/mixed = {m32/max(m16,1):.2f}x  (paper: ~1.8x)")
    print(f"step-time ratio        = {t32/max(t16,1e-9):.2f}x  "
          f"(paper: 1.57-1.7x on GPU; CPU has no bf16 fast path)")


if __name__ == "__main__":
    main()
