"""MPX quickstart — the paper's API end to end on a small MLP.

Mirrors the paper's Example 2: the ONLY changes vs a full-precision pipeline
are (1) `mpx.filter_grad(loss, loss_scaling)` instead of a plain grad, and
(2) `mpx.optimizer_update(...)` instead of update+apply.

The run also demonstrates precision observability (`repro.obs`): the loss
scale starts deliberately above what fp16 gradients can absorb, so the §3.3
controller overflows, halves, and settles — every transition lands in a
:class:`~repro.obs.precision.PrecisionStats` snapshot (trajectory, overflow
count, halving/doubling events) printed and JSON-exported at the end.

Run: PYTHONPATH=src python examples/quickstart.py

``--metrics-out precision.prom`` additionally exports the PrecisionStats
registry as Prometheus text — the file ``python -m repro.obs.postmortem
--precision`` joins into a serve incident report (the loss-scale
trajectory behind a nonfinite event).
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro import mpx
from repro.obs.precision import PrecisionStats
from repro.optim import adamw


def init_mlp(key, sizes):
    params = []
    for din, dout in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        params.append({"w": jax.random.normal(sub, (din, dout)) / din ** 0.5,
                       "b": jnp.zeros(dout)})
    return params


def forward(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.gelu(x)
    return x


def loss_fn(model, batch):
    pred = forward(model, batch["x"])
    # sums/means are overflow-prone in fp16 -> force full precision (paper §3.2)
    return mpx.force_full_precision(jnp.mean)((pred - batch["y"]) ** 2)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the PrecisionStats registry as Prometheus "
                         "text to this path (joinable via `python -m "
                         "repro.obs.postmortem --precision`)")
    args = ap.parse_args(argv)
    # fp16 like the paper's GPUs; dynamic loss scaling is then load-bearing
    mpx.set_half_dtype(jnp.float16)
    key = jax.random.key(0)
    model = init_mlp(key, [32, 128, 128, 1])
    optimizer = adamw(learning_rate=1e-3, weight_decay=0.0)
    opt_state = optimizer.init(model)
    # start the scale ABOVE what fp16 cotangents can absorb: the first steps
    # overflow, the controller halves until gradients fit, then ramps back —
    # the full §3.3 feedback loop, captured by PrecisionStats below
    loss_scaling = mpx.DynamicLossScaling(2.0 ** 24, period=50)
    precision = PrecisionStats()
    precision.record_scaling(0, loss_scaling)   # trajectory origin

    x = jax.random.normal(jax.random.key(1), (256, 32))
    y = jnp.sum(jnp.sin(x), axis=-1, keepdims=True)
    batch = {"x": x, "y": y}

    @mpx.filter_jit
    def train_step(model, opt_state, loss_scaling, batch):
        # --- the paper's Example 2(b), verbatim shape ---
        loss_scaling, grads_finite, grads = mpx.filter_grad(
            loss_fn, loss_scaling)(model, batch)
        model, opt_state = mpx.optimizer_update(
            model, optimizer, opt_state, grads, grads_finite)
        return model, opt_state, loss_scaling, grads_finite

    for step in range(200):
        model, opt_state, loss_scaling, finite = train_step(
            model, opt_state, loss_scaling, batch)
        precision.record_scaling(step + 1, loss_scaling, bool(finite))
        if (step + 1) % 50 == 0:
            print(f"step {step+1:4d}  loss={float(loss_fn(model, batch)):.4f}"
                  f"  scale={float(loss_scaling.loss_scaling):.0f}")
    mpx.set_half_dtype(jnp.bfloat16)

    snap = precision.snapshot()
    with open("quickstart_precision.json", "w") as f:
        json.dump(snap, f, indent=2)
    print(f"precision: {precision.overflow_steps} overflow steps skipped, "
          f"{precision.scale_halvings} halvings, "
          f"{precision.scale_doublings} doublings "
          f"(trajectory + counters -> quickstart_precision.json)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(precision.registry.prometheus())
        print(f"precision registry (Prometheus text) -> {args.metrics_out}")
    print("done — mixed-precision fp16 training with dynamic loss scaling")


if __name__ == "__main__":
    main()
