"""Batched serving via ``repro.serve``: the engine as a thin client.

Serves a model in bf16 (weights cast once at load — the inference half of
mixed precision) through the :class:`repro.serve.ServeEngine` subsystem,
built on the **per-layer-kind state pool**: attention layers get a paged
KV pool (fixed-size pages, per-sequence page tables, pages reserved on
admit and freed on retire), recurrent layers (Mamba-2 SSD, RG-LRU) get
O(1) per-slot fp32 state — no pages at all — reset on admit.  On top of
the pool: true chunked prefill (prompts run through the model ``--chunk``
tokens at a time via the batched ``serve_forward`` step, not
token-by-token decode), continuous batching with mixed prefill+decode
steps (finished sequences retire mid-flight, waiting requests are
admitted the same step, and decoding sequences keep emitting tokens while
another slot prefills — bound per-step prefill work with
``--max-batched-tokens``), and fp32 sampling from bf16 logits.

``--config`` picks the model: the default llama-style ``serve-20m``, or
any registry architecture id (``mamba2-130m``, ``recurrentgemma-9b``,
``mixtral-8x7b``, ...) served at its smoke size — one engine, one
scheduler, one compiled step shape across attention, SSM, hybrid and MoE
stacks.  Greedy output is token-identical to the dense per-token
``decode()`` oracle for every family (pinned by tests/test_serve_state.py).
Speculative windows need the rollback only paged KV supports, so
``--spec-tokens`` requires an attention-only config.

``--spec-tokens K`` turns every decode into a speculative
propose/verify/commit loop:

1. **propose** — the default n-gram prompt-lookup proposer drafts up to K
   tokens per decoding slot on the host (continue the most recent earlier
   occurrence of the context's suffix n-gram — free lunch on repetitive
   text, zero device cost);
2. **verify** — the slot's window (committed token + drafts) rides the
   SAME batched ``(B, chunk)`` step a single decode token would have
   used; ``serve_forward`` returns per-position logits for the window;
3. **commit** — fp32 rejection sampling accepts the longest matching
   prefix plus one corrected/bonus token, and the paged cache truncates
   back over the rejected tail (dead positions, no page churn).

With ``--temperature 0`` the accept rule is argmax equality, so greedy
speculative output is token-identical to non-speculative output — only
``steps`` and ``tokens_per_step`` in the summary change.  Acceptance rate
and tokens-per-step print with the summary; per-request rates are on
``result.metrics.acceptance_rate``.

Usage sketch (what this script does)::

    from repro import mpx, serve
    from repro.models import transformer as T

    params = mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), cfg))
    engine = serve.ServeEngine(cfg, params, n_slots=4, max_seq=128,
                               page_size=16, chunk_size=32,
                               spec_tokens=3)   # 0 disables speculation
    for prompt in prompts:
        engine.submit(prompt, max_new=32)
    for result in engine.drain():          # continuous batching inside
        print(result.request_id, result.tokens, result.metrics.ttft)
    print(engine.stats.summary())          # tok/s, TTFT, accept rate

Stochastic sampling: pass ``serve.SamplingParams(temperature=0.8,
top_k=40, top_p=0.95)`` — all transforms (and speculative verification)
run in fp32, and rejection sampling preserves the target distribution
exactly regardless of what the proposer guesses.

``--use-kernel`` routes EVERY step — prefill chunks, decode windows and
mixed batches alike — through the native paged-attention Pallas kernel
(``repro.kernels.paged_attention``): the per-slot page tables are walked
inside the kernel, so the per-step gathered contiguous KV copy never
exists and only allocated pages are streamed.  ``--pages-per-block``
widens the kernel's K-blocks to span that many logical pages per grid
step (page_size 16 alone underfills the 128-lane MXU contraction dim).
On TPU this is the hot path; off-TPU it runs in (slow) interpret mode, so
the flag is off by default here.

**Quantized serving** (``--kv-dtype i8`` / ``f8_e4m3`` / ``f8_e3m4``):
the KV page pools store sub-bf16 values with one fp32 amax scale per
(page, kv-head) in a small sidecar pool (``repro.quant``).  Every chunk's
K/V is quantized as it is written (the touched pages requantize against
a fresh amax); on read the paged-attention kernel multiplies the scales
back onto K/V blocks in VMEM right before the score/output matmuls, so
decode — which PR 3 made HBM-bound on KV page reads — streams the cache
at 1 byte/element and never materializes a dense bf16 view of it.  This
is the MPX move applied to inference: the cache's precision is a policy
component (``Policy.parse("p=f32,c=bf16,o=bf16,kv=i8")`` round-trips to
the same engine configuration), not a property of the arrays.  Greedy
outputs may differ from the bf16 baseline in near-tie tokens; logits
stay within the tolerance pinned by tests/test_serve.py.

**Prefix caching** (``--prefix-cache``): the paged pool refcounts pages
and shares committed full pages across requests whose prompts start the
same way.  Admission probes a rolling-hash prefix index — O(pages
touched), not O(context) — maps every hit into the new slot's page
table with a refcount bump, and chunked prefill skips the covered
tokens entirely: a hot-prefix request pays prefill only for its unique
suffix (the 112-token-prefix bench cell cuts hot TTFT to ~0.1x and
prefill tokens from 684 to 12).  Writes stay sound via copy-on-write —
a page is copied (values *and* quantized-format scale sidecars) before
the first divergent write — so greedy output is token-identical with
the flag on or off, for bf16 and quantized KV alike.  Retired pages
park on an LRU list and are reclaimed before any live slot would be
preempted.  This script prints per-request ``prefix=N`` skip counts and
a hit/miss/COW/shared summary line.  Recurrent and hybrid stacks accept
the flag but serve with it inert (recurrent state is a function of the
whole history, not a page of it).  ``--no-prefix-cache`` is the
explicit off switch (also the default).

**Observability** (``repro.obs``): the engine always carries a metrics
registry — queue depth, admissions, page-pool occupancy/peak, truncated
speculative tokens, per-slot token counters and TTFT/ITL histograms —
exported with ``--metrics-out metrics.prom`` as Prometheus text.
``--trace out.json`` attaches a :class:`repro.obs.Tracer` and writes a
Chrome trace at the end: open it in Perfetto (https://ui.perfetto.dev)
to see every engine tick's phases (admit / plan / device step / host
sync / commit) on the engine track and each slot's request lifecycle —
submit/admit instants, prefill chunk spans, decode window spans carrying
draft/accept counts, truncate markers on rejected speculative tails, and
retire — as a per-slot timeline.  The instrumentation reads host state
only; tracing adds zero device syncs and <3% tok/s (the bench's
``serving_obs_overhead_pct`` row prices it).  ``--journal out.jsonl``
additionally attaches the flight recorder: every external input to the
drive (config fingerprint, fault schedule, clock samples, submits,
cancels) plus a per-tick digest lands in an append-only JSONL journal
that ``python -m repro.obs.journal out.jsonl`` replays deterministically
(token-identical, or the first divergent tick named) and ``python -m
repro.obs.postmortem out.jsonl`` renders as a per-request incident
report — ``--chaos --journal`` records a poisoned, pool-starved drive
you can replay and dissect offline.

**Failure semantics** (the resilience layer): every request ends with
exactly one result whose ``status`` is ``ok`` / ``cancelled`` /
``timeout`` / ``failed`` — partial output is always delivered, never
dropped.  ``--max-queue N`` bounds the waiting queue: a full queue makes
``submit()`` raise :class:`serve.EngineOverloaded` (typed backpressure
carrying queue depth and an admission-time estimate) — this script
handles it the way a real client should, by stepping the engine and
resubmitting.  ``--deadline-ms`` attaches an end-to-end deadline to
every request; expiry retires it as ``timeout`` at the next tick
boundary with whatever tokens it has.  Under page-pool pressure the
scheduler preempts the youngest decoding slot and requeues it as a
recompute prefill — preempted requests still finish ``ok``,
token-identical under greedy sampling.  ``--chaos`` arms the
:mod:`repro.serve.faults` injector with a small scripted schedule
(NaN-poison one request's logits mid-decode, hold the page pool for a
few ticks) to show the failure paths live: the poisoned request ends
``failed`` with an explanatory ``metrics.error``, its batch neighbors'
output is untouched, and the drive's summary counts every status.

Run: PYTHONPATH=src python examples/serve.py --requests 12 --slots 4 \
         --spec-tokens 3 --kv-dtype i8 \
         --trace serve_trace.json --metrics-out metrics.prom
     PYTHONPATH=src python examples/serve.py --chaos --max-queue 8 \
         --deadline-ms 60000
     PYTHONPATH=src python examples/serve.py --prefix-cache --requests 8
"""
import argparse

import jax
import numpy as np

from repro import mpx, serve
from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.obs import JournalRecorder, Tracer

SERVE_MODEL = ModelConfig(
    name="serve-20m", family="dense",
    n_layers=6, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=1024, vocab_size=8192,
    pattern=("attn",), mlp="swiglu", rope_theta=10000.0,
    tie_embeddings=True, remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", type=str, default="serve-20m",
                    choices=["serve-20m"] + list(registry.ARCH_IDS),
                    help="model to serve: the default dense serve-20m or "
                         "any registry architecture (smoke-sized) — "
                         "attention, SSM, hybrid and MoE stacks all run "
                         "through the same state-pool engine")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (batch size)")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV-cache page size (tokens)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size (tokens per prefill step)")
    ap.add_argument("--max-batched-tokens", type=int, default=None,
                    help="per-step token budget (decode first, then "
                         "prefill, then drafts; default: slots*chunk)")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="speculative window: up to K n-gram-proposed "
                         "draft tokens verified per decode step "
                         "(0 disables)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run all steps through the paged-attention "
                         "Pallas kernel (TPU hot path; interpret mode "
                         "elsewhere)")
    ap.add_argument("--pages-per-block", type=int, default=1,
                    help="logical pages per kernel K-block (fill the MXU "
                         "lane dim; only meaningful with --use-kernel)")
    ap.add_argument("--kv-dtype", type=str, default="bf16",
                    choices=["bf16", "i8", "f8_e4m3", "f8_e3m4"],
                    help="KV-cache page storage format: bf16 passthrough "
                         "or quantized with per-page amax scales "
                         "(repro.quant; dequantized inside the kernel)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="share committed KV pages across requests with a "
                         "common prompt prefix (refcounted, copy-on-write; "
                         "greedy output is identical on/off); "
                         "--no-prefix-cache is the explicit off switch")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the waiting queue: a full queue makes "
                         "submit() raise EngineOverloaded (typed "
                         "backpressure; this script then steps the "
                         "engine and resubmits)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request end-to-end deadline; expiry "
                         "retires the request as status=timeout with "
                         "its partial output")
    ap.add_argument("--chaos", action="store_true",
                    help="arm the fault injector: NaN-poison request "
                         "1's logits at tick 3 and hold the page pool "
                         "over ticks 2-5 — demonstrates the nonfinite "
                         "guard, pool-pressure handling and per-request "
                         "failure isolation")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--trace", type=str, default=None,
                    help="write a Chrome trace of the whole drive to this "
                         "path (open in Perfetto: per-slot request "
                         "timelines + engine tick phases)")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the engine's metrics registries to this "
                         "path as Prometheus text")
    ap.add_argument("--journal", type=str, default=None,
                    help="record the drive's flight-recorder journal "
                         "(JSONL) to this path; replay it later with "
                         "`python -m repro.obs.journal <path>` and render "
                         "the incident report with `python -m "
                         "repro.obs.postmortem <path>`")
    args = ap.parse_args()

    if args.config == "serve-20m":
        cfg = SERVE_MODEL
    else:
        cfg = registry.get_smoke_config(args.config)
        if not cfg.supports_decode():
            ap.error(f"--config {args.config}: {cfg.family} models have "
                     f"no decode path to serve")
    params = mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), cfg))
    tracer = Tracer(process_name="repro.serve") if args.trace else None
    # param_seed=0 matches the init above, so the journal is
    # self-contained: replay rebuilds the weights from the header alone
    journal = (JournalRecorder(args.journal, param_seed=0)
               if args.journal else None)
    faults = None
    if args.chaos:
        faults = (serve.FaultInjector()
                  .poison_logits(1, tick=3)
                  .exhaust_pool(2, until_tick=6))
    engine = serve.ServeEngine(
        cfg, params, n_slots=args.slots, max_seq=args.max_seq,
        page_size=args.page_size, chunk_size=args.chunk,
        max_batched_tokens=args.max_batched_tokens,
        spec_tokens=args.spec_tokens,
        use_kernel=args.use_kernel, pages_per_block=args.pages_per_block,
        kv_dtype=args.kv_dtype,
        prefix_cache=args.prefix_cache,
        max_queue=args.max_queue,
        sampling=serve.SamplingParams(temperature=args.temperature,
                                      top_k=args.top_k, top_p=args.top_p),
        tracer=tracer, faults=faults, journal=journal)

    rng = np.random.default_rng(0)
    # with --prefix-cache, give every request a shared "system prompt"
    # spanning a few pages so the sharing layer has something to hit;
    # requests still diverge on their random suffix
    system = (rng.integers(1, cfg.vocab_size,
                           3 * args.page_size).tolist()
              if args.prefix_cache else [])
    for _ in range(args.requests):
        prompt = system + rng.integers(1, cfg.vocab_size,
                                       rng.integers(4, 12)).tolist()
        while True:
            try:
                engine.submit(prompt, max_new=args.max_new,
                              deadline_ms=args.deadline_ms)
                break
            except serve.EngineOverloaded as e:
                # the backpressure contract: back off (here: run a tick
                # to drain the queue) and resubmit
                eta = (f"~{e.est_wait_s:.1f}s" if e.est_wait_s is not None
                       else "unknown")
                print(f"overloaded (queue {e.queue_depth}/{e.max_queue}, "
                      f"eta {eta}) — stepping engine and retrying")
                engine.step()

    statuses = {}
    for res in engine.drain():
        statuses[res.status] = statuses.get(res.status, 0) + 1
        ttft = res.metrics.ttft
        rate = res.metrics.acceptance_rate
        spec = f" accept {rate:.0%}" if rate is not None else ""
        tail = "" if res.status == "ok" else f" [{res.status}]"
        if res.metrics.error:
            tail += f" ({res.metrics.error})"
        ttft_s = f"ttft {ttft * 1e3:.0f}ms" if ttft is not None else "no ttft"
        px = (f" prefix={res.metrics.cached_prefix_tokens}"
              if res.metrics.cached_prefix_tokens else "")
        print(f"req {res.request_id:2d}: prompt[{len(res.prompt)}] -> "
              f"{len(res.tokens)} tokens: {res.tokens[:8]}... "
              f"({ttft_s}{spec}{px}){tail}")
    print("statuses: "
          + " ".join(f"{k}={v}" for k, v in sorted(statuses.items())))
    if args.prefix_cache:
        snap = engine.metrics_snapshot()
        print(f"prefix cache: {int(snap['serve_prefix_hits_total'])} page "
              f"hits, {int(snap['serve_prefix_miss_total'])} probe misses, "
              f"{int(snap['serve_cow_copies_total'])} COW copies, "
              f"{int(snap['serve_pages_shared'])} pages shared / "
              f"{int(snap['serve_pages_cached'])} cached now")

    s = engine.stats.summary()
    print(f"\n{int(s['requests'])} requests, {int(s['new_tokens'])} tokens "
          f"in {s['elapsed_s']:.2f}s ({s['tok_per_s']:.0f} tok/s, "
          f"{int(s['prefill_steps'])} prefill + "
          f"{int(s['mixed_steps'])} mixed + "
          f"{int(s['decode_steps'])} decode steps, "
          f"{100 * s['mean_occupancy']:.0f}% occupancy, "
          f"{args.slots} slots)")
    if "itl_p50_s" in s:
        print(f"inter-token latency: p50 {s['itl_p50_s']*1e3:.1f}ms, "
              f"p95 {s['itl_p95_s']*1e3:.1f}ms")
    if s["spec_proposed"]:
        print(f"speculation: {int(s['spec_accepted'])}/"
              f"{int(s['spec_proposed'])} drafts accepted "
              f"({100 * s['spec_accept_rate']:.0f}%), "
              f"{s['tokens_per_step']:.2f} tokens/step")
    if tracer is not None:
        tracer.export(args.trace)
        print(f"trace: {len(tracer.events)} events -> {args.trace} "
              f"(open in https://ui.perfetto.dev)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(engine.prometheus())
        print(f"metrics: Prometheus snapshot -> {args.metrics_out}")
    if journal is not None:
        journal.close()
        print(f"journal: flight recorder -> {args.journal} "
              f"(replay: python -m repro.obs.journal {args.journal}; "
              f"report: python -m repro.obs.postmortem {args.journal})")


if __name__ == "__main__":
    main()
