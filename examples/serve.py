"""Batched serving with a KV cache and continuous-batching-lite scheduling.

Serves a small llama-style model in bf16 (weights cast once at load — the
inference half of mixed precision): a request queue feeds a fixed set of
decode slots; finished sequences free their slot for the next request, so
the jitted single-token `serve_step` runs at full batch occupancy — the
decode_32k / long_500k dry-run cells lower exactly this function.

Run: PYTHONPATH=src python examples/serve.py --requests 12 --slots 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import mpx
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.train.steps import make_serve_step

SERVE_MODEL = ModelConfig(
    name="serve-20m", family="dense",
    n_layers=6, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=1024, vocab_size=8192,
    pattern=("attn",), mlp="swiglu", rope_theta=10000.0,
    tie_embeddings=True, remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (batch size)")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = SERVE_MODEL
    params = mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), cfg))
    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    queue = [{"id": i,
              "prompt": rng.integers(1, cfg.vocab_size,
                                     rng.integers(4, 12)).tolist()}
             for i in range(args.requests)]
    done = []

    # slot state: one shared batched KV cache; per-slot bookkeeping
    cache = T.init_cache(cfg, args.slots, args.max_seq, jnp.bfloat16)
    slots = [None] * args.slots
    tokens = jnp.zeros((args.slots, 1), jnp.int32)
    pos = 0
    t0 = time.perf_counter()
    steps = 0

    def admit():
        nonlocal tokens
        for s in range(args.slots):
            if slots[s] is None and queue:
                req = queue.pop(0)
                # prefill-by-decode: feed prompt tokens one step at a time
                slots[s] = {"id": req["id"], "prompt": req["prompt"],
                            "fed": 0, "out": [], "born": pos}
                tokens = tokens.at[s, 0].set(req["prompt"][0])
                slots[s]["fed"] = 1

    admit()
    while any(slots) or queue:
        next_tok, cache = serve_step(params, cache, tokens, jnp.int32(pos))
        steps += 1
        pos += 1
        nt = np.asarray(next_tok)
        for s in range(args.slots):
            st = slots[s]
            if st is None:
                continue
            if st["fed"] < len(st["prompt"]):          # still prefilling
                tokens = tokens.at[s, 0].set(st["prompt"][st["fed"]])
                st["fed"] += 1
            else:                                      # generating
                tok = int(nt[s, 0])
                st["out"].append(tok)
                tokens = tokens.at[s, 0].set(tok)
                if len(st["out"]) >= args.max_new or pos >= args.max_seq - 1:
                    done.append(st)
                    slots[s] = None
        admit()
        if pos >= args.max_seq - 1:
            break

    dt = time.perf_counter() - t0
    for st in sorted(done, key=lambda s: s["id"]):
        print(f"req {st['id']:2d}: prompt[{len(st['prompt'])}] -> "
              f"{len(st['out'])} tokens: {st['out'][:8]}...")
    total = sum(len(s["out"]) for s in done)
    print(f"\n{len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/max(dt,1e-9):.0f} tok/s, {steps} batched steps, "
          f"{args.slots} slots)")


if __name__ == "__main__":
    main()
