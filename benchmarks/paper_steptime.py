"""Paper Figure 3: training-step time vs batch size, fp32 vs mixed.

The paper reports 1.57–1.7× step-time speedup on GPUs.  This container is a
CPU, where bf16 has no hardware fast path, so we report BOTH:

- the honest measured CPU wall time (mixed is not expected to win here —
  documented, not hidden), and
- the TPU-roofline-derived expectation from the compiled artifacts' memory
  traffic (the mechanism behind the paper's speedup on the RTX4070, whose
  tensor cores are fp32-rate-equal: reduced memory movement).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import mpx
from repro.models import vit
from repro.optim import adamw


def _timed_step(cfg, batch: int, mixed: bool, iters: int = 4):
    params = vit.init_params(jax.random.key(0), cfg)
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    loss_fn = vit.make_loss_fn(cfg)
    scaling = mpx.DynamicLossScaling(2.0 ** 15)
    images = jax.random.normal(jax.random.key(1),
                               (batch, cfg.image_size, cfg.image_size, 3))
    labels = jax.random.randint(jax.random.key(2), (batch,), 0,
                                cfg.n_classes)

    @jax.jit
    def step(params, opt_state, images, labels):
        s, finite, (loss, aux), grads = mpx.filter_value_and_grad(
            loss_fn, scaling, has_aux=True,
            use_mixed_precision=mixed)(params, {"images": images,
                                                "labels": labels})
        params, opt_state = mpx.optimizer_update(params, opt, opt_state,
                                                 grads, finite)
        return params, opt_state, loss

    params, opt_state, _ = step(params, opt_state, images, labels)  # compile
    jax.block_until_ready(params)
    t0 = time.perf_counter()
    for _ in range(iters):
        params, opt_state, loss = step(params, opt_state, images, labels)
    jax.block_until_ready(params)
    wall = (time.perf_counter() - t0) / iters

    # bytes from the compiled artifact (TPU roofline proxy)
    comp = step.lower(params, opt_state, images, labels).compile()
    from repro.analysis.hlo import cost_dict
    byts = float(cost_dict(comp).get("bytes accessed", 0.0))
    return wall, byts


def run() -> list[tuple[str, float, str]]:
    cfg = vit.ViTConfig(d_model=128, n_layers=3, n_heads=4, d_ff=256)
    rows = []
    for batch in (16, 48):
        full_t, full_b = _timed_step(cfg, batch, mixed=False)
        half_t, half_b = _timed_step(cfg, batch, mixed=True)
        rows.append((
            f"paper_fig3_steptime_b{batch}", full_t * 1e6,
            f"cpu_fp32={full_t*1e3:.1f}ms cpu_mixed={half_t*1e3:.1f}ms "
            f"hbm_bytes_ratio={full_b/max(half_b,1):.2f}x "
            f"(paper speedup 1.57-1.7x on GPU)"))
    return rows
