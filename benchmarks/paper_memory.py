"""Paper Figure 2: accelerator memory vs batch size, fp32 vs mixed.

The paper measures VRAM for ViT training on an RTX4070 as batch grows and
reports ~1.8× reduction from mixed precision.

Backend caveat (measured, documented): the CPU XLA backend *materializes
fp32 copies of bf16 dot operands* (no native bf16 units), so neither
``memory_analysis().temp_size`` nor optimized-HLO buffer sizes can exhibit
the GPU/TPU saving here.  We therefore measure the backend-INDEPENDENT
artifact: the **pre-optimization StableHLO** (``lowered.as_text()``), whose
tensor types are exactly the dtypes the pipeline requested — on GPU/TPU
these are the buffers that hit HBM.  fp32-pipeline vs mixed-pipeline ratio
of produced-value bytes is the Fig. 2 analogue (paper: 1.8×).
"""
from __future__ import annotations

import re
import time

import jax
import jax.numpy as jnp

from repro import mpx
from repro.models import vit
from repro.optim import adamw

_STABLEHLO_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "i32": 4,
                    "i64": 8, "i8": 1, "i1": 1, "ui8": 1, "ui32": 4}
_RESULT_TY_RE = re.compile(r"->\s*tensor<([0-9x]*)x?(\w+)>")
_PLAIN_TY_RE = re.compile(r":\s*tensor<([0-9x]*)x?(\w+)>\s*$")


def produced_bytes_by_dtype(stablehlo_text: str) -> dict:
    """Sum bytes of op-result tensors by dtype from StableHLO text."""
    out: dict = {}
    for line in stablehlo_text.splitlines():
        m = _RESULT_TY_RE.search(line) or _PLAIN_TY_RE.search(line)
        if not m:
            continue
        dims, dtype = m.group(1), m.group(2)
        if dtype not in _STABLEHLO_BYTES:
            continue
        n = 1
        for d in dims.split("x"):
            if d:
                n *= int(d)
        out[dtype] = out.get(dtype, 0) + n * _STABLEHLO_BYTES[dtype]
    return out


def _compile_step(cfg: vit.ViTConfig, batch: int, mixed: bool):
    params = jax.eval_shape(lambda: vit.init_params(jax.random.key(0), cfg))
    opt = adamw(1e-3)
    opt_state = jax.eval_shape(opt.init, params)
    loss_fn = vit.make_loss_fn(cfg)
    scaling = mpx.DynamicLossScaling(2.0 ** 15)

    def step(params, opt_state, images, labels):
        s, finite, (loss, aux), grads = mpx.filter_value_and_grad(
            loss_fn, scaling, has_aux=True,
            use_mixed_precision=mixed)(params, {"images": images,
                                                "labels": labels})
        params, opt_state = mpx.optimizer_update(params, opt, opt_state,
                                                 grads, finite)
        return params, opt_state, loss

    img = jax.ShapeDtypeStruct((batch, cfg.image_size, cfg.image_size, 3),
                               jnp.float32)
    lab = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return jax.jit(step).lower(params, opt_state, img, lab)


def run() -> list[tuple[str, float, str]]:
    cfg = vit.PAPER_DESKTOP
    rows = []
    for batch in (32, 128, 512):
        t0 = time.perf_counter()
        l32 = _compile_step(cfg, batch, mixed=False)
        l16 = _compile_step(cfg, batch, mixed=True)
        us = (time.perf_counter() - t0) * 1e6
        b32 = produced_bytes_by_dtype(l32.as_text())
        b16 = produced_bytes_by_dtype(l16.as_text())
        tot32, tot16 = sum(b32.values()), sum(b16.values())
        rows.append((
            f"paper_fig2_memory_b{batch}", us,
            f"produced fp32={tot32/2**20:.0f}MiB mixed={tot16/2**20:.0f}MiB "
            f"ratio={tot32/max(tot16,1):.2f}x (paper:1.8x); "
            f"bf16_share={b16.get('bf16',0)/max(tot16,1)*100:.0f}%"))
    return rows
