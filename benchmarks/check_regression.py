"""Bench-regression guard: compare a fresh ``serving_bench.json``
artifact against the committed ``benchmarks/baseline.json``.

CI runs the serving bench and then this check, so two classes of
regression fail the workflow loudly instead of silently drifting:

- **schema drift** — a row present in the baseline but missing from the
  run (or vice versa) means ``expected_row_names()`` changed without the
  baseline being regenerated; downstream artifact consumers key on row
  names, so both directions are errors.
- **analytic-model drift** — the ``*hbm_bytes*`` rows are *computed*
  (bytes the decode path touches per token), not measured: identical
  inputs must give bit-identical values on any machine, so they are
  compared **exactly**.  A change means the cost model changed — do it
  deliberately and regenerate the baseline.

Wall-clock rows (``serving_tok_*`` / ``serving_ttft_*`` /
``serving_itl_*``) are measured on whatever hardware CI happens to run,
so they get a deliberately loose *relative* tolerance (default 25x either
way) that only catches catastrophic regressions — a hang, an accidental
O(n^2) path, interpret-mode left on — not scheduler noise.  Everything
else (ratios, percentages, counts) is checked for presence only; their
meaningful bounds are asserted inside the bench itself.

Regenerating the baseline after a deliberate change::

    PYTHONPATH=src python -m benchmarks.serving_bench --json \
        benchmarks/baseline.json

Usage (as CI runs it)::

    python -m benchmarks.check_regression serving_bench.json \
        benchmarks/baseline.json
"""
from __future__ import annotations

import json
import sys
from typing import List

#: wall-clock rows: measured us-per-token/latency values, hardware-bound
WALLCLOCK_PREFIXES = ("serving_tok_", "serving_ttft_", "serving_itl_")

#: default relative tolerance for wall-clock rows — loose on purpose:
#: CI hardware varies run to run, the guard is for catastrophes
DEFAULT_TOLERANCE = 25.0


def _by_name(rows: List[dict]) -> dict:
    names = [r["name"] for r in rows]
    dupes = {n for n in names if names.count(n) > 1}
    if dupes:
        raise ValueError(f"duplicate row names: {sorted(dupes)}")
    return {r["name"]: float(r["value"]) for r in rows}


def compare(current: List[dict], baseline: List[dict],
            tolerance: float = DEFAULT_TOLERANCE) -> List[str]:
    """All violations (empty list = pass).

    ``current`` / ``baseline`` are the bench's JSON row lists
    (``[{"name": ..., "value": ..., "derived": ...}, ...]``).
    """
    if tolerance <= 1.0:
        raise ValueError(f"tolerance must be > 1 (a ratio): {tolerance}")
    cur, base = _by_name(current), _by_name(baseline)
    errors = []
    missing = sorted(set(base) - set(cur))
    extra = sorted(set(cur) - set(base))
    if missing:
        errors.append(
            f"schema drift: baseline rows missing from the run: {missing}")
    if extra:
        errors.append(
            f"schema drift: run rows absent from the baseline: {extra} "
            f"— regenerate benchmarks/baseline.json deliberately")
    for name in sorted(set(cur) & set(base)):
        c, b = cur[name], base[name]
        if "hbm_bytes" in name:
            if c != b:
                errors.append(
                    f"{name}: analytic bytes row drifted — baseline "
                    f"{b!r}, run {c!r} (these are computed, not "
                    f"measured: exact match required)")
        elif name.startswith(WALLCLOCK_PREFIXES):
            lo, hi = b / tolerance, b * tolerance
            if not (lo <= c <= hi):
                errors.append(
                    f"{name}: wall-clock row {c:.1f} outside "
                    f"[{lo:.1f}, {hi:.1f}] ({tolerance}x tolerance "
                    f"around baseline {b:.1f})")
    return errors


def main(argv: List[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh serving_bench.json artifact")
    ap.add_argument("baseline", help="committed benchmarks/baseline.json")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="relative tolerance (ratio) for wall-clock rows "
                         f"(default {DEFAULT_TOLERANCE}x)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    errors = compare(current, baseline, tolerance=args.tolerance)
    if errors:
        print(f"bench regression check FAILED ({len(errors)} violation(s))")
        for e in errors:
            print(f"  - {e}")
        return 1
    n_exact = sum(1 for r in baseline if "hbm_bytes" in r["name"])
    n_wall = sum(1 for r in baseline
                 if r["name"].startswith(WALLCLOCK_PREFIXES))
    print(f"bench regression check passed: {len(baseline)} rows "
          f"({n_exact} exact, {n_wall} wall-clock at "
          f"{args.tolerance}x, rest presence-only)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
