"""Dynamic-loss-scaling overhead microbench (paper §3.3).

The paper's pitch is that MPX's scaling machinery is a drop-in with
negligible cost.  Measures the train-step wall time of NoOp vs Dynamic
scaling on the same model, plus the fused Pallas unscale+isfinite kernel
vs its unfused jnp reference.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import mpx
from repro.configs import registry, shapes
from repro.configs.base import RunConfig
from repro.kernels import ops, ref
from repro.optim import make_optimizer
from repro.train import state as S
from repro.train.steps import make_train_step


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> list[tuple[str, float, str]]:
    rows = []
    cfg = registry.get_smoke_config("llama3-8b")
    batch = shapes.make_batch(cfg, 8, 32)
    times = {}
    for name, ls in (("dynamic", "dynamic"), ("none", "none")):
        run_cfg = RunConfig(loss_scaling=ls)
        opt = make_optimizer(run_cfg)
        st = S.init_state(jax.random.key(0), cfg, run_cfg, opt)
        step = jax.jit(make_train_step(cfg, run_cfg, opt))
        times[name] = _time(lambda s: step(s, batch)[1]["loss"], st)
    overhead = (times["dynamic"] / times["none"] - 1) * 100
    rows.append(("loss_scaling_overhead", times["dynamic"] * 1e6,
                 f"dynamic={times['dynamic']*1e3:.2f}ms "
                 f"noop={times['none']*1e3:.2f}ms "
                 f"overhead={overhead:+.1f}%"))

    g = jax.random.normal(jax.random.key(0), (1 << 16,), jnp.bfloat16)
    t_kernel = _time(lambda x: ops.unscale_and_check(x, 1 / 512.0)[0], g)
    t_ref = _time(jax.jit(lambda x: ref.unscale_finite_ref(x, 1 / 512.0)[0]),
                  g)
    rows.append(("unscale_finite_fused_64k", t_kernel * 1e6,
                 f"kernel(interp)={t_kernel*1e3:.2f}ms "
                 f"jnp_ref={t_ref*1e3:.2f}ms (interpret-mode timing; "
                 f"TPU win is 3 HBM passes -> 1)"))
    return rows
