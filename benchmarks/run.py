"""Benchmark harness — one bench per paper table/figure (+ framework extras).

Prints ``name,us_per_call,derived`` CSV, per the repo contract:

- ``paper_fig2_memory_*``   — Fig. 2: memory vs batch, fp32 vs mixed
- ``paper_fig3_steptime_*`` — Fig. 3: step time vs batch, fp32 vs mixed
- ``loss_scaling_*``        — §3.3: dynamic-scaling overhead + fused kernel
- ``attention_*``           — blocked-vs-plain attention (memory roofline)
- ``serving_*``             — repro.serve engine: tok/s + TTFT + inter-token
  p50/p95 vs slot count, paged-kernel vs gather-path rows on an identical
  workload, estimated HBM bytes per decode token for both paths and per
  KV format, speculative-decode accept/steps rows, and
  ``serving_obs_overhead_pct`` — the tok/s cost of request tracing
  (``repro.obs``; budget <3%)

Run: ``PYTHONPATH=src python -m benchmarks.run``
(``python -m benchmarks.serving_bench --json out.json`` runs just the
serving trajectory and archives it — the CI artifact.)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (attention_bench, loss_scaling_bench,
                            paper_memory, paper_steptime, serving_bench)
    modules = [paper_memory, paper_steptime, loss_scaling_bench,
               attention_bench, serving_bench]
    print("name,us_per_call,derived")
    failed = False
    for mod in modules:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception:  # noqa: BLE001 — report all benches
            traceback.print_exc()
            failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
