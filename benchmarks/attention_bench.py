"""Attention-path benchmark: plain vs blocked-XLA vs Pallas(interpret).

Wall time on CPU (indicative only) + compiled bytes for the memory-roofline
story: the blocked path never materializes the (S, S) score tensor, which
is what lets 32k-prefill cells fit HBM (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.nn import attention as A


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> list[tuple[str, float, str]]:
    b, s, h, d = 1, 2048, 4, 64
    q = jax.random.normal(jax.random.key(0), (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (b, s, h, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (b, s, h, d), jnp.bfloat16)

    plain = jax.jit(lambda q, k, v: A.attend_plain(
        q, k, v, causal=True, window=0, cap=0.0))
    blocked = jax.jit(lambda q, k, v: A.attend_blocked(
        q, k, v, causal=True, window=0, cap=0.0, q_block=512, k_block=512))

    t_plain = _time(plain, q, k, v)
    t_blocked = _time(blocked, q, k, v)

    from repro.analysis.hlo import cost_dict
    bytes_plain = float(cost_dict(jax.jit(plain).lower(q, k, v).compile())
                        .get("bytes accessed", 0))
    bytes_blocked = float(cost_dict(jax.jit(blocked).lower(q, k, v)
                                    .compile()).get("bytes accessed", 0))
    return [
        ("attention_plain_2k", t_plain * 1e6,
         f"bytes={bytes_plain/2**20:.0f}MiB"),
        ("attention_blocked_2k", t_blocked * 1e6,
         f"bytes={bytes_blocked/2**20:.0f}MiB "
         f"({bytes_plain/max(bytes_blocked,1):.2f}x fewer)"),
    ]
