"""Serving-engine benchmark: tok/s, TTFT, ITL, paged-kernel vs gather,
and speculative decoding vs baseline.

Drives the full ``repro.serve`` stack (paged KV cache, mixed prefill+decode
chunk steps, continuous batching, greedy fp32 sampling) over a fixed ragged
request queue on a small dense model.  Wall time on CPU is indicative only;
the shape of the trajectory — throughput scaling with slot count while TTFT
holds, and inter-token p50/p95 staying near one step time instead of
ballooning whenever another slot prefills — is the serving-side analogue of
the paper's batch-size sweeps.

The ``*_gather`` vs ``*_paged`` rows compare the two attention paths over
the identical workload: the gather path materializes every slot's padded
KV prefix as a dense contiguous copy each step, the paged path streams
only the allocated pages through the page-table-walking Pallas kernel
(``repro.kernels.paged_attention``).  Off-TPU the kernel runs in interpret
mode, so its *wall-clock* rows are not meaningful there — the
``serving_hbm_bytes_decode_*`` rows carry the comparison: estimated HBM
bytes touched per decode token, the quantity the decode hot path is
actually bound by.

The ``serving_*_kv{bf16,i8,f8}`` rows sweep the KV-cache storage format
(``repro.quant``): tok/s per format over an identical workload, and —
the trajectory metric — ``serving_hbm_bytes_decode_kv*``, the estimated
HBM bytes the paged kernel streams per decode token under each format
(quantized pools read at 1 byte/element plus the fp32 scale sidecar,
which is why the i8 row sits at ~0.51x of bf16 instead of exactly 0.5x).
Off-TPU the wall-clock rows run the gather fallback (the kernel
interprets); the bytes rows carry the comparison.

The ``serving_spec_*`` rows measure speculative decoding with the n-gram
prompt-lookup proposer on a repeat-heavy workload (greedy, so the
speculative engine is token-identical to the baseline by construction):
``serving_spec_accept_rate`` (accepted/proposed drafts),
``serving_spec_tokens_per_step`` (with the baseline's steps-per-token
ratio in the derived column — the headline: how many engine ticks each
generated token costs), plus a ``serving_tok_spec_{base,spec}`` tok/s
pair over the identical workload.

The ``serving_obs_overhead_pct`` row drives the identical comparison
workload twice — tracer off vs a live :class:`repro.obs.Tracer` — and
reports the tok/s cost of tracing as a percentage (budget: <3%; the
instrumentation reads host state only, so the cost is pure Python on the
tick path, pinned structurally by the zero-added-syncs test in
tests/test_obs.py).  ``--trace`` / ``--metrics-out`` additionally export
a Chrome trace (Perfetto per-slot timeline of a speculative-decode
drive: prefill chunks, decode windows with draft/accept counts,
truncates, retires) and the Prometheus text snapshot of the engine's
registries — CI archives both next to the JSON rows.

The ``serving_journal_overhead_pct`` row prices the flight recorder the
same way (journal off vs a live :class:`repro.obs.JournalRecorder`,
interleaved best-of-N, budget <3%), and ``--journal`` records the
scripted chaos drive's journal — CI replays it with ``python -m
repro.obs.journal`` (token-identical re-drive or the first divergent
tick) and renders the postmortem with ``python -m
repro.obs.postmortem``, archiving both.

The ``serving_tok_arch_{attn,ssm,rglru,hybrid}`` rows drive one config
per layer-kind family through the same engine — the per-layer-kind state
pool serves attention (paged KV), pure SSD and pure RG-LRU (O(1)
per-slot recurrent state, zero pages) and the recurrentgemma-shaped
hybrid (both at once) with identical scheduling — so the trajectory
shows serving throughput per architecture, not just for transformers.

The resilience rows price the failure paths:
``serving_preempt_recompute_overhead_pct`` runs the identical greedy
workload on an ample vs a deliberately too-small page pool (preemption +
recompute-prefill, token-identical output) and reports the extra engine
steps as a percentage — 0 when preemption never fires; and
``serving_resilience_statuses`` drives one scripted chaos schedule
(NaN-poisoned logits, a clock-jump deadline expiry, a cancellation) and
reports the count of distinct terminal statuses with the per-status
tally in the derived column.  ``--fault-trace`` exports the chaos
drive's Chrome trace for CI to archive beside the JSON rows.

The ``serving_prefix_*`` rows price refcounted prefix-page sharing on a
repeated-prefix workload (a hot 7-page system prompt, short distinct
suffixes): ``serving_prefix_ttft_hot_ratio`` (hot-request TTFT with the
cache on as a fraction of the no-sharing baseline — one chunk step
instead of the whole prompt), ``serving_prefix_prefill_tokens_hot``
(prefill tokens actually fed — the cached prefix is skipped entirely,
asserted structurally), and ``serving_prefix_pages_resident`` (one
physical prefix copy serving every request, vs per-request page allocs
without sharing).

Row names are pinned by :func:`expected_row_names` — ``run()`` refuses
to return a row set that drifted from it, and the fast schema test in
``tests/test_quant.py`` pins the trajectory-critical names, so a rename
cannot silently break the CI artifact consumers.

Standalone run (used by CI to archive the trajectory)::

    PYTHONPATH=src python -m benchmarks.serving_bench --json out.json \
        --trace serving_trace.json --metrics-out serving_metrics.prom
"""
from __future__ import annotations

import numpy as np

SLOT_COUNTS = (2, 4, 8)
REQUESTS = 16
MAX_NEW = 16

# kernel-vs-gather comparison cell (kept small: off-TPU the kernel runs
# in interpret mode)
CMP_SLOTS = 4
CMP_REQUESTS = 8
CMP_MAX_NEW = 8
CMP_MAX_SEQ = 64
CMP_PAGE = 16

# speculative-decode cell: repeat-heavy prompts, window of SPEC_TOKENS
SPEC_TOKENS = 3
SPEC_SLOTS = 2
SPEC_REQUESTS = 6
SPEC_MAX_NEW = 32

# KV-dtype cell: (row label, repro.quant format name).  The f8 row uses
# e4m3; e3m4's bytes are identical (both 1 byte/elem + the same sidecar).
KV_CELL = (("bf16", "bf16"), ("i8", "i8"), ("f8", "f8_e4m3"))

# prefix-cache cell: repeated-prefix workload (hot system prompt).
# PREFIX_LEN is page-aligned on purpose: 7 full pages register, and the
# hot requests' short distinct suffixes are the only uncached feed.
PREFIX_SLOTS = 2
PREFIX_LEN = 112
PREFIX_SUFFIX = 2
PREFIX_REQUESTS = 6
PREFIX_MAX_NEW = 8
PREFIX_PAGE = 16


def expected_row_names() -> list:
    """Every row ``run()`` emits, in order — the CI artifact schema.

    CI uploads the ``--json`` rows as the serving trajectory; downstream
    comparisons key on these names, so ``run()`` validates its output
    against this list and the fast test in tests/test_quant.py pins the
    trajectory-critical entries.
    """
    names = []
    for slots in SLOT_COUNTS:
        names += [f"serving_tok_{slots}slots", f"serving_ttft_{slots}slots",
                  f"serving_itl_p95_{slots}slots"]
    for label in ("gather", "paged"):
        names += [f"serving_tok_{CMP_SLOTS}slots_{label}",
                  f"serving_itl_p95_{CMP_SLOTS}slots_{label}"]
    names += ["serving_hbm_bytes_decode_gather",
              "serving_hbm_bytes_decode_paged"]
    names += [f"serving_tok_kv{label}" for label, _ in KV_CELL]
    names += [f"serving_hbm_bytes_decode_kv{label}" for label, _ in KV_CELL]
    names += ["serving_tok_spec_base", "serving_tok_spec_spec",
              "serving_spec_accept_rate", "serving_spec_tokens_per_step"]
    names += ["serving_obs_overhead_pct", "serving_journal_overhead_pct"]
    names += [f"serving_tok_arch_{label}" for label, _ in _arch_cell_cfgs()]
    names += ["serving_preempt_recompute_overhead_pct",
              "serving_resilience_statuses"]
    names += ["serving_prefix_ttft_hot_ratio",
              "serving_prefix_prefill_tokens_hot",
              "serving_prefix_pages_resident"]
    return names


def check_rows(rows) -> None:
    """Raise if the emitted row names drifted from the pinned schema."""
    got = [name for name, _, _ in rows]
    want = expected_row_names()
    if got != want:
        missing = [n for n in want if n not in got]
        extra = [n for n in got if n not in want]
        raise RuntimeError(
            "serving_bench rows drifted from expected_row_names() — "
            "update the schema (and the pinned names in "
            f"tests/test_quant.py) deliberately; missing={missing} "
            f"extra={extra}")


def _bench_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(
        name="serve-bench", family="dense",
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
        d_ff=512, vocab_size=2048, pattern=("attn",), mlp="swiglu",
        tie_embeddings=True, remat="none",
    )


def _arch_cell_cfgs():
    """(label, config) per architecture family the state pool serves.

    One config per layer-kind family: the dense attention bench model,
    a mamba2-130m-shaped pure-SSD stack, a pure RG-LRU stack, and a
    recurrentgemma-shaped (rglru, rglru, local_attn) hybrid.  Sizes match
    the registry smoke configs so the rows price the same shapes the
    token-identity tests pin.
    """
    from repro.configs.base import ModelConfig
    ssm = ModelConfig(
        name="serve-bench-ssm", family="ssm",
        n_layers=3, d_model=48, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=512,
        pattern=("ssd",), mlp="none", norm="rmsnorm",
        ssm_state=16, ssm_headdim=24, ssm_expand=2, ssm_chunk=8,
        conv_width=4, rope_theta=0.0, tie_embeddings=True, remat="none")
    rglru = ModelConfig(
        name="serve-bench-rglru", family="hybrid",
        n_layers=3, d_model=48, n_heads=0, n_kv_heads=0,
        d_ff=96, vocab_size=512,
        pattern=("rglru",), mlp="geglu", norm="rmsnorm",
        d_rnn=48, conv_width=4, rope_theta=0.0,
        tie_embeddings=True, remat="none")
    hybrid = ModelConfig(
        name="serve-bench-hybrid", family="hybrid",
        n_layers=5, d_model=48, n_heads=4, n_kv_heads=1, head_dim=12,
        d_ff=96, vocab_size=512,
        pattern=("rglru", "rglru", "local_attn"), window=8,
        mlp="geglu", norm="rmsnorm", d_rnn=48, conv_width=4,
        rope_theta=10000.0, tie_embeddings=True, emb_scale=True,
        remat="none")
    return [("attn", _bench_cfg()), ("ssm", ssm), ("rglru", rglru),
            ("hybrid", hybrid)]


def _hbm_bytes_per_decode_token(cfg, slots: int, max_seq: int,
                                mean_len: float, page_size: int,
                                itemsize: int = 2) -> tuple[float, float]:
    """(gather, paged) estimated HBM bytes per decode token.

    One pure-decode step emits ``slots`` tokens.  Per layer the gather
    path touches the full padded view three times (pool read -> dense
    write -> attention read) for K and V; the paged kernel streams each
    slot's *allocated pages* once — page-granular, so a ``mean_len``-token
    prefix costs ``ceil(mean_len / page_size) * page_size`` positions, not
    ``mean_len``.  Q/O and weight traffic are identical between the paths
    and excluded.
    """
    kv_bytes = cfg.n_kv_heads * cfg.resolved_head_dim * itemsize * 2  # K+V
    page_tokens = -(-mean_len // page_size) * page_size
    gather = cfg.n_layers * 3 * slots * max_seq * kv_bytes / slots
    paged = cfg.n_layers * slots * page_tokens * kv_bytes / slots
    return gather, paged


def _hbm_bytes_per_decode_token_kv(cfg, mean_len: float, page_size: int,
                                   fmt) -> float:
    """Estimated HBM bytes the *paged kernel* streams per decode token
    under KV format ``fmt`` (``repro.quant.KVFormat``).

    Reuses the paged-path accounting of
    :func:`_hbm_bytes_per_decode_token` (so the two row families can
    never desynchronize) at the format's native itemsize, plus — for
    quantized formats — the fp32 scale sidecar (2 scales per page per
    kv head, K and V).  The sidecar is why i8 lands at ~0.51x of bf16
    rather than exactly 0.5x.
    """
    _, paged = _hbm_bytes_per_decode_token(cfg, 1, 0, mean_len, page_size,
                                           itemsize=fmt.itemsize)
    if fmt.quantized:
        pages = -(-mean_len // page_size)
        paged += cfg.n_layers * pages * cfg.n_kv_heads * 4 * 2
    return paged


def _drive(engine, prompts, max_new):
    import repro.serve as serve
    # warm the single compiled (B, chunk) step so the sweep measures
    # steady state (prefill, decode and mixed plans share one shape)
    engine.submit(prompts[0], max_new=2)
    engine.drain()
    engine.stats = serve.EngineStats(engine.cache.n_slots)
    for p in prompts:
        engine.submit(p, max_new=max_new)
    engine.drain()
    return engine.stats.summary()


def run(trace_path=None, metrics_path=None, fault_trace_path=None,
        journal_path=None) -> list[tuple[str, float, str]]:
    import jax
    import jax.numpy as jnp

    from repro import mpx, serve
    from repro.models import transformer as T
    from repro.obs import JournalRecorder, Tracer

    cfg = _bench_cfg()
    params = mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), cfg))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            int(n)).tolist()
               for n in rng.integers(4, 24, REQUESTS)]

    rows = []
    for slots in SLOT_COUNTS:
        engine = serve.ServeEngine(cfg, params, n_slots=slots, max_seq=64,
                                   page_size=16, chunk_size=16)
        s = _drive(engine, prompts, MAX_NEW)
        us_per_tok = 1e6 / max(s["tok_per_s"], 1e-9)
        rows.append((
            f"serving_tok_{slots}slots", us_per_tok,
            f"tok_s={s['tok_per_s']:.0f} occ={s['mean_occupancy']:.2f}"))
        rows.append((
            f"serving_ttft_{slots}slots", s["ttft_mean_s"] * 1e6,
            f"p95={s['ttft_p95_s']*1e3:.1f}ms steps={int(s['steps'])}"))
        rows.append((
            f"serving_itl_p95_{slots}slots", s["itl_p95_s"] * 1e6,
            f"p50={s['itl_p50_s']*1e3:.2f}ms "
            f"mixed={int(s['mixed_steps'])}/{int(s['steps'])} steps"))

    # -- paged kernel vs gather path, identical workload --------------------
    cmp_prompts = prompts[:CMP_REQUESTS]
    on_tpu = jax.default_backend() == "tpu"
    for label, use_kernel in (("gather", False), ("paged", True)):
        engine = serve.ServeEngine(
            cfg, params, n_slots=CMP_SLOTS, max_seq=CMP_MAX_SEQ,
            page_size=CMP_PAGE, chunk_size=16, use_kernel=use_kernel)
        s = _drive(engine, cmp_prompts, CMP_MAX_NEW)
        us_per_tok = 1e6 / max(s["tok_per_s"], 1e-9)
        note = "" if (on_tpu or not use_kernel) else " (interpret mode)"
        rows.append((
            f"serving_tok_{CMP_SLOTS}slots_{label}", us_per_tok,
            f"tok_s={s['tok_per_s']:.0f}{note}"))
        rows.append((
            f"serving_itl_p95_{CMP_SLOTS}slots_{label}",
            s["itl_p95_s"] * 1e6,
            f"p50={s['itl_p50_s']*1e3:.2f}ms{note}"))

    mean_len = float(np.mean([len(p) for p in cmp_prompts])) + CMP_MAX_NEW / 2
    gb, pb = _hbm_bytes_per_decode_token(cfg, CMP_SLOTS, CMP_MAX_SEQ,
                                         mean_len, CMP_PAGE)
    rows.append(("serving_hbm_bytes_decode_gather", gb,
                 f"3x padded dense copy/layer maxseq={CMP_MAX_SEQ}"))
    rows.append(("serving_hbm_bytes_decode_paged", pb,
                 f"allocated pages only mean_len={mean_len:.0f} "
                 f"page={CMP_PAGE} ({gb / pb:.1f}x less than gather)"))

    # -- KV-dtype sweep: quantized page pools, identical workload -----------
    # wall-clock rows run the gather fallback off-TPU (the kernel
    # interprets there); the serving_hbm_bytes_decode_kv* rows carry the
    # comparison — bytes the paged kernel streams per decode token
    from repro import quant
    kv_hbm = {}
    for label, fmt_name in KV_CELL:
        fmt = quant.resolve(fmt_name)
        engine = serve.ServeEngine(
            cfg, params, n_slots=CMP_SLOTS, max_seq=CMP_MAX_SEQ,
            page_size=CMP_PAGE, chunk_size=16, use_kernel=on_tpu,
            kv_dtype=fmt)
        s = _drive(engine, cmp_prompts, CMP_MAX_NEW)
        rows.append((
            f"serving_tok_kv{label}", 1e6 / max(s["tok_per_s"], 1e-9),
            f"tok_s={s['tok_per_s']:.0f} fmt={fmt.name}"
            f"{'' if on_tpu else ' (gather fallback wall-clock)'}"))
        kv_hbm[label] = _hbm_bytes_per_decode_token_kv(
            cfg, mean_len, CMP_PAGE, fmt)
    for label, fmt_name in KV_CELL:
        ratio = kv_hbm[label] / kv_hbm["bf16"]
        rows.append((
            f"serving_hbm_bytes_decode_kv{label}", kv_hbm[label],
            f"paged-kernel bytes/decode-token fmt={fmt_name} "
            f"({ratio:.2f}x of bf16, incl. scale sidecar)"))

    # -- speculative decode vs baseline, repeat-heavy workload --------------
    # the bench model's random weights generate pattern-free text that an
    # n-gram proposer can't guess, so the speculative cell runs a
    # repeat-prone variant (blocks zeroed: the residual stream is exactly
    # the last token's embedding, greedy decode repeats it) — the
    # proposer's best case, measuring the verify/commit machinery at high
    # acceptance rather than the proposer's hit rate on noise.  Greedy
    # keeps the two runs token-identical, so the comparison is pure steps.
    rep_params = dict(params)
    rep_params["scan"] = jax.tree.map(jnp.zeros_like, params["scan"])
    spec_prompts = [
        (rng.integers(1, cfg.vocab_size, 4).tolist() * 4)[:14]
        for _ in range(SPEC_REQUESTS)]
    spec_stats = {}
    for label, spec in (("base", 0), ("spec", SPEC_TOKENS)):
        engine = serve.ServeEngine(
            cfg, rep_params, n_slots=SPEC_SLOTS, max_seq=128, page_size=16,
            chunk_size=16, spec_tokens=spec)
        s = _drive(engine, spec_prompts, SPEC_MAX_NEW)
        spec_stats[label] = s
        rows.append((
            f"serving_tok_spec_{label}", 1e6 / max(s["tok_per_s"], 1e-9),
            f"tok_s={s['tok_per_s']:.0f} steps={int(s['steps'])} "
            f"k={spec}"))
    sb, ss = spec_stats["base"], spec_stats["spec"]
    steps_ratio = ((sb["steps"] / max(sb["new_tokens"], 1)) /
                   max(ss["steps"] / max(ss["new_tokens"], 1), 1e-9))
    rows.append((
        "serving_spec_accept_rate", ss["spec_accept_rate"],
        f"accepted={int(ss['spec_accepted'])}/"
        f"proposed={int(ss['spec_proposed'])} k={SPEC_TOKENS} ngram"))
    rows.append((
        "serving_spec_tokens_per_step", ss["tokens_per_step"],
        f"base={sb['tokens_per_step']:.2f} "
        f"({steps_ratio:.1f}x fewer steps/token)"))

    # -- observability overhead: identical workload, tracer off vs on -------
    # the engine registry is always on (its cost is part of every row
    # above); this cell prices the opt-in tracer specifically.  The
    # tracer's intrinsic cost is ~12us of host Python per tick (measured)
    # vs multi-ms steps, so the signal is far below CPU run-to-run noise —
    # interleave the variants and take best-of-N tok/s, the standard
    # microbenchmark treatment for scheduler jitter.
    tok = {"off": 0.0, "on": 0.0}
    for rep in range(3):
        for label in ("off", "on") if rep % 2 == 0 else ("on", "off"):
            engine = serve.ServeEngine(
                cfg, params, n_slots=CMP_SLOTS, max_seq=CMP_MAX_SEQ,
                page_size=CMP_PAGE, chunk_size=16,
                tracer=Tracer() if label == "on" else None)
            s = _drive(engine, cmp_prompts, CMP_MAX_NEW)
            tok[label] = max(tok[label], s["tok_per_s"])
    overhead_pct = 100.0 * (tok["off"] - tok["on"]) / max(tok["off"], 1e-9)
    rows.append((
        "serving_obs_overhead_pct", overhead_pct,
        f"tok_s off={tok['off']:.0f} on={tok['on']:.0f} (budget <3%)"))

    # -- flight-recorder overhead: identical workload, journal off vs on ----
    # the recorder appends one JSONL line per tick/submit/result from
    # host-side ints only (the token-chain hash reuses the two arrays the
    # verifier already transferred — zero added syncs, pinned by the same
    # transfer-count test as the tracer).  Same interleaved best-of-N
    # treatment as the obs cell.
    import os
    import tempfile
    tok = {"off": 0.0, "on": 0.0}
    for rep in range(3):
        for label in ("off", "on") if rep % 2 == 0 else ("on", "off"):
            journal = None
            jpath = None
            if label == "on":
                fd, jpath = tempfile.mkstemp(suffix=".jsonl")
                os.close(fd)
                journal = JournalRecorder(jpath, param_seed=0)
            engine = serve.ServeEngine(
                cfg, params, n_slots=CMP_SLOTS, max_seq=CMP_MAX_SEQ,
                page_size=CMP_PAGE, chunk_size=16, journal=journal)
            s = _drive(engine, cmp_prompts, CMP_MAX_NEW)
            if journal is not None:
                journal.close()
                os.unlink(jpath)
            tok[label] = max(tok[label], s["tok_per_s"])
    overhead_pct = 100.0 * (tok["off"] - tok["on"]) / max(tok["off"], 1e-9)
    rows.append((
        "serving_journal_overhead_pct", overhead_pct,
        f"tok_s off={tok['off']:.0f} on={tok['on']:.0f} (budget <3%)"))

    # -- per-architecture throughput: one state-pool engine, every family ---
    # attention reserves KV pages; ssm/rglru slots carry O(1) recurrent
    # state with zero pages; the hybrid stack uses both at once.  Greedy
    # token identity vs the dense decode() oracle is pinned by
    # tests/test_serve_state.py — these rows price the trajectories.
    for i, (label, acfg) in enumerate(_arch_cell_cfgs()):
        aparams = mpx.cast_to_bfloat16(
            T.init_params(jax.random.key(100 + i), acfg))
        arch_prompts = [rng.integers(1, acfg.vocab_size, int(n)).tolist()
                        for n in rng.integers(4, 12, 6)]
        engine = serve.ServeEngine(acfg, aparams, n_slots=2, max_seq=64,
                                   page_size=16, chunk_size=16)
        s = _drive(engine, arch_prompts, 8)
        kinds = ",".join(sorted(set(acfg.layer_kinds())))
        rows.append((
            f"serving_tok_arch_{label}", 1e6 / max(s["tok_per_s"], 1e-9),
            f"tok_s={s['tok_per_s']:.0f} kinds={kinds} "
            f"pages={engine.cache.num_pages}"))

    # -- resilience: preemption/recompute overhead --------------------------
    # identical greedy workload on an ample pool vs a pool deliberately
    # too small for both slots (3 pages, 2 pages per request): the second
    # request can only admit by evicting the first, which then recomputes.
    # Greedy output is token-identical between the runs (pinned by
    # tests/test_serve_faults.py), so the pct is the pure step cost of the
    # recompute prefills — and exactly 0 when preemption never fires.
    pre_prompts = [rng.integers(1, cfg.vocab_size, 8).tolist()
                   for _ in range(4)]
    pre = {}
    for label, pool_kw in (("ample", {}), ("constrained", {"num_pages": 3})):
        engine = serve.ServeEngine(cfg, params, n_slots=2, max_seq=64,
                                   page_size=8, chunk_size=16, **pool_kw)
        pre[label] = _drive(engine, pre_prompts, 8)
        pre[label]["preemptions"] = engine.metrics_snapshot().get(
            "serve_preemptions_total", 0)
    overhead_pct = (100.0
                    * (pre["constrained"]["steps"] - pre["ample"]["steps"])
                    / max(pre["ample"]["steps"], 1))
    rows.append((
        "serving_preempt_recompute_overhead_pct", overhead_pct,
        f"steps ample={int(pre['ample']['steps'])} "
        f"constrained={int(pre['constrained']['steps'])} "
        f"preemptions={int(pre['constrained']['preemptions'])} "
        f"(ample run: {int(pre['ample']['preemptions'])})"))

    # -- resilience: one scripted chaos drive -------------------------------
    # four requests, four fates: one poisoned to NaN logits mid-decode,
    # one whose deadline a scripted clock jump expires, one cancelled
    # while waiting, one untouched — the value is the count of distinct
    # terminal statuses (4 = every failure path exercised); the derived
    # column carries the per-status tally.  With --fault-trace the drive
    # runs under a tracer and exports the Chrome trace (preempt / timeout
    # / cancelled / nonfinite instants on the per-slot timelines) for CI
    # to archive beside the JSON rows.
    clock = serve.FakeClock()
    faults = (serve.FaultInjector(clock=clock)
              .poison_logits(1, tick=2)
              .advance_clock(3, 10.0))
    ftracer = Tracer(process_name="repro.serve.chaos")
    # with --journal the chaos drive doubles as the CI replay fixture:
    # the journal records this exact drive (faults, clock jumps, cancel)
    # and `python -m repro.obs.journal <path>` re-drives it token-
    # identically (params rebuilt from param_seed=0, same as above)
    fjournal = (JournalRecorder(journal_path, param_seed=0)
                if journal_path else None)
    engine = serve.ServeEngine(cfg, params, n_slots=2, max_seq=64,
                               page_size=16, chunk_size=16,
                               faults=faults, tracer=ftracer,
                               journal=fjournal)
    rid_ok = engine.submit(pre_prompts[0], max_new=8)
    engine.submit(pre_prompts[1], max_new=8, request_id=1)  # poisoned
    rid_dl = engine.submit(pre_prompts[2], max_new=8, deadline_ms=500)
    rid_cx = engine.submit(pre_prompts[3], max_new=8)
    engine.step()
    engine.step()
    engine.cancel(rid_cx)
    status_of = {r.request_id: r.status for r in engine.drain()}
    counts = {}
    for st in status_of.values():
        counts[st] = counts.get(st, 0) + 1
    engine.cache.check_invariants()      # chaos must not leak the pool
    assert status_of[rid_ok] == "ok" and status_of[1] == "failed"
    assert status_of[rid_dl] == "timeout" and status_of[rid_cx] == "cancelled"
    rows.append((
        "serving_resilience_statuses", float(len(counts)),
        " ".join(f"{k}={v}" for k, v in sorted(counts.items()))))
    if fault_trace_path:
        ftracer.export(fault_trace_path)
    if fjournal is not None:
        fjournal.close()

    # -- prefix caching: repeated-prefix workload ---------------------------
    # a hot 112-token (7-page) system prompt shared by every request,
    # with 2-token distinct suffixes.  One warm request registers the
    # prefix; the hot requests then admit with those pages mapped shared
    # and prefill only their suffix — TTFT drops to roughly one chunk
    # step regardless of prompt length, and the pool holds ONE copy of
    # the prefix however many requests ride it.  Greedy output stays
    # token-identical to the no-sharing run (pinned by
    # tests/test_prefix_cache.py); these rows price the win.
    hot_prefix = rng.integers(1, cfg.vocab_size, PREFIX_LEN).tolist()
    hot_prompts = [
        hot_prefix + rng.integers(1, cfg.vocab_size, PREFIX_SUFFIX).tolist()
        for _ in range(PREFIX_REQUESTS)]
    px_stats, px_engine = {}, {}
    for label, pc in (("off", False), ("on", True)):
        engine = serve.ServeEngine(
            cfg, params, n_slots=PREFIX_SLOTS, max_seq=256,
            page_size=PREFIX_PAGE, chunk_size=16, prefix_cache=pc)
        engine.submit(list(hot_prefix), max_new=2)   # warm: compile, and
        engine.drain()                               # (on) register prefix
        engine.stats = serve.EngineStats(PREFIX_SLOTS)
        for p in hot_prompts:                        # hot: sequential, so
            engine.submit(list(p), max_new=PREFIX_MAX_NEW)
            engine.drain()                           # TTFT is queue-free
        px_stats[label] = engine.stats.summary()
        px_engine[label] = engine
    ratio = (px_stats["on"]["ttft_mean_s"]
             / max(px_stats["off"]["ttft_mean_s"], 1e-9))
    snap = px_engine["on"].metrics_snapshot()
    rows.append((
        "serving_prefix_ttft_hot_ratio", ratio,
        f"hot ttft on={px_stats['on']['ttft_mean_s']*1e3:.1f}ms "
        f"off={px_stats['off']['ttft_mean_s']*1e3:.1f}ms "
        f"prefix={PREFIX_LEN}tok (target <=0.2x)"))
    rows.append((
        "serving_prefix_prefill_tokens_hot",
        px_stats["on"]["prefill_tokens_fed"],
        f"off={int(px_stats['off']['prefill_tokens_fed'])} — cached "
        f"prefix skipped entirely; hits="
        f"{int(snap['serve_prefix_hits_total'])} pages"))
    # after the drives everything is retired, so the resident pages are
    # exactly the cached prefix copy (used_pages counts non-free pages)
    resident = px_engine["on"].cache.used_pages
    off_allocs = px_engine["off"].cache.pages_for(
        PREFIX_LEN + PREFIX_SUFFIX + PREFIX_MAX_NEW) * PREFIX_REQUESTS
    rows.append((
        "serving_prefix_pages_resident", float(resident),
        f"one {PREFIX_LEN // PREFIX_PAGE}-page prefix copy serves "
        f"{PREFIX_REQUESTS} requests (no sharing: {off_allocs} page-"
        f"allocs); cow={int(snap['serve_cow_copies_total'])}"))
    # the cell's structural claim — the hot requests fed only their
    # suffixes (cached-prefix prefill tokens ~ 0, not just "fewer")
    assert (px_stats["on"]["prefill_tokens_fed"]
            <= PREFIX_REQUESTS * (PREFIX_SUFFIX + 1)), \
        "prefix cache failed to absorb the shared prefix"
    check_rows(rows)     # the CI artifact schema is pinned — fail loudly

    if trace_path or metrics_path:
        # artifact drive: speculative engine with a live tracer, so the
        # exported timeline shows the full lifecycle including decode
        # windows with draft/accept counts and truncated tails
        tracer = Tracer(process_name="repro.serve")
        engine = serve.ServeEngine(
            cfg, rep_params, n_slots=SPEC_SLOTS, max_seq=128, page_size=16,
            chunk_size=16, spec_tokens=SPEC_TOKENS, tracer=tracer)
        _drive(engine, spec_prompts, SPEC_MAX_NEW)
        if trace_path:
            tracer.export(trace_path)
        if metrics_path:
            with open(metrics_path, "w") as f:
                f.write(engine.prometheus())
    return rows


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", type=str, default=None,
                    help="also dump rows as JSON to this path (CI artifact)")
    ap.add_argument("--trace", type=str, default=None,
                    help="export a Chrome trace of a speculative serve "
                         "drive to this path (open in Perfetto)")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the engine's Prometheus text snapshot "
                         "to this path")
    ap.add_argument("--fault-trace", type=str, default=None,
                    help="export a Chrome trace of the scripted chaos "
                         "drive (poison/deadline/cancel) to this path")
    ap.add_argument("--journal", type=str, default=None,
                    help="record the chaos drive's flight-recorder journal "
                         "to this path (replay with `python -m "
                         "repro.obs.journal <path>`, analyze with "
                         "`python -m repro.obs.postmortem <path>`)")
    args = ap.parse_args()
    rows = run(trace_path=args.trace, metrics_path=args.metrics_out,
               fault_trace_path=args.fault_trace,
               journal_path=args.journal)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "value": v, "derived": d}
                       for n, v, d in rows], f, indent=2)


if __name__ == "__main__":
    main()
