"""Serving-engine benchmark: tok/s, TTFT and inter-token latency.

Drives the full ``repro.serve`` stack (paged KV cache, mixed prefill+decode
chunk steps, continuous batching, greedy fp32 sampling) over a fixed ragged
request queue on a small dense model.  Wall time on CPU is indicative only;
the shape of the trajectory — throughput scaling with slot count while TTFT
holds, and inter-token p50/p95 staying near one step time instead of
ballooning whenever another slot prefills — is the serving-side analogue of
the paper's batch-size sweeps.  The ITL rows are the measurable form of the
unified-batch scheduler fix: under the old prefill-priority alternation a
decode slot's inter-token gap spanned a whole prompt's worth of chunk
steps.
"""
from __future__ import annotations

import numpy as np

SLOT_COUNTS = (2, 4, 8)
REQUESTS = 16
MAX_NEW = 16


def run() -> list[tuple[str, float, str]]:
    import jax

    from repro import mpx, serve
    from repro.configs.base import ModelConfig
    from repro.models import transformer as T

    cfg = ModelConfig(
        name="serve-bench", family="dense",
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
        d_ff=512, vocab_size=2048, pattern=("attn",), mlp="swiglu",
        tie_embeddings=True, remat="none",
    )
    params = mpx.cast_to_bfloat16(T.init_params(jax.random.key(0), cfg))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            int(n)).tolist()
               for n in rng.integers(4, 24, REQUESTS)]

    rows = []
    for slots in SLOT_COUNTS:
        engine = serve.ServeEngine(cfg, params, n_slots=slots, max_seq=64,
                                   page_size=16, chunk_size=16)
        # warm the single compiled (B, chunk) step so the sweep measures
        # steady state (prefill, decode and mixed plans share one shape)
        engine.submit(prompts[0], max_new=2)
        engine.drain()
        engine.stats = serve.EngineStats(slots)
        for p in prompts:
            engine.submit(p, max_new=MAX_NEW)
        engine.drain()
        s = engine.stats.summary()
        us_per_tok = 1e6 / max(s["tok_per_s"], 1e-9)
        rows.append((
            f"serving_tok_{slots}slots", us_per_tok,
            f"tok_s={s['tok_per_s']:.0f} occ={s['mean_occupancy']:.2f}"))
        rows.append((
            f"serving_ttft_{slots}slots", s["ttft_mean_s"] * 1e6,
            f"p95={s['ttft_p95_s']*1e3:.1f}ms steps={int(s['steps'])}"))
        rows.append((
            f"serving_itl_p95_{slots}slots", s["itl_p95_s"] * 1e6,
            f"p50={s['itl_p50_s']*1e3:.2f}ms "
            f"mixed={int(s['mixed_steps'])}/{int(s['steps'])} steps"))
    return rows
